"""End-to-end scheduler tests over the real Table-1 catalog."""
import numpy as np
import pytest

from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_8B,
                        LLAMA3_70B, TPU_CATALOG, build_problem, make_trace,
                        solve, solve_homogeneous)
from repro.core.scheduler import (apply_round_robin_assignment,
                                  solve_fixed_composition, uniform_composition)
from repro.core.costmodel import config_throughput


@pytest.fixture(scope="module")
def trace():
    return make_trace("trace1", num_requests=500, seed=0)


def test_build_problem_shapes(trace):
    p = build_problem([LLAMA3_70B], trace, GPU_CATALOG,
                      AVAILABILITY_SNAPSHOTS["avail1"], budget=30.0)
    assert len(p.configs) > 10
    assert p.h.shape[0] == len(p.configs)
    assert (p.h >= 0).all()
    # every demand must be servable by at least one config
    assert (p.h.max(axis=0) > 0).all()


def test_solve_binary_search_respects_constraints(trace):
    avail = AVAILABILITY_SNAPSHOTS["avail1"]
    plan = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, budget=30.0)
    assert plan.cost <= 30.0 + 1e-6
    for name, n in plan.composition().items():
        assert n <= avail[name]
    # full coverage: assignment columns sum to 1
    col = plan.assignment.sum(axis=0)
    np.testing.assert_allclose(col, 1.0, atol=1e-6)
    assert plan.makespan > 0


def test_heterogeneous_beats_homogeneous(trace):
    """The paper's headline: ours >= best homogeneous baseline (same budget)."""
    budget = 30.0
    avail = AVAILABILITY_SNAPSHOTS["avail1"]
    ours = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, budget)
    homo_best = None
    for gpu in ("H100", "A6000", "4090"):
        try:
            p = solve_homogeneous([LLAMA3_70B], trace, GPU_CATALOG, gpu, budget)
            homo_best = p.makespan if homo_best is None else min(homo_best, p.makespan)
        except (RuntimeError, ValueError):
            continue
    assert homo_best is not None
    # Note: homogeneous baselines have *unlimited* availability (paper §5.1),
    # so they can beat constrained heterogeneity at high budgets; at 30 $/h
    # under avail1 heterogeneity must win or tie within tolerance.
    assert ours.makespan <= homo_best * 1.05


def test_fixed_uniform_composition_is_worse_or_equal(trace):
    budget = 30.0
    avail = AVAILABILITY_SNAPSHOTS["avail1"]
    ours = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, budget)
    comp = uniform_composition(GPU_CATALOG, avail, budget)
    uni = solve_fixed_composition([LLAMA3_70B], trace, GPU_CATALOG, comp, budget)
    assert uni.makespan >= ours.makespan * 0.999


def test_round_robin_assignment_is_worse_or_equal(trace):
    budget = 30.0
    avail = AVAILABILITY_SNAPSHOTS["avail1"]
    ours = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, budget)
    h_fn = lambda cfg, w: config_throughput(cfg.stages, cfg.model,
                                            __import__("repro.core.workloads",
                                                       fromlist=["WORKLOAD_TYPES"]).WORKLOAD_TYPES[w])
    rr = apply_round_robin_assignment(ours, h_fn)
    assert rr.makespan >= ours.makespan * 0.999


def test_multi_model_serving(trace):
    """App E: two models share budget + availability."""
    mm_trace = make_trace("trace1", num_requests=400, model_mix=(0.8, 0.2), seed=1)
    plan = solve([LLAMA3_8B, LLAMA3_70B], mm_trace, GPU_CATALOG,
                 AVAILABILITY_SNAPSHOTS["avail2"], budget=60.0)
    assert plan.cost <= 60.0 + 1e-6
    models_used = {cfg.model_index for cfg in plan.replicas}
    assert models_used == {0, 1}
    np.testing.assert_allclose(plan.assignment.sum(axis=0), 1.0, atol=1e-6)


def test_tpu_catalog_scheduling(trace):
    """Hardware adaptation: same scheduler over heterogeneous TPU slices."""
    avail = {"v5e-1": 16, "v5e-4": 8, "v5e-8": 4, "v4-8": 4, "v5p-8": 2}
    plan = solve([LLAMA3_8B], trace, TPU_CATALOG, avail, budget=40.0)
    assert plan.cost <= 40.0 + 1e-6
    assert plan.makespan > 0


def test_budget_monotonicity(trace):
    """More budget can't make the optimal makespan worse."""
    avail = AVAILABILITY_SNAPSHOTS["avail1"]
    t15 = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, 15.0, tol=0.5).makespan
    t60 = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, 60.0, tol=0.5).makespan
    assert t60 <= t15 * 1.02


def test_feasibility_accepts_time_limit_incumbent(trace, monkeypatch):
    """HiGHS status 1 (time/iteration limit) with a feasible incumbent must
    be accepted by solve_feasibility, exactly as solve_milp accepts (0, 1)
    — rejecting it made binary search treat "slow to prove optimal" as
    "infeasible" and silently degrade plans under tight time limits."""
    from repro.core import build_problem, milp as milp_mod
    from repro.core.milp import solve_feasibility

    problem = build_problem([LLAMA3_70B], trace, GPU_CATALOG,
                            AVAILABILITY_SNAPSHOTS["avail1"], budget=30.0)
    t_hat = problem.makespan_upper_bound()        # generously feasible
    witness = solve_feasibility(problem, t_hat)
    assert witness is not None
    y0, x0 = witness

    real_milp = milp_mod.milp

    class _TimeLimited:
        def __init__(self, res):
            self.status = 1                       # limit hit, incumbent kept
            self.x = res.x
            self.message = "time limit"

    def fake_milp(*args, **kwargs):
        return _TimeLimited(real_milp(*args, **kwargs))

    monkeypatch.setattr(milp_mod, "milp", fake_milp)
    witness1 = solve_feasibility(problem, t_hat)
    assert witness1 is not None
    np.testing.assert_allclose(witness1[0], y0)

    # status 1 *without* an incumbent (x is None) must still return None
    class _NoIncumbent:
        status = 1
        x = None
        message = "time limit, no solution"

    monkeypatch.setattr(milp_mod, "milp", lambda *a, **k: _NoIncumbent())
    assert solve_feasibility(problem, t_hat) is None
