"""Planner-registry tests: DeploymentSpec validation and strategy parity —
every registered strategy must produce a plan identical (composition,
configs, assignment) to the legacy ``solve_*`` entrypoint it replaces."""
import warnings

import numpy as np
import pytest

from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_8B,
                        DeploymentSpec, make_trace, plan, planner_names,
                        replan, uniform_composition)
from repro.core import scheduler as sched
from repro.core.scheduler import ScalePolicy

TRACES = {
    "t1": make_trace("trace1", num_requests=300, seed=0),
    "t2": make_trace("trace2", num_requests=200, arrival_rate=5.0, seed=1),
}
AVAILS = {"avail1": AVAILABILITY_SNAPSHOTS["avail1"],
          "avail2": AVAILABILITY_SNAPSHOTS["avail2"]}
BUDGET = 20.0
FAST = dict(tol=2.0)           # keep the MILP search cheap in CI


def _spec(trace, avail, **kw):
    return DeploymentSpec(models=[LLAMA3_8B], workload=trace,
                          catalog=GPU_CATALOG, availability=avail,
                          budget=BUDGET, **kw)


def _assert_identical(a, b):
    """Same composition, same configs, same assignment, same makespan."""
    assert [c.key for c in a.replicas] == [c.key for c in b.replicas]
    assert a.composition() == b.composition()
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert a.demands == b.demands
    assert a.makespan == b.makespan
    assert a.cost == b.cost


def _legacy(fn, *args, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kw)


@pytest.mark.parametrize("tkey", sorted(TRACES))
@pytest.mark.parametrize("akey", sorted(AVAILS))
def test_milp_strategy_matches_solve(tkey, akey):
    trace, avail = TRACES[tkey], AVAILS[akey]
    ours = plan(_spec(trace, avail), **FAST)
    legacy = _legacy(sched.solve, [LLAMA3_8B], trace, GPU_CATALOG, avail,
                     BUDGET, **FAST)
    _assert_identical(ours, legacy)


def test_homogeneous_strategy_matches_solve_homogeneous():
    trace, avail = TRACES["t1"], AVAILS["avail1"]
    ours = plan(_spec(trace, avail), strategy="homogeneous",
                gpu_type="A6000", **FAST)
    legacy = _legacy(sched.solve_homogeneous, [LLAMA3_8B], trace,
                     GPU_CATALOG, "A6000", BUDGET, **FAST)
    _assert_identical(ours, legacy)


def test_uniform_strategy_matches_solve_uniform_deployment():
    trace, avail = TRACES["t1"], AVAILS["avail2"]
    ours = plan(_spec(trace, avail), strategy="uniform", tp=4, **FAST)
    legacy = _legacy(sched.solve_uniform_deployment, [LLAMA3_8B], trace,
                     GPU_CATALOG, avail, BUDGET, tp=4, **FAST)
    _assert_identical(ours, legacy)


def test_fixed_strategy_matches_solve_fixed_composition():
    trace, avail = TRACES["t2"], AVAILS["avail1"]
    comp = uniform_composition(GPU_CATALOG, avail, BUDGET)
    ours = plan(_spec(trace, avail), strategy="fixed", composition=comp,
                **FAST)
    legacy = _legacy(sched.solve_fixed_composition, [LLAMA3_8B], trace,
                     GPU_CATALOG, comp, BUDGET, **FAST)
    _assert_identical(ours, legacy)
    # the default composition IS the uniform split (ablation i)
    default = plan(_spec(trace, avail), strategy="fixed", **FAST)
    _assert_identical(ours, default)


def test_cost_objective_matches_solve_min_cost():
    trace, avail = TRACES["t1"], AVAILS["avail1"]
    base = plan(_spec(trace, avail), **FAST)
    slo = base.makespan * 2.0
    ours = plan(_spec(trace, avail, objective="cost", slo_makespan=slo))
    legacy = _legacy(sched.solve_min_cost, [LLAMA3_8B], trace, GPU_CATALOG,
                     avail, BUDGET, slo)
    _assert_identical(ours, legacy)
    assert ours.cost <= base.cost + 1e-6
    # makespan-only solver knobs must not be silently ignored
    with pytest.raises(ValueError, match="do not apply"):
        plan(_spec(trace, avail, objective="cost", slo_makespan=slo),
             tol=0.5)


def test_replan_matches_legacy_replan():
    trace, avail = TRACES["t1"], AVAILS["avail1"]
    spec = _spec(trace, avail)
    base = plan(spec, **FAST)
    dropped = dict(avail, H100=0)
    ours = replan(base, spec, availability=dropped, **FAST)
    legacy = _legacy(sched.replan, base, [LLAMA3_8B], trace, GPU_CATALOG,
                     dropped, BUDGET, **FAST)
    _assert_identical(ours, legacy)
    assert (ours.solver_info["replicas_kept"]
            == legacy.solver_info["replicas_kept"])
    assert "H100" not in ours.composition()


def test_replan_accepts_legacy_positional_signature():
    """`from repro.core import replan` predates the spec API: the old
    positional call shape must keep working (with a warning)."""
    trace, avail = TRACES["t1"], AVAILS["avail1"]
    spec = _spec(trace, avail)
    base = plan(spec, **FAST)
    dropped = dict(avail, H100=0)
    with pytest.warns(DeprecationWarning, match="replan"):
        legacy = replan(base, [LLAMA3_8B], trace, GPU_CATALOG, dropped,
                        BUDGET, **FAST)
    new = replan(base, spec, availability=dropped, **FAST)
    _assert_identical(legacy, new)
    with pytest.raises(TypeError):
        replan(base, [LLAMA3_8B], trace)          # malformed legacy call
    with pytest.raises(TypeError):
        replan(base, spec, trace)                 # extra positional


def test_registry_surface():
    for name in ("milp", "homogeneous", "uniform", "fixed"):
        assert name in planner_names()
    with pytest.raises(ValueError, match="unknown planning strategy"):
        plan(_spec(TRACES["t1"], AVAILS["avail1"]), strategy="nope")


def test_spec_validation():
    trace, avail = TRACES["t1"], AVAILS["avail1"]
    with pytest.raises(ValueError, match="budget"):
        _spec(trace, avail).with_budget(-1.0)
    with pytest.raises(ValueError, match="objective"):
        _spec(trace, avail, objective="latency")
    with pytest.raises(ValueError, match="slo_makespan"):
        _spec(trace, avail, objective="cost")
    spec = _spec(trace, avail)
    assert spec.with_availability({"H100": 1}).availability == {"H100": 1}
    assert spec.with_budget(5.0).budget == 5.0
    assert spec.with_objective("cost", slo_makespan=10.0).slo_makespan == 10.0


def test_legacy_wrappers_warn():
    trace, avail = TRACES["t1"], AVAILS["avail1"]
    with pytest.warns(DeprecationWarning, match="deprecated"):
        sched.solve([LLAMA3_8B], trace, GPU_CATALOG, avail, BUDGET, **FAST)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        sched.solve_homogeneous([LLAMA3_8B], trace, GPU_CATALOG, "A6000",
                                BUDGET, **FAST)


def test_scale_policy_from_spec():
    trace, avail = TRACES["t1"], AVAILS["avail1"]
    spec = _spec(trace, avail)
    base = plan(spec, **FAST)
    policy = ScalePolicy.from_spec(spec, base, window=2, cooldown=1)
    assert policy.budget == spec.budget
    assert [c.key for c in policy.candidates] == [c.key for c in base.replicas]
    assert policy.window == 2 and policy.cooldown == 1
