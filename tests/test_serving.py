"""Serving-runtime tests: router fidelity, engine generation, end-to-end
plan execution with real JAX replicas, and the training loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import GPU_CATALOG, make_trace, solve
from repro.core.costmodel import ModelProfile
from repro.serving import AssignmentRouter, HeterogeneousServer, ReplicaEngine
from repro.training import AdamW, init_state, make_train_step, data_stream


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("llama3-8b").reduced()


def test_engine_generates(tiny_cfg):
    eng = ReplicaEngine(tiny_cfg, seed=0)
    prompts = jnp.asarray(np.random.default_rng(0).integers(
        0, tiny_cfg.vocab_size, (3, 12)), jnp.int32)
    res = eng.generate(prompts, max_new=6)
    assert res.tokens.shape == (3, 6)
    assert res.tokens.dtype == np.int32
    assert (res.tokens >= 0).all() and (res.tokens < tiny_cfg.vocab_size).all()


def test_engine_deterministic(tiny_cfg):
    eng = ReplicaEngine(tiny_cfg, seed=0)
    prompts = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)
    a = eng.generate(prompts, max_new=5).tokens
    b = eng.generate(prompts, max_new=5).tokens
    np.testing.assert_array_equal(a, b)


@pytest.fixture(scope="module")
def small_plan():
    trace = make_trace("trace1", num_requests=60, seed=0)
    profile = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                           head_dim=64, params_total=2e6, params_active=2e6)
    plan = solve([profile], trace, GPU_CATALOG,
                 {"A40": 4, "4090": 4, "H100": 2}, budget=8.0)
    return plan, trace


def test_router_tracks_plan_fractions(small_plan):
    plan, trace = small_plan
    router = AssignmentRouter(plan)
    counts = np.zeros((len(plan.replicas), len(plan.demands)))
    index = {(m, w): d for d, (m, w, _) in enumerate(plan.demands)}
    for req in trace.requests:
        i = router.route(req)
        counts[i, index[(req.model, req.workload)]] += 1
    totals = counts.sum(axis=0, keepdims=True)
    realized = counts / np.maximum(totals, 1)
    # deficit-round-robin keeps realized within 1 request of planned
    for d in range(counts.shape[1]):
        np.testing.assert_allclose(
            realized[:, d] * totals[0, d],
            plan.assignment[:, d] * totals[0, d], atol=1.0)


def test_server_end_to_end(small_plan, tiny_cfg):
    plan, trace = small_plan
    server = HeterogeneousServer(plan, [tiny_cfg], max_batch=8)
    stats = server.serve(trace, input_len=8, max_new=4)
    assert stats.completed == trace.num_requests
    assert stats.generated_tokens == trace.num_requests * 4
    assert sum(stats.per_replica_requests) == trace.num_requests
    assert stats.tokens_per_s > 0


def test_train_loop_descends(tiny_cfg):
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    state = init_state(tiny_cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(tiny_cfg, opt))
    stream = data_stream(tiny_cfg, batch=4, seq_len=32, seed=0)
    batch = next(stream)   # single batch -> loss must fall when memorizing
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], f"no descent: {losses}"


def test_train_step_all_archs_grad_finite():
    """One optimizer step for a couple of exotic archs (hybrid, ssm)."""
    for name in ("jamba-v0.1-52b", "xlstm-125m"):
        cfg = get_config(name).reduced()
        opt = AdamW(lr=1e-3)
        state = init_state(cfg, jax.random.PRNGKey(1), opt)
        step = jax.jit(make_train_step(cfg, opt))
        batch = next(data_stream(cfg, batch=2, seq_len=16, seed=1))
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"])), name
