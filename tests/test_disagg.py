"""Prefill/decode disaggregation tests: transfer-queue and handoff-manager
units (capacity gating, backpressure, degrade-to-recompute), the "disagg"
planner strategy (affinity partition, role-tagged merged plans, fallback),
end-to-end disaggregated serving on the cost backend, backend-identical
handoff + admission logs, disagg-vs-colocated byte-identical engine token
streams, "both"-role degeneration to colocated behavior, host-RAM-derived
host-tier sizing, measured-hit-rate replan feedback, and the trace-summary
handoff columns cross-checked against ``result.info``."""
import dataclasses
import json
import math
import pathlib
import sys

import numpy as np
import pytest

from repro.core import costmodel
from repro.core.catalog import GPU_CATALOG, DeviceType
from repro.core.costmodel import ModelProfile, Stage, phase_affinity
from repro.core.plan import Config, ServingPlan
from repro.core.scheduler import partition_by_affinity
from repro.core.spec import DeploymentSpec
from repro.core.workloads import WORKLOAD_TYPES, Request, Trace
from repro.runtime import (CostModelExecutor, HandoffManager, ServingRuntime,
                           TransferQueue)
from repro.runtime.disagg import _Handoff
from repro.runtime.kvcache.budget import host_blocks_for, host_ram_blocks

BS = 16
TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)
BLOCK_BYTES = BS * TINY.kv_bytes_per_token


def _replica(num_blocks: int = 12, role: str = "both", **dev_kw) -> Config:
    free = (num_blocks + 0.5) * BLOCK_BYTES
    mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
           / costmodel.MEMORY_UTIL)
    dev = DeviceType("disagg-test", 1e12, 1e11, mem, 1.0, 8, 1e11, 1e9,
                     "x", **dev_kw)
    return Config(stages=(Stage(dev, 1, 1.0),), model_index=0, model=TINY,
                  role=role)


def _plan(cfgs, n_requests: int) -> ServingPlan:
    """Manual plan: arrival mass on non-decode replicas only (what the
    "disagg" strategy emits)."""
    cfgs = list(cfgs)
    takers = [i for i, c in enumerate(cfgs) if c.role != "decode"]
    assignment = np.zeros((len(cfgs), 1))
    for i in takers:
        assignment[i, 0] = 1.0 / len(takers)
    return ServingPlan(replicas=cfgs, assignment=assignment,
                       demands=[(0, 0, float(n_requests))], makespan=1.0,
                       cost=sum(c.cost for c in cfgs))


def _trace(n=4, input_len=30, output_len=4) -> Trace:
    return Trace("disagg", tuple(
        Request(req_id=i, workload=0, input_len=input_len,
                output_len=output_len, arrival=0.0) for i in range(n)))


def _run_cost(cfgs, trace, *, host_blocks=16, **ex_kw):
    executor = CostModelExecutor(list(cfgs), [TINY],
                                 host_blocks=host_blocks, **ex_kw)
    runtime = ServingRuntime(_plan(cfgs, trace.num_requests), executor)
    res = runtime.run(trace)
    return res, runtime, executor


# ------------------------------------------------- unit: transfer queue

class _Src:
    def __init__(self, index):
        self.index = index


def test_transfer_queue_capacity_and_fifo():
    q = TransferQueue(capacity=2)
    assert q.room == 2 and not q and len(q) == 0
    a = _Handoff(state=None, src=_Src(0), blocks=1, dst=None)
    b = _Handoff(state=None, src=_Src(1), blocks=1, dst=None)
    q.append(a)
    q.append(b)
    assert q.room == 0 and q.peak == 2
    assert q.parked_from(0) and q.parked_from(1) and not q.parked_from(2)
    assert q.peek() is a and q.popleft() is a       # FIFO
    assert q.room == 1 and q.peak == 2              # peak is sticky
    assert q.drain() == [b] and not q
    with pytest.raises(ValueError):
        TransferQueue(capacity=0)


# ---------------------------------------------- unit: affinity partition

def test_partition_by_affinity_splits_pool():
    avail = {"H100": 2, "A100": 2, "A40": 4, "4090": 4}
    pre, dec = partition_by_affinity(GPU_CATALOG, avail)
    assert pre and dec and not set(pre) & set(dec)
    assert sorted(pre + dec) == sorted(avail)
    # every prefill-pool type is at least as prefill-leaning as every
    # decode-pool type
    assert (min(phase_affinity(GPU_CATALOG[t]) for t in pre)
            >= max(phase_affinity(GPU_CATALOG[t]) for t in dec))
    # degenerate pools: fewer than two types -> both sides identical
    solo_p, solo_d = partition_by_affinity(GPU_CATALOG, {"H100": 4})
    assert solo_p == solo_d == ["H100"]
    # zero-count and unknown types are ignored
    pre2, dec2 = partition_by_affinity(
        GPU_CATALOG, {"H100": 2, "A100": 0, "not-a-gpu": 3, "A40": 1})
    assert sorted(pre2 + dec2) == ["A40", "H100"]


# -------------------------------------------------- planner: "disagg"

def _catalog_spec(budget=20.0):
    from repro.core import AVAILABILITY_SNAPSHOTS, LLAMA3_8B, make_trace
    trace = make_trace("trace1", num_requests=120, seed=0)
    return DeploymentSpec(models=[LLAMA3_8B], workload=trace,
                          catalog=GPU_CATALOG,
                          availability=AVAILABILITY_SNAPSHOTS["avail1"],
                          budget=budget)


def test_disagg_plan_roles_and_zero_decode_mass():
    from repro.core import plan
    spec = _catalog_spec()
    p = plan(spec, strategy="disagg", budget_splits=(0.5,), tol=2.0)
    roles = {c.role for c in p.replicas}
    assert roles == {"prefill", "decode"}
    assert p.solver_info["disagg"] == 1.0
    assert p.solver_info["budget_split"] == 0.5
    assert p.cost <= spec.budget + 1e-9
    # arrivals route to prefill replicas only: decode rows carry no mass
    for i, c in enumerate(p.replicas):
        mass = float(np.abs(p.assignment[i]).sum())
        if c.role == "decode":
            assert mass == 0.0
        else:
            assert "|prefill" in c.key
    # the merged makespan is the slower phase's
    assert math.isclose(p.makespan,
                        max(p.solver_info["prefill_makespan"],
                            p.solver_info["decode_makespan"]))
    # prefill and decode pools draw from disjoint GPU types
    pre_types = {st.device.name for c in p.replicas
                 if c.role == "prefill" for st in c.stages}
    dec_types = {st.device.name for c in p.replicas
                 if c.role == "decode" for st in c.stages}
    assert pre_types and dec_types and not pre_types & dec_types


def test_disagg_plan_falls_back_on_single_type():
    from repro.core import plan
    spec = _catalog_spec()
    solo = spec.with_availability({"H100": 8})
    p = plan(solo, strategy="disagg", tol=2.0)
    assert p.solver_info.get("disagg_fallback") == 1.0
    assert all(c.role == "both" for c in p.replicas)
    with pytest.raises(ValueError):
        plan(spec.with_objective("cost", slo_makespan=1e4),
             strategy="disagg")


# --------------------------------- integration: disaggregated cost serving

def test_disagg_cost_end_to_end_handoff_accounting():
    cfgs = [_replica(role="prefill"), _replica(role="decode")]
    trace = _trace(n=4)
    res, runtime, executor = _run_cost(cfgs, trace)
    pre, dec = runtime.replicas
    assert res.num_completed == 4 and res.num_failed == 0
    # every request prefilled on the prefill replica, decoded on the
    # decode replica after exactly one KV handoff
    assert pre.handoffs == 4 and dec.handoffs == 0
    assert all(r.handoffs == 1 for r in res.records)
    assert [rid for rid, dst, _ in pre.handoff_log] == [0, 1, 2, 3]
    assert all(dst == dec.index for _, dst, _ in pre.handoff_log)
    assert all(blocks > 0 for _, _, blocks in pre.handoff_log)
    # the payload landed in the target's host tier and readmitted through
    # the ordinary swap-in path
    assert res.info["handoff_delivered"] == 4.0
    assert res.info["handoff_degraded"] == 0.0
    assert res.info["handoffs"] == 4.0
    assert res.info["handoff_bytes"] == pre.handoff_blocks * BLOCK_BYTES
    assert res.info["handoff_log"][pre.index] == list(pre.handoff_log)
    by_rep = {e["replica"]: e for e in res.info["per_replica"]}
    assert by_rep[pre.index]["role"] == "prefill"
    assert by_rep[dec.index]["role"] == "decode"
    assert by_rep[pre.index]["handoffs"] == 4
    # the source holds no blocks at the end; the decode side swapped in
    assert executor.kv_manager(pre.index).used_blocks == 0
    dmgr = executor.kv_manager(dec.index)
    assert dmgr.swap_ins == 4 and dmgr.used_blocks == 0
    assert dmgr.host_used_blocks == 0
    # decode-side admission cohorts are swap-in readmissions of the
    # handed-off requests
    assert sorted(rid for g in dec.admission_log for rid in g) == [0, 1, 2, 3]


def test_disagg_backpressure_parks_then_drains():
    # decode host tier holds one 2-block payload at a time: concurrent
    # handoffs must park in the transfer queue and drain as capacity frees
    cfgs = [_replica(role="prefill"), _replica(role="decode")]
    trace = _trace(n=4)
    res, runtime, _ = _run_cost(cfgs, trace, host_blocks=2)
    assert res.num_completed == 4 and res.num_failed == 0
    assert res.info["handoff_delivered"] == 4.0
    assert res.info["handoff_degraded"] == 0.0
    assert res.info["handoff_parked_total"] > 0
    assert res.info["handoff_queue_peak"] >= 1.0
    assert res.info.get("handoffs_stranded", 0.0) == 0.0
    assert runtime._handoffs is not None and not runtime._handoffs.queue


def test_disagg_unfittable_payload_degrades_to_recompute():
    # a 2-block payload can never fit a 1-block decode host tier: the
    # request still migrates, by recompute (zero-block handoff)
    cfgs = [_replica(role="prefill"), _replica(role="decode")]
    trace = _trace(n=3)
    res, runtime, executor = _run_cost(cfgs, trace, host_blocks=1)
    pre, dec = runtime.replicas
    assert res.num_completed == 3 and res.num_failed == 0
    assert res.info["handoff_delivered"] == 0.0
    assert res.info["handoff_degraded"] == 3.0
    assert all(blocks == 0 for _, _, blocks in pre.handoff_log)
    assert executor.kv_manager(dec.index).swap_ins == 0
    # degraded migration re-prefills on the decode target
    assert sorted(rid for g in dec.admission_log for rid in g) == [0, 1, 2]


def test_disagg_admission_throttles_while_stalled():
    """While a prefill replica has staged or parked handoffs, it plans no
    new admissions (backpressure): prefill capacity must not outrun the
    decode pool without bound."""
    from repro.runtime.lifecycle import RequestState
    cfgs = [_replica(role="prefill"), _replica(role="decode")]
    executor = CostModelExecutor(list(cfgs), [TINY], host_blocks=16)
    runtime = ServingRuntime(_plan(cfgs, 2), executor)
    pre = runtime.replicas[0]

    def fresh(rid):
        return RequestState(req=Request(req_id=rid, workload=0, input_len=30,
                                        output_len=4, arrival=0.0))

    # a transfer parked from this source replica throttles admission
    pre.enqueue(fresh(9))
    runtime._handoffs.queue.append(
        _Handoff(state=None, src=pre, blocks=1, dst=None))
    assert pre._plan_admission_event(math.inf) is None
    runtime._handoffs.queue.drain()
    # so does a staged-but-unplanned handoff on the replica itself
    pre.handoff_ready.append(fresh(8))
    assert pre._plan_admission_event(math.inf) is None
    pre.handoff_ready.clear()
    # unthrottled: the queued request admits (planning consumes the queue)
    assert pre._plan_admission_event(math.inf) is not None
    assert not pre.queue


def test_both_role_plan_degenerates_to_colocated():
    """A plan whose replicas are all role="both" wires no handoff manager
    and reproduces exactly the legacy colocated behavior."""
    trace = _trace(n=4)
    cfgs = [_replica(), _replica()]
    res, runtime, _ = _run_cost(cfgs, trace)
    assert runtime._handoffs is None
    assert res.num_completed == 4
    assert "handoffs" not in res.info
    assert "handoff_delivered" not in res.info
    assert all("role" not in c.key for c in runtime.plan.replicas)
    assert all(r.handoffs == 0 and not r.handoff_ready
               for r in runtime.replicas)
    assert all(e["role"] == "both" for e in res.info["per_replica"])


# --------------------------- acceptance: backend-identical handoff logs

def _run_engine(cfgs, trace, *, host_blocks=16, num_blocks=12, **ex_kw):
    from repro.configs import get_config
    from repro.obs import TickClock
    from repro.runtime import EngineExecutor
    plan = _plan(cfgs, trace.num_requests)
    executor = EngineExecutor(plan, [get_config("llama3-8b").reduced()],
                              models=[TINY], max_batch=8, input_len=8,
                              max_new=5, fused_steps=1,
                              host_blocks=host_blocks, clock=TickClock(),
                              **ex_kw)
    runtime = ServingRuntime(plan, executor)
    res = runtime.run(trace)
    return res, runtime, executor


def test_disagg_backend_identical_handoff_and_admission_logs():
    """Acceptance: the cost-model and engine backends plan, gate, and
    commit the same handoffs — per-replica admission logs and handoff
    logs are identical."""
    pytest.importorskip("jax")
    cfgs = [_replica(role="prefill"), _replica(role="decode")]
    trace = _trace(n=3)
    cost_res, cost_rt, _ = _run_cost(cfgs, trace)
    eng_res, eng_rt, _ = _run_engine(cfgs, trace)
    assert cost_res.num_completed == eng_res.num_completed == 3
    for cr, er in zip(cost_rt.replicas, eng_rt.replicas):
        assert cr.admission_log == er.admission_log
        assert cr.handoff_log == er.handoff_log
    assert cost_res.info["handoffs"] == eng_res.info["handoffs"] == 3.0
    assert (cost_res.info["handoff_delivered"]
            == eng_res.info["handoff_delivered"] == 3.0)


def test_disagg_streams_byte_identical_to_colocated_engine():
    """Acceptance: a disaggregated run's token streams equal the
    colocated run's exactly — the handed-off KV resumes decode on the
    decode replica with no re-prefill and no token drift."""
    pytest.importorskip("jax")
    trace = _trace(n=3)
    colo_res, _, colo_ex = _run_engine([_replica()], trace)
    dis_res, dis_rt, dis_ex = _run_engine(
        [_replica(role="prefill"), _replica(role="decode")], trace)
    assert colo_res.num_completed == dis_res.num_completed == 3
    assert dis_res.info["handoff_delivered"] == 3.0
    assert dis_res.info["handoff_degraded"] == 0.0
    assert set(dis_ex.token_log) == set(colo_ex.token_log)
    for rid, colo_log in colo_ex.token_log.items():
        assert list(dis_ex.token_log[rid]) == list(colo_log)
    # the physical pools are clean on both sides
    for rep in (0, 1):
        paged = dis_ex._paged[rep]
        assert paged.allocator.used_blocks == 0


# ------------------------------------ satellite: host-RAM-derived sizing

def test_host_ram_block_sizing_helpers():
    assert host_ram_blocks(0.0, TINY, BS) == 0
    assert host_ram_blocks(-10.0, TINY, BS) == 0
    assert host_ram_blocks(7 * BLOCK_BYTES, TINY, BS) == 7
    cfg = _replica()
    assert host_blocks_for(cfg, TINY, None, BS, default=5) == 5
    assert host_blocks_for(cfg, TINY, 7 * BLOCK_BYTES, BS) == 7
    ram_cfg = _replica(host_ram_bytes=3 * BLOCK_BYTES)
    assert host_blocks_for(ram_cfg, TINY, "auto", BS) == 3


def test_executor_host_tier_sized_from_ram_budget():
    cfg = _replica(host_ram_bytes=6 * BLOCK_BYTES)
    executor = CostModelExecutor([cfg], [TINY], host_blocks=2,
                                 host_ram_bytes="auto")
    assert executor.kv_manager(0).host_blocks == 6
    explicit = CostModelExecutor([cfg], [TINY], host_blocks=2,
                                 host_ram_bytes=9 * BLOCK_BYTES)
    assert explicit.kv_manager(0).host_blocks == 9
    fallback = CostModelExecutor([cfg], [TINY], host_blocks=2)
    assert fallback.kv_manager(0).host_blocks == 2


def test_spec_host_ram_validated_and_catalog_defaults():
    spec = _catalog_spec()
    assert spec.host_ram_bytes is None
    auto = spec.with_host_ram("auto")
    assert auto.host_ram_bytes == "auto"
    sized = spec.with_host_ram(64 * 1024**3)
    assert sized.host_ram_bytes == float(64 * 1024**3)
    with pytest.raises(ValueError):
        spec.with_host_ram("lots")
    with pytest.raises(ValueError):
        spec.with_host_ram(-1.0)
    # catalog carries per-device host RAM + handoff interconnect defaults
    for dev in GPU_CATALOG.values():
        assert dev.host_ram_bytes > 0
        assert dev.interconnect_bw > 0


# --------------------------- satellite: measured-hit-rate replan feedback

def test_watcher_feeds_measured_hit_rates_into_replan():
    from repro.runtime import AvailabilityWatcher
    spec = _catalog_spec()
    seen = []

    def planner(s):
        seen.append(s)
        return _plan([_replica()], 1)

    old = _plan([_replica()], 1)
    off = AvailabilityWatcher(spec, planner=planner)
    off.replan(old, hit_rates={0: 0.5})
    assert seen[-1].prefix_hit_rates is None        # default: ignored
    on = AvailabilityWatcher(spec, planner=planner, hit_rate_feedback=True)
    on.replan(old, hit_rates={0: 0.5})
    assert seen[-1].prefix_hit_rates == {0: 0.5}
    on.replan(old, hit_rates=None)                  # no measurement yet
    assert seen[-1].prefix_hit_rates is None


def test_runtime_measures_prefix_hit_rates_for_feedback():
    from repro.core.workloads import make_shared_prefix_trace
    cfg = _replica(num_blocks=50)
    trace = make_shared_prefix_trace("sp", 6, input_len=48, output_len=4,
                                     prefix_pool_size=1, prefix_len=32,
                                     hit_ratio=1.0, arrival_rate=None,
                                     seed=2)
    executor = CostModelExecutor([cfg], [TINY], prefix_cache=True)
    runtime = ServingRuntime(_plan([cfg], trace.num_requests), executor)
    res = runtime.run(trace)
    assert res.info["prefix_hit_rate"] > 0
    rates = runtime._measured_hit_rates()
    assert rates is not None
    assert set(rates) == set(range(len(WORKLOAD_TYPES)))
    assert all(0.0 < v <= 1.0 for v in rates.values())
    assert math.isclose(rates[0], res.info["prefix_hit_rate"])
    # cold executor: nothing measured, nothing fed back
    cold = CostModelExecutor([cfg], [TINY])
    cold_rt = ServingRuntime(_plan([cfg], trace.num_requests), cold)
    cold_rt.run(trace)
    assert cold_rt._measured_hit_rates() is None


# ------------------------------------ trace tooling: handoff/role columns

def _load_summarizer():
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "tools"))
    import trace_summarize
    return trace_summarize


def test_trace_summarize_handoff_columns():
    ts = _load_summarizer()
    doc = {"traceEvents": [
        {"ph": "M", "name": "thread_name", "tid": 0,
         "args": {"name": "replica-0 (tiny:H100x1|prefill)"}},
        {"ph": "M", "name": "thread_name", "tid": 1,
         "args": {"name": "replica-1 (tiny:A40x1|decode)"}},
        {"ph": "X", "tid": 0, "ts": 0.0, "dur": 2e6, "cat": "prefill",
         "name": "prefill[2]"},
        {"ph": "X", "tid": 0, "ts": 2e6, "dur": 1e6, "cat": "handoff",
         "name": "handoff[B=2]",
         "args": {"req_ids": [0, 1], "blocks": 4, "bytes": 8192.0}},
        {"ph": "X", "tid": 1, "ts": 3e6, "dur": 1e6, "cat": "swapin",
         "name": "swapin[B=2]", "args": {"bytes": 8192.0}},
    ]}
    s = ts.summarize(doc)
    pre, dec = s["replicas"]
    assert pre["role"] == "prefill" and dec["role"] == "decode"
    assert pre["handoffs"] == 2 and pre["handoff_s"] == 1.0
    assert pre["handoff_blocks"] == 4 and pre["handoff_bytes"] == 8192.0
    assert dec["handoffs"] == 0 and dec["swap_ins"] == 1
    text = ts.format_summary(s)
    assert "role" in text and "handoff" in text and "hnd-MB" in text
    assert "prefill" in text and "decode" in text


def test_trace_summary_cross_checks_runtime_info(tmp_path):
    from repro.obs import Observability
    ts = _load_summarizer()
    cfgs = [_replica(role="prefill"), _replica(role="decode")]
    trace = _trace(n=4)
    executor = CostModelExecutor(list(cfgs), [TINY], host_blocks=16)
    runtime = ServingRuntime(_plan(cfgs, trace.num_requests), executor,
                             obs=Observability())
    res = runtime.run(trace)
    path = tmp_path / "disagg_trace.json"
    runtime.export_trace(str(path))
    s = ts.summarize(json.loads(path.read_text()))
    by_rep = {e["replica"]: e for e in res.info["per_replica"]}
    summarized = {i: r for i, r in enumerate(s["replicas"])}
    for i, entry in by_rep.items():
        assert summarized[i]["role"] == entry["role"]
        assert summarized[i]["handoffs"] == entry["handoffs"]
        assert summarized[i]["handoff_bytes"] == entry["handoff_bytes"]
    assert sum(r["handoffs"] for r in s["replicas"]) == res.info["handoffs"]
