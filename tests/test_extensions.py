"""Beyond-paper scheduler extensions: SLO-constrained min-cost plans,
availability-drop replanning, and the profiled-throughput interface."""
import numpy as np
import pytest

from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_70B,
                        make_trace, simulate, solve)
from repro.core.costmodel import ProfiledThroughput, config_throughput
from repro.core.scheduler import replan, solve_min_cost
from repro.core.workloads import WORKLOAD_TYPES


@pytest.fixture(scope="module")
def trace():
    return make_trace("trace1", num_requests=400, seed=0)


def test_min_cost_under_slo(trace):
    avail = AVAILABILITY_SNAPSHOTS["avail1"]
    fast = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, 60.0, tol=1.0)
    # an SLO 1.5x looser than the best achievable must cost no more
    slo = fast.makespan * 1.5
    cheap = solve_min_cost([LLAMA3_70B], trace, GPU_CATALOG, avail, 60.0, slo)
    assert cheap.makespan <= slo * 1.01
    assert cheap.cost <= fast.cost + 1e-6
    # a very loose SLO should be much cheaper than the full budget
    loose = solve_min_cost([LLAMA3_70B], trace, GPU_CATALOG, avail, 60.0,
                           slo * 4)
    assert loose.cost <= cheap.cost + 1e-6


def test_min_cost_infeasible_slo_raises(trace):
    avail = {"A40": 4}
    with pytest.raises(RuntimeError):
        solve_min_cost([LLAMA3_70B], trace, GPU_CATALOG, avail, 10.0,
                       slo_makespan=0.5)


def test_replan_on_availability_drop(trace):
    avail = dict(AVAILABILITY_SNAPSHOTS["avail1"])
    plan = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, 30.0, tol=1.0)
    # the H100 pool evaporates (spot reclaim)
    dropped = dict(avail, H100=0)
    new_plan = replan(plan, [LLAMA3_70B], trace, GPU_CATALOG, dropped, 30.0,
                      tol=1.0)
    assert new_plan.composition().get("H100", 0) == 0
    assert new_plan.cost <= 30.0 + 1e-6
    # the new plan still serves everything
    np.testing.assert_allclose(new_plan.assignment.sum(axis=0), 1.0,
                               atol=1e-6)
    sim = simulate(new_plan, trace, [LLAMA3_70B])
    assert len(sim.latencies) == trace.num_requests


def test_profiled_throughput_drop_in(trace):
    """The paper's one-time-profiling interface: a measured h-table drives
    the same solver and reproduces the analytical plan when the table IS the
    analytical model."""
    avail = AVAILABILITY_SNAPSHOTS["avail1"]
    analytic = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, 30.0, tol=1.0)

    table = {}
    def profiling_fn(cfg, w):
        key = (cfg.key, WORKLOAD_TYPES.index(w))
        table[key] = config_throughput(cfg.stages, cfg.model, w)
        return table[key]
    profiled = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, 30.0, tol=1.0,
                     throughput_fn=profiling_fn)
    assert abs(profiled.makespan - analytic.makespan) <= \
        0.05 * analytic.makespan + 1.0
    # the captured table can be replayed through ProfiledThroughput
    pt = ProfiledThroughput(table)
    some_key = next(iter(table))
    assert pt(*some_key) == table[some_key]
