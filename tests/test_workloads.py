"""Workload/trace substrate tests (Fig 1 categorization, Table 4 mixes)."""
import numpy as np
import pytest

from repro.core.workloads import (INPUT_LENGTHS, OUTPUT_LENGTHS, TRACE_MIXES,
                                  WORKLOAD_TYPES, WorkloadType, make_trace,
                                  workload_demand)


def test_nine_workload_types_grid():
    assert len(WORKLOAD_TYPES) == 9
    assert {w.input_len for w in WORKLOAD_TYPES} == set(INPUT_LENGTHS)
    assert {w.output_len for w in WORKLOAD_TYPES} == set(OUTPUT_LENGTHS)


def test_fig1_categorization():
    assert WorkloadType(2455, 510).kind == "long_input_long_output"
    assert WorkloadType(2455, 18).kind == "long_input_short_output"
    assert WorkloadType(496, 510).kind == "short_input_long_output"
    assert WorkloadType(496, 18).kind == "short_input_short_output"


def test_table4_mixes_sum_to_100():
    for name, mix in TRACE_MIXES.items():
        assert len(mix) == 9, name
        assert sum(mix) == 100, name


def test_trace_mixture_statistics():
    trace = make_trace("trace3", num_requests=5000, seed=0)
    counts = trace.counts_by_type()
    expected = np.array(TRACE_MIXES["trace3"]) / 100 * 5000
    # multinomial: within 5 sigma
    sigma = np.sqrt(expected * (1 - expected / 5000) + 1e-9)
    assert np.all(np.abs(counts - expected) < 5 * sigma + 5)


def test_poisson_arrival_rate():
    trace = make_trace("trace1", num_requests=2000, arrival_rate=4.0, seed=1)
    arrivals = np.array([r.arrival for r in trace.requests])
    assert np.all(np.diff(arrivals) >= 0)
    rate = 2000 / arrivals.max()
    assert 3.5 < rate < 4.5


def test_multimodel_demand_matrix():
    trace = make_trace("trace1", num_requests=1000, model_mix=(0.75, 0.25),
                       seed=2)
    lam = workload_demand(trace, num_models=2)
    assert lam.shape == (2, 9)
    assert lam.sum() == 1000
    assert 0.68 < lam[0].sum() / 1000 < 0.82
