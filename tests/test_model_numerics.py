"""Algorithm-equivalence tests: every parallel/chunked formulation must match
its sequential oracle (hypothesis-swept over shapes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import xlstm as X
from repro.models import transformer as T
from repro.models.config import (ATTN, MAMBA, MLP, MLSTM, MOE as FFN_MOE,
                                 NONE, SLSTM, ArchConfig, LayerDesc)


def _mamba_cfg(d=32, di_expand=2, ds=8):
    return ArchConfig(name="m", arch_type="ssm", n_layers=1, d_model=d,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=0,
                      vocab_size=64, period=(LayerDesc(MAMBA, NONE),),
                      ssm_state_dim=ds, ssm_expand=di_expand)


def _mamba_params(cfg, key):
    return jax.tree.map(lambda x: x[0],
                        T._init_mixer(cfg, LayerDesc(MAMBA, NONE), key, 1))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([7, 64, 130, 300]),
       st.integers(0, 1000))
def test_mamba_chunked_matches_sequential(b, s, seed):
    cfg = _mamba_cfg()
    key = jax.random.PRNGKey(seed)
    p = _mamba_params(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y_chunk, _ = M.mamba_prefill(cfg, p, x)
    y_ref = M.mamba_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=0.05, atol=0.02)


def test_mamba_step_matches_prefill():
    """Streaming the sequence token-by-token == one-shot prefill."""
    cfg = _mamba_cfg()
    key = jax.random.PRNGKey(7)
    p = _mamba_params(cfg, key)
    b, s = 2, 24
    x = (jax.random.normal(key, (b, s, cfg.d_model)) * 0.5).astype(jnp.bfloat16)
    y_all, _ = M.mamba_prefill(cfg, p, x)
    state = {"conv": jnp.zeros((b, cfg.ssm_conv_width - 1, cfg.d_inner),
                               jnp.bfloat16),
             "h": jnp.zeros((b, cfg.d_inner, cfg.ssm_state_dim), jnp.float32)}
    outs = []
    for t in range(s):
        y, state = M.mamba_step(cfg, p, x[:, t:t + 1], state)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq, np.float32),
                               np.asarray(y_all, np.float32),
                               rtol=0.05, atol=0.02)


def _xlstm_cfg(d=32, nh=2):
    return ArchConfig(name="x", arch_type="ssm", n_layers=1, d_model=d,
                      n_heads=nh, n_kv_heads=nh, head_dim=d // nh, d_ff=0,
                      vocab_size=64, period=(LayerDesc(MLSTM, NONE),))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([5, 64, 129, 200]),
       st.integers(0, 1000))
def test_mlstm_chunkwise_matches_sequential(b, s, seed):
    cfg = _xlstm_cfg()
    key = jax.random.PRNGKey(seed)
    p = jax.tree.map(lambda x: x[0],
                     T._init_mixer(cfg, LayerDesc(MLSTM, NONE), key, 1))
    x = (jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2 * cfg.d_model))
         * 0.5).astype(jnp.bfloat16)
    y_chunk, st_chunk = X.mlstm_chunkwise(cfg, p, x, chunk=32)
    y_seq, st_seq = X.mlstm_seq(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=0.05, atol=0.03)
    # final states agree too (decode can resume from either)
    np.testing.assert_allclose(np.asarray(st_chunk["n"]), np.asarray(st_seq["n"]),
                               rtol=0.05, atol=0.03)


def test_mlstm_block_prefill_then_decode_continuity():
    cfg = _xlstm_cfg()
    key = jax.random.PRNGKey(3)
    p = jax.tree.map(lambda x: x[0],
                     T._init_mixer(cfg, LayerDesc(MLSTM, NONE), key, 1))
    b, s = 2, 40
    x = (jax.random.normal(key, (b, s, cfg.d_model)) * 0.5).astype(jnp.bfloat16)
    y_full, _ = X.mlstm_block(cfg, p, x)
    y_pre, state = X.mlstm_block(cfg, p, x[:, :s - 4])
    ys = [y_pre]
    for t in range(s - 4, s):
        y, state = X.mlstm_block(cfg, p, x[:, t:t + 1], state=state)
        ys.append(y)
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=0.06, atol=0.04)


def _moe_cfg(e=4, k=2, d=32, ff=48):
    return ArchConfig(name="moe", arch_type="moe", n_layers=1, d_model=d,
                      n_heads=2, n_kv_heads=2, head_dim=16, d_ff=ff,
                      vocab_size=64, period=(LayerDesc(ATTN, FFN_MOE),),
                      n_experts=e, n_experts_active=k, moe_d_ff=ff)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([4, 17, 64]),
       st.sampled_from([(4, 2), (8, 2), (4, 1), (8, 4)]),
       st.integers(0, 10_000))
def test_moe_pack_matches_dense_ref(b, s, ek, seed):
    """With no-drop capacity the packed implementation equals the dense
    every-expert oracle exactly."""
    e, k = ek
    cfg = _moe_cfg(e=e, k=k)
    key = jax.random.PRNGKey(seed)
    p = jax.tree.map(lambda x: x[0],
                     T._init_ffn(cfg, LayerDesc(ATTN, FFN_MOE), key, 1))
    x = (jax.random.normal(jax.random.fold_in(key, 1), (b, s, cfg.d_model))
         * 0.5).astype(jnp.bfloat16)
    y_pack = MOE.moe_block(cfg, p, x, capacity_factor=float(e) / k)
    y_ref = MOE.moe_block_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_pack, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=0.05, atol=0.02)


def test_moe_capacity_drops_bounded():
    """With tight capacity, output differs only on dropped tokens and stays
    finite; load-balance loss is finite and ≥ 1 (its minimum at uniform)."""
    cfg = _moe_cfg(e=4, k=2)
    key = jax.random.PRNGKey(0)
    p = jax.tree.map(lambda x: x[0],
                     T._init_ffn(cfg, LayerDesc(ATTN, FFN_MOE), key, 1))
    x = (jax.random.normal(key, (2, 32, cfg.d_model)) * 0.5).astype(jnp.bfloat16)
    y = MOE.moe_block(cfg, p, x, capacity_factor=0.5)
    assert bool(jnp.all(jnp.isfinite(y)))
    aux = MOE.aux_load_balance_loss(cfg, p["router"], x)
    assert float(aux) >= 0.99


def test_moe_ep_matches_single_device():
    """Expert-parallel shard_map path == single-shard path (4 host devices)."""
    import os
    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices (run in dryrun env)")


def test_slstm_decode_continuity():
    cfg = ArchConfig(name="s", arch_type="ssm", n_layers=1, d_model=32,
                     n_heads=2, n_kv_heads=2, head_dim=16, d_ff=0,
                     vocab_size=64, period=(LayerDesc(SLSTM, NONE),))
    key = jax.random.PRNGKey(5)
    p = jax.tree.map(lambda x: x[0],
                     T._init_mixer(cfg, LayerDesc(SLSTM, NONE), key, 1))
    b, s = 2, 20
    x = (jax.random.normal(key, (b, s, cfg.d_model)) * 0.5).astype(jnp.bfloat16)
    y_full, _ = X.slstm_block(cfg, p, x)
    y_pre, state = X.slstm_block(cfg, p, x[:, :s - 3])
    ys = [y_pre]
    for t in range(s - 3, s):
        y, state = X.slstm_block(cfg, p, x[:, t:t + 1], state=state)
        ys.append(y)
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=0.06, atol=0.04)


def test_moe_virtual_expert_shards_match_baseline():
    """Virtual ff-slice experts (moe_expert_shards=2) == real experts when
    the virtual weights are the real weights' ff-slices."""
    import dataclasses
    cfg = _moe_cfg(e=4, k=2, d=32, ff=48)
    cfg_v = dataclasses.replace(cfg, moe_expert_shards=2)
    key = jax.random.PRNGKey(11)
    p = jax.tree.map(lambda x: x[0],
                     T._init_ffn(cfg, LayerDesc(ATTN, FFN_MOE), key, 1))
    s, ffv = 2, 48 // 2
    def split_gate(w):  # (E, d, ff) -> (E*s, d, ff/s)
        e, d, ff = w.shape
        return w.reshape(e, d, s, ffv).transpose(0, 2, 1, 3).reshape(e * s, d, ffv)
    def split_down(w):  # (E, ff, d) -> (E*s, ff/s, d)
        e, ff, d = w.shape
        return w.reshape(e, s, ffv, d).reshape(e * s, ffv, d)
    p_v = {"router": p["router"], "w_gate": split_gate(p["w_gate"]),
           "w_up": split_gate(p["w_up"]), "w_down": split_down(p["w_down"])}
    x = (jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
         * 0.5).astype(jnp.bfloat16)
    y_base = MOE.moe_block(cfg, p, x, capacity_factor=2.0)
    y_virt = MOE.moe_block(cfg_v, p_v, x, capacity_factor=2.0)
    np.testing.assert_allclose(np.asarray(y_virt, np.float32),
                               np.asarray(y_base, np.float32),
                               rtol=0.05, atol=0.02)
