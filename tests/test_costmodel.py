"""Cost-model tests: the paper's Observations 1-3 must emerge from it."""
import numpy as np
import pytest

from repro.core.catalog import GPU_CATALOG
from repro.core.costmodel import (LLAMA3_8B, LLAMA3_70B, ModelProfile, Stage,
                                  config_throughput, max_batch_size)
from repro.core.workloads import WorkloadType

COMPUTE_HEAVY = WorkloadType(2455, 18)   # long input, short output
MEMORY_HEAVY = WorkloadType(496, 510)    # short input, long output


def _single(dev_name: str, tp: int, model=LLAMA3_70B):
    dev = GPU_CATALOG[dev_name]
    return (Stage(dev, tp, 1.0),)


def _per_dollar(dev_name: str, tp: int, workload, model=LLAMA3_70B):
    stages = _single(dev_name, tp, model)
    h = config_throughput(stages, model, workload)
    cost = sum(s.price for s in stages)
    return h / cost


def test_throughput_positive_when_memory_fits():
    h = config_throughput(_single("H100", 4), LLAMA3_70B, COMPUTE_HEAVY)
    assert h > 0


def test_zero_throughput_when_model_does_not_fit():
    # 70B bf16 needs ~140GB; one 24GB 4090 can't hold it.
    h = config_throughput(_single("4090", 1), LLAMA3_70B, MEMORY_HEAVY)
    assert h == 0.0


def test_observation1_datacenter_wins_compute_heavy():
    """H100 best per-dollar on compute-intensive (long-in short-out) 70B."""
    h100 = _per_dollar("H100", 4, COMPUTE_HEAVY)
    a6000 = _per_dollar("A6000", 4, COMPUTE_HEAVY)
    assert h100 > a6000


def test_observation1_workstation_wins_memory_heavy():
    """Workstation GPUs (A40) beat data-center per-dollar on memory-bound."""
    a40 = _per_dollar("A40", 4, MEMORY_HEAVY)
    a100 = _per_dollar("A100", 4, MEMORY_HEAVY)
    assert a40 > a100


def test_observation1_consumer_wins_small_model():
    """4090 best per-dollar for Llama3-8B (fits one GPU, best bw/$)."""
    w = MEMORY_HEAVY
    r4090 = _per_dollar("4090", 1, w, LLAMA3_8B)
    h100 = _per_dollar("H100", 1, w, LLAMA3_8B)
    a100 = _per_dollar("A100", 1, w, LLAMA3_8B)
    assert r4090 > h100 and r4090 > a100


def test_observation2_dp_beats_tp_for_small_model():
    """8B: two TP=1 replicas outperform one TP=2 replica (DP wins)."""
    w = MEMORY_HEAVY
    one_tp2 = config_throughput(_single("A6000", 2, LLAMA3_8B), LLAMA3_8B, w)
    two_tp1 = 2 * config_throughput(_single("A6000", 1, LLAMA3_8B), LLAMA3_8B, w)
    assert two_tp1 > one_tp2


def test_tp_scaling_sublinear_but_positive():
    w = COMPUTE_HEAVY
    h4 = config_throughput(_single("H100", 4), LLAMA3_70B, w)
    h8 = config_throughput(_single("H100", 8), LLAMA3_70B, w)
    assert h8 > h4            # more compute helps
    assert h8 < 2.5 * h4      # but not superlinear


def test_pp_inter_machine_penalty():
    """PP over Ethernet is slower than TP over NVLink at equal device count."""
    dev = GPU_CATALOG["H100"]
    tp4 = (Stage(dev, 4, 1.0),)
    pp4 = tuple(Stage(dev, 1, 0.25) for _ in range(4))
    h_tp = config_throughput(tp4, LLAMA3_70B, COMPUTE_HEAVY)
    h_pp = config_throughput(pp4, LLAMA3_70B, COMPUTE_HEAVY)
    assert h_tp > h_pp


def test_max_batch_respects_memory():
    # 2×A6000 (96GB) doesn't fit 70B weights (141GB) -> 0.
    assert max_batch_size(_single("A6000", 2), LLAMA3_70B, MEMORY_HEAVY) == 0
    # 2xH100 (160GiB) fits but is capacity-starved vs 8xH100.
    b_small = max_batch_size(_single("H100", 2), LLAMA3_70B,
                             WorkloadType(2455, 510))
    b_big = max_batch_size(_single("H100", 8), LLAMA3_70B, MEMORY_HEAVY)
    assert 0 < b_small < b_big <= 64


def test_sliding_window_bounds_kv():
    dense = ModelProfile(name="d", n_layers=32, d_model=4096, n_kv_heads=8,
                         head_dim=128, params_total=8e9, params_active=8e9)
    swa = ModelProfile(name="s", n_layers=32, d_model=4096, n_kv_heads=8,
                       head_dim=128, params_total=8e9, params_active=8e9,
                       window=4096)
    long_w = WorkloadType(30000, 500)
    stages = _single("A100", 1, dense)
    assert config_throughput(stages, swa, long_w) > config_throughput(stages, dense, long_w)


def test_moe_active_params_speed_up_decode():
    dense = ModelProfile(name="dense", n_layers=56, d_model=6144, n_kv_heads=8,
                         head_dim=128, params_total=141e9, params_active=141e9)
    moe = ModelProfile(name="moe", n_layers=56, d_model=6144, n_kv_heads=8,
                       head_dim=128, params_total=141e9, params_active=39e9)
    stages = tuple(Stage(GPU_CATALOG["H100"], 8, 0.5) for _ in range(2))
    assert config_throughput(stages, moe, MEMORY_HEAVY) > \
        config_throughput(stages, dense, MEMORY_HEAVY)
