"""Utilization-driven autoscaler tests: ScalePolicy decision logic (unit)
and the runtime's online scale-up/drain loop on both backends (the
cost-model integration is deterministic; the engine acceptance run shows a
bursty trace triggering >= 1 online replan that improves goodput)."""
import numpy as np
import pytest

from repro.core import costmodel
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage
from repro.core.plan import Config, ServingPlan
from repro.core.scheduler import (ReplicaSnapshot, ScalePolicy, scaled_plan)
from repro.core.workloads import Request, Trace
from repro.runtime import CostModelExecutor, ServingRuntime, SLO

TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)


def _replica(num_blocks: int = 64, *, speed: float = 1.0,
             price: float = 1.0) -> Config:
    """One-device replica holding ``num_blocks`` 16-token KV blocks."""
    block_bytes = 16 * TINY.kv_bytes_per_token
    free = (num_blocks + 0.5) * block_bytes
    mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
           / costmodel.MEMORY_UTIL)
    dev = DeviceType("scale-test", 1e12 * speed, 1e9 * speed, mem, price,
                     8, 1e11, 1e9, "x")
    return Config(stages=(Stage(dev, 1, 1.0),), model_index=0, model=TINY)


def _plan(configs, n_requests: float) -> ServingPlan:
    R = len(configs)
    return ServingPlan(replicas=list(configs),
                       assignment=np.full((R, 1), 1.0 / R),
                       demands=[(0, 0, float(n_requests))], makespan=1.0,
                       cost=sum(c.cost for c in configs))


def _snap(i, cfg, queue=0, active=0, kv=0.0, draining=False):
    return ReplicaSnapshot(index=i, config=cfg, queue_len=queue,
                           active=active, kv_used_frac=kv,
                           draining=draining)


# ------------------------------------------------------------- policy unit

def test_policy_adds_on_sustained_queue_pressure():
    cfg = _replica()
    plan = _plan([cfg], 10)
    policy = ScalePolicy([cfg], budget=3 * cfg.cost, window=2, cooldown=0,
                         queue_high=4.0)
    assert policy.update(0.1, [_snap(0, cfg, queue=9)], plan) is None  # window
    d = policy.update(0.2, [_snap(0, cfg, queue=9)], plan)
    assert d is not None and d.action == "add"
    assert d.config_key == cfg.key
    assert len(d.plan.replicas) == 2
    # the emitted plan's assignment is a valid router input
    np.testing.assert_allclose(d.plan.assignment.sum(axis=0), 1.0)


def test_policy_respects_budget():
    cfg = _replica()
    plan = _plan([cfg], 10)
    policy = ScalePolicy([cfg], budget=1.5 * cfg.cost, window=1, cooldown=0)
    assert policy.update(0.1, [_snap(0, cfg, queue=50)], plan) is None


def test_policy_never_adds_a_candidate_that_cannot_serve_demand():
    """A candidate for a model with no demand has zero value: renting it
    cannot relieve the backlog, so the policy must not spend on it."""
    cfg = _replica()
    other_model = Config(stages=cfg.stages, model_index=1, model=TINY)
    plan = _plan([cfg], 10)                  # all demand is model 0
    policy = ScalePolicy([other_model], budget=10 * cfg.cost, window=1,
                         cooldown=0)
    assert policy.update(0.1, [_snap(0, cfg, queue=50)], plan) is None


def test_policy_adds_on_kv_watermark():
    cfg = _replica()
    plan = _plan([cfg], 10)
    policy = ScalePolicy([cfg], budget=4 * cfg.cost, window=1, cooldown=0,
                         kv_high=0.9)
    d = policy.update(0.1, [_snap(0, cfg, queue=0, active=3, kv=0.95)], plan)
    assert d is not None and d.action == "add"


def test_policy_cooldown_suppresses_back_to_back_actions():
    cfg = _replica()
    plan = _plan([cfg], 10)
    policy = ScalePolicy([cfg], budget=9 * cfg.cost, window=1, cooldown=2)
    assert policy.update(0.1, [_snap(0, cfg, queue=9)], plan) is not None
    # window cleared + 2 cooldown ticks: next two observations are absorbed
    assert policy.update(0.2, [_snap(0, cfg, queue=9)], plan) is None
    assert policy.update(0.3, [_snap(0, cfg, queue=9)], plan) is None
    assert policy.update(0.4, [_snap(0, cfg, queue=9)], plan) is not None


def test_policy_drains_idle_replica_but_keeps_minimum():
    a, b = _replica(), _replica()
    plan = _plan([a, b], 10)
    policy = ScalePolicy([a], budget=4 * a.cost, window=1, cooldown=0,
                         min_replicas=1)
    d = policy.update(1.0, [_snap(0, a), _snap(1, b)], plan)
    assert d is not None and d.action == "drain"
    assert len(d.plan.replicas) == 1
    # at min_replicas, an idle pool must NOT drain further
    policy.reset()
    assert policy.update(2.0, [_snap(0, a)], _plan([a], 10)) is None


def test_policy_drain_never_strands_a_model():
    a = _replica()
    b = Config(stages=a.stages, model_index=1, model=TINY)
    plan = ServingPlan(replicas=[a, b], assignment=np.eye(2),
                       demands=[(0, 0, 5.0), (1, 0, 5.0)], makespan=1.0,
                       cost=a.cost + b.cost)
    policy = ScalePolicy([a], budget=10.0, window=1, cooldown=0,
                         min_replicas=1)
    # both idle, but each is the last replica of its model: no drain
    assert policy.update(1.0, [_snap(0, a), _snap(1, b)], plan) is None


def test_scaled_plan_covers_demands():
    a, b = _replica(), _replica(speed=2.0)
    base = _plan([a], 20)
    plan2 = scaled_plan(base, [a, b])
    assert plan2.cost == a.cost + b.cost
    np.testing.assert_allclose(plan2.assignment.sum(axis=0), 1.0)
    # faster replica takes the larger share
    assert plan2.assignment[1, 0] > plan2.assignment[0, 0]


def test_drain_releases_idle_instance_among_identical_replicas():
    """When two replicas share a config key and the policy drains one, the
    *idle* instance must be the one released — the busy survivor keeps its
    queue and active batch."""
    from repro.runtime.lifecycle import RequestState
    from repro.runtime.orchestrator import ReplanEvent
    cfg = _replica()
    plan = _plan([cfg, cfg], 4)
    runtime = ServingRuntime(plan, CostModelExecutor(plan.replicas, [TINY]))
    busy = runtime.replicas[1]
    busy.enqueue(RequestState(req=Request(req_id=7, workload=0, input_len=8,
                                          output_len=4, arrival=0.0)))
    runtime._apply_replan(ReplanEvent(time=1.0, plan=_plan([cfg], 4)))
    assert runtime.replicas[0].draining and not busy.draining
    assert len(busy.queue) == 1          # survivor kept its backlog


# --------------------------------------------- cost-model runtime integration

@pytest.fixture(scope="module")
def burst_setup():
    cfg = _replica(speed=0.01)
    n = 80
    reqs = tuple(Request(req_id=i, workload=0, input_len=64, output_len=128,
                         arrival=0.0) for i in range(n))
    trace = Trace("burst", reqs)
    plan = _plan([cfg], n)
    static = ServingRuntime(
        plan, CostModelExecutor(plan.replicas, [TINY])).run(trace)
    return cfg, trace, plan, static


def test_autoscale_improves_goodput_on_burst(burst_setup):
    """Acceptance: a bursty trace emits >= 1 online ReplanEvent and beats
    the static plan's goodput on the same trace."""
    cfg, trace, plan, static = burst_setup
    policy = ScalePolicy([cfg], budget=4 * cfg.cost,
                         interval=static.makespan / 40, window=2,
                         queue_high=2.0, cooldown=1)
    runtime = ServingRuntime(plan, CostModelExecutor(plan.replicas, [TINY]))
    auto = runtime.run(trace, autoscale=policy)
    assert auto.num_completed == trace.num_requests
    assert auto.info["autoscale_events"] >= 1
    assert auto.info["autoscale_adds"] >= 1
    assert len(runtime.scale_log) == auto.info["autoscale_events"]
    slo = SLO()          # unbounded: goodput == throughput
    assert auto.goodput(slo) > static.goodput(slo)
    assert auto.makespan < static.makespan
    # scale-up rebalanced the backlog onto the added replica(s)
    assert auto.info["requests_migrated"] > 0
    added = [row for row in auto.info["per_replica"] if row["replica"] >= 1]
    assert added and any(row["completed"] > 0 for row in added)


def test_autoscale_drains_idle_replica_during_lull(burst_setup):
    """A long lull after the burst lets the policy release capacity; a
    late arrival is still served by the surviving pool."""
    cfg, _, _, static = burst_setup
    n = 40
    late_t = static.makespan * 2
    reqs = tuple(Request(req_id=i, workload=0, input_len=64, output_len=128,
                         arrival=0.0) for i in range(n))
    reqs += (Request(req_id=n, workload=0, input_len=64, output_len=16,
                     arrival=late_t),)
    trace = Trace("burst+lull", reqs)
    plan = _plan([cfg, cfg], n + 1)
    policy = ScalePolicy([cfg], budget=4 * cfg.cost,
                         interval=static.makespan / 40, window=2,
                         queue_high=3.0, queue_low=0.5, kv_low=0.5,
                         cooldown=1)
    runtime = ServingRuntime(plan, CostModelExecutor(plan.replicas, [TINY]))
    res = runtime.run(trace, autoscale=policy)
    assert res.num_completed == trace.num_requests
    assert res.info.get("autoscale_drains", 0) >= 1
    assert any(d.action == "drain" for d in runtime.scale_log)


def test_autoscale_engine_backend_burst():
    """Acceptance (engine backend): an autoscale-enabled run on a bursty
    trace emits >= 1 online ReplanEvent and improves goodput over the
    static plan — with real token generation, measured clocks, and the
    added replica spun up through EngineExecutor.add_replica (joining
    *warm* thanks to the shared jit cache)."""
    from repro.configs import get_config
    from repro.serving import HeterogeneousServer
    cfg = _replica(num_blocks=4096)
    n = 64
    trace = Trace("engine-burst", tuple(
        Request(req_id=i, workload=0, input_len=32, output_len=8,
                arrival=0.0) for i in range(n)))
    plan = _plan([cfg], n)
    arch = get_config("llama3-8b").reduced()

    static_server = HeterogeneousServer(plan, [arch], max_batch=4,
                                        concurrent=False)
    static_server.serve(trace, input_len=8, max_new=4)   # warm the jits
    auto_server = HeterogeneousServer(plan, [arch], max_batch=4,
                                      concurrent=False)

    # The structural properties (scale event fired, added replica served
    # backlog, everything completed) must hold on every attempt; the
    # wall-clock goodput comparison between separately measured runs gets
    # a few attempts so one OS-scheduling stall on a loaded CI runner
    # cannot fail the gating job on a timing coin flip.
    improved = False
    for _ in range(3):
        static = static_server.serve(trace, input_len=8, max_new=4)
        assert static.completed == n
        # tick a handful of times inside the (warm) static makespan so the
        # windowed queue-depth trigger fires while the backlog is deep
        interval = max(static.result.makespan / 20, 1e-4)
        policy = ScalePolicy([cfg], budget=2 * cfg.cost, interval=interval,
                             window=2, queue_high=2.0, cooldown=10**6)
        auto = auto_server.serve(trace, autoscale=policy, input_len=8,
                                 max_new=4)
        assert auto.completed == n
        runtime = auto_server.last_runtime
        assert len(runtime.scale_log) >= 1
        assert auto.result.info["autoscale_adds"] >= 1
        # the added replica really served part of the backlog
        added = [row for row in auto.result.info["per_replica"]
                 if row["replica"] >= 1]
        assert added and any(row["completed"] > 0 for row in added)
        # goodput(SLO()) == throughput == n / makespan on the same trace
        if auto.result.goodput(SLO()) > static.result.goodput(SLO()):
            improved = True
            break
    assert improved, "autoscaled run never beat the static plan's goodput"
