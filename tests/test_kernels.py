"""Pallas kernel validation: shape/dtype sweeps, allclose vs pure-jnp oracle
(interpret mode on CPU; the kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.ops import decode_attention_op
from repro.kernels.decode_attention.ref import decode_attention_ref


def _qkv(key, b, h, kv, s, d, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = (jax.random.normal(k1, (b, h, s, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(k2, (b, kv, s, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(k3, (b, kv, s, d)) * 0.5).astype(dtype)
    return q, k, v


TOLS = {jnp.bfloat16: dict(rtol=0.05, atol=0.02),
        jnp.float32: dict(rtol=2e-3, atol=2e-3)}


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 4, 256, 128),     # MHA, seq == block
    (2, 8, 2, 512, 128),     # GQA 4:1, multi-block
    (1, 4, 1, 384, 64),      # GQA, odd seq (pad path), 64-dim heads
    (2, 2, 2, 128, 128),
])
def test_flash_attention_causal(dtype, b, h, kv, s, d):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, h, kv, s, d, dtype)
    out = flash_attention_op(q, k, v, block_q=128, block_k=128)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("window", [64, 128, 200])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 4, 2, 384, 64, jnp.bfloat16)
    out = flash_attention_op(q, k, v, window=window, block_q=128, block_k=128)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=0.05, atol=0.02)


def test_flash_attention_softcap():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 4, 4, 256, 128, jnp.bfloat16)
    out = flash_attention_op(q, k, v, softcap=50.0, block_q=128, block_k=128)
    ref = attention_ref(q, k, v, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=0.05, atol=0.02)


def test_flash_attention_block_shape_independence():
    """Different BlockSpec tilings give the same answer."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 2, 2, 512, 64, jnp.float32)
    a = flash_attention_op(q, k, v, block_q=64, block_k=128)
    b = flash_attention_op(q, k, v, block_q=256, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                               atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("b,h,kv,t,d", [
    (2, 8, 2, 512, 128),
    (1, 4, 4, 1024, 128),    # MHA
    (4, 14, 2, 384, 64),     # internvl2-like: 7:1 GQA, 64-dim heads
    (2, 32, 8, 256, 128),    # mixtral-like
])
def test_decode_attention(dtype, b, h, kv, t, d):
    key = jax.random.PRNGKey(4)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = (jax.random.normal(k1, (b, h, d)) * 0.5).astype(dtype)
    kc = (jax.random.normal(k2, (b, t, kv, d)) * 0.5).astype(dtype)
    vc = (jax.random.normal(k3, (b, t, kv, d)) * 0.5).astype(dtype)
    lengths = jax.random.randint(k4, (b,), 1, t + 1)
    out = decode_attention_op(q, kc, vc, lengths, block_k=128)
    ref = decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


def test_decode_attention_full_and_single_lengths():
    b, h, kv, t, d = 2, 4, 2, 256, 64
    key = jax.random.PRNGKey(5)
    q = (jax.random.normal(key, (b, h, d)) * 0.5).astype(jnp.float32)
    kc = (jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, d)) * 0.5
          ).astype(jnp.float32)
    vc = (jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, d)) * 0.5
          ).astype(jnp.float32)
    for lengths in (jnp.array([t, t]), jnp.array([1, 2])):
        out = decode_attention_op(q, kc, vc, lengths, block_k=64)
        ref = decode_attention_ref(q, kc, vc, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill_last_row():
    """Flash-decode of the last token == last row of full flash attention."""
    b, h, kv, s, d = 1, 4, 2, 256, 64
    q, k, v = _qkv(jax.random.PRNGKey(6), b, h, kv, s, d, jnp.float32)
    full = flash_attention_op(q, k, v, block_q=64, block_k=64)
    kc = k.transpose(0, 2, 1, 3)   # (B,S,KV,D)
    vc = v.transpose(0, 2, 1, 3)
    dec = decode_attention_op(q[:, :, -1], kc, vc, jnp.array([s]), block_k=64)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, -1]),
                               rtol=2e-3, atol=2e-3)
