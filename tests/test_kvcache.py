"""Paged KV-cache subsystem tests: block allocator, admission accounting,
preemption-by-recompute, budget property under random traces, and the
backend-equivalence acceptance check (cost-model and engine executors make
identical admission decisions on the same trace)."""
import math

import numpy as np
import pytest

from repro.core import costmodel
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage
from repro.core.plan import Config, ServingPlan
from repro.core.workloads import Request, Trace
from repro.runtime import CostModelExecutor, ServingRuntime
from repro.runtime.kvcache import (BlockAllocator, KVCacheManager,
                                   make_kv_manager, num_kv_blocks)

BS = 16
# kv_bytes_per_token = 2 * 2 layers * 2 kv_heads * 64 head_dim * 2 B = 1024
TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)
BLOCK_BYTES = BS * TINY.kv_bytes_per_token


def _replica(num_blocks: int) -> Config:
    """A one-device replica whose modeled HBM budget holds exactly
    ``num_blocks`` KV blocks of BS tokens."""
    free = (num_blocks + 0.5) * BLOCK_BYTES
    mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
           / costmodel.MEMORY_UTIL)
    dev = DeviceType("kv-test", 1e12, 1e11, mem, 1.0, 8, 1e11, 1e9, "x")
    return Config(stages=(Stage(dev, 1, 1.0),), model_index=0, model=TINY)


def _plan(config: Config, n_requests: int) -> ServingPlan:
    return ServingPlan(replicas=[config], assignment=np.ones((1, 1)),
                       demands=[(0, 0, float(n_requests))], makespan=1.0,
                       cost=config.cost)


def _trace(reqs) -> Trace:
    return Trace("kv", tuple(reqs))


# ----------------------------------------------------------- unit: allocator

def test_block_allocator_ids_cycle():
    a = BlockAllocator(4, first_id=1)
    ids = a.alloc(3)
    assert sorted(ids) == [1, 2, 3]
    assert (a.used_blocks, a.free_blocks) == (3, 1)
    a.free(ids[:2])
    assert a.free_blocks == 3
    more = a.alloc(3)
    assert a.free_blocks == 0 and len(set(more) | {ids[2]}) == 4
    with pytest.raises(MemoryError):
        a.alloc(1)
    a.free([more[0]])
    with pytest.raises(ValueError):
        a.free([more[0]])   # double free


# ------------------------------------------------------------- unit: manager

def test_manager_admission_watermark_and_growth():
    m = KVCacheManager(num_blocks=5, block_size=BS)
    assert m.watermark == 1
    assert m.admit(0, 31, solo=True)            # 2 blocks
    assert m.admit(1, 31)                       # 2 + watermark 1 <= 5
    assert not m.admit(2, 31)                   # would need 7 > 5
    assert m.used_blocks == 4
    # both can grow one token (still inside block 2), not past block 3 x2
    assert m.feasible_steps([(0, 31), (1, 31)], 4) == 1
    m.free(1)
    assert m.feasible_steps([(0, 31)], 4) == 4
    assert m.grow(0, 35)
    assert m.used_blocks == 3
    m.free(0)
    assert m.used_blocks == 0 and m.peak_used == 4


def test_manager_solo_overflow_keeps_progress():
    m = KVCacheManager(num_blocks=1, block_size=BS)
    assert not m.admit(7, 100)                  # 7 blocks never fit
    assert m.admit(7, 100, solo=True)           # but a lone request runs
    assert m.overflow_admissions == 1
    assert m.grow(7, 200, allow_overflow=True)
    m.free(7)
    assert m.used_blocks == 0


def test_manager_window_caps_growth():
    m = KVCacheManager(num_blocks=10, block_size=BS, window=32)
    assert m.blocks_for(1000) == 2              # ring buffer: 32 tokens max
    assert m.admit(0, 1000)
    assert m.feasible_steps([(0, 1000)], 10**6) == 10**6


# ------------------------------------------------------------ budget sizing

def test_budget_matches_costmodel_free_bytes():
    cfg = _replica(num_blocks=5)
    assert num_kv_blocks(cfg, TINY, BS) == 5
    mgr = make_kv_manager(cfg, TINY, BS)
    assert mgr.num_blocks == 5
    free = costmodel.kv_free_bytes(cfg.stages, TINY)
    assert mgr.num_blocks * BLOCK_BYTES <= free < (mgr.num_blocks + 1) * BLOCK_BYTES


def test_state_only_accounting_for_recurrent_models():
    """Pure-recurrent profiles (no per-token KV, constant state) still get
    memory-based admission: one state block per sequence, pool sized by
    free HBM / state bytes."""
    ssm = ModelProfile(name="ssm", n_layers=2, d_model=256, n_kv_heads=0,
                       head_dim=64, params_total=2e6, params_active=2e6,
                       state_bytes_per_seq=float(BLOCK_BYTES))
    cfg = _replica(5)   # free HBM = 5.5 state units
    mgr = make_kv_manager(cfg, ssm, BS)
    assert mgr is not None and mgr.num_blocks == 5
    assert mgr.blocks_for(10**6) == 1        # history costs nothing
    assert mgr.admit(0, 30, solo=True) and mgr.admit(1, 30)
    assert mgr.used_blocks == 2
    assert mgr.feasible_steps([(0, 30), (1, 30)], 10**6) == 10**6
    # no per-token KV and no state -> nothing to account
    no_mem = ModelProfile(name="none", n_layers=2, d_model=256, n_kv_heads=0,
                          head_dim=64, params_total=2e6, params_active=2e6)
    assert make_kv_manager(cfg, no_mem, BS) is None


# ------------------------------------------- integration: preemption (cost)

def _overflow_requests(n=3, input_len=30, output_len=4):
    return [Request(req_id=i, workload=0, input_len=input_len,
                    output_len=output_len, arrival=0.0) for i in range(n)]


def test_overflow_trace_preempts_and_completes():
    """Acceptance: a trace that outgrows a small replica's HBM budget
    triggers preemption/recompute — never an over-budget batch — and every
    request still completes."""
    cfg = _replica(num_blocks=5)
    trace = _trace(_overflow_requests())
    executor = CostModelExecutor([cfg], [TINY])
    runtime = ServingRuntime(_plan(cfg, trace.num_requests), executor)
    res = runtime.run(trace)
    assert res.num_completed == trace.num_requests
    assert res.num_preemptions > 0
    assert res.info["preemptions"] == res.num_preemptions
    mgr = executor.kv_manager(0)
    assert mgr.peak_used <= mgr.num_blocks      # the budget held throughout
    assert mgr.overflow_admissions == 0
    assert mgr.used_blocks == 0                 # everything freed
    # a preempted request re-entered the queue and paid prefill again
    assert len(runtime.replicas[0].admission_log) > 1
    readmitted = [rid for g in runtime.replicas[0].admission_log for rid in g]
    assert len(readmitted) > trace.num_requests


def test_ample_budget_never_preempts():
    cfg = _replica(num_blocks=50)
    trace = _trace(_overflow_requests())
    res = ServingRuntime(_plan(cfg, 3), CostModelExecutor([cfg], [TINY])
                         ).run(trace)
    assert res.num_completed == 3
    assert res.num_preemptions == 0


# --------------------------------- acceptance: backend admission equivalence

def test_cost_and_engine_backends_make_identical_admission_decisions():
    """The same synthetic overflow trace through both executors: identical
    admission cohorts (by request id), identical preemption counts — block
    accounting, not backend timing, decides who runs when memory is
    scarce."""
    pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.runtime import EngineExecutor

    cfg = _replica(num_blocks=5)
    reqs = _overflow_requests(n=3, input_len=30, output_len=4)
    trace = _trace(reqs)
    plan = _plan(cfg, len(reqs))

    cost_rt = ServingRuntime(plan, CostModelExecutor([cfg], [TINY]))
    cost_res = cost_rt.run(trace)

    # max_new=5 -> engine decode quota min(output_len, 4) == 4 == cost quota:
    # both backends walk the same token-growth curve through the manager
    engine = EngineExecutor(plan, [get_config("llama3-8b").reduced()],
                            models=[TINY], max_batch=8, input_len=8,
                            max_new=5)
    eng_rt = ServingRuntime(plan, engine)
    eng_res = eng_rt.run(trace)

    assert cost_res.num_completed == eng_res.num_completed == 3
    assert (cost_rt.replicas[0].admission_log
            == eng_rt.replicas[0].admission_log)
    cost_pre = {r.req.req_id: r.preemptions for r in cost_res.records}
    eng_pre = {r.req.req_id: r.preemptions for r in eng_res.records}
    assert cost_pre == eng_pre
    assert cost_res.num_preemptions > 0
    # the engine's preempted requests really recomputed through real blocks
    paged = engine._paged[0]
    assert paged is not None
    assert paged.allocator.used_blocks == 0     # all physical blocks freed


# ----------------------------------------------- property: budget invariant

def test_block_usage_never_exceeds_budget_property():
    """Across random traces, the sum of blocks allocated on a replica never
    exceeds its modeled HBM budget (and all blocks are freed at the end)."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=25, deadline=None)
    @hypothesis.given(
        num_blocks=st.integers(min_value=6, max_value=40),
        reqs=st.lists(
            st.tuples(st.integers(1, 40),       # input_len
                      st.integers(1, 20),       # output_len
                      st.floats(0.0, 5.0)),     # arrival
            min_size=1, max_size=25),
    )
    def run(num_blocks, reqs):
        # every single request fits the budget (<= ceil(61/16) + 0 = 4 < 6
        # blocks), so admission never needs the solo-overflow escape hatch
        cfg = _replica(num_blocks)
        trace = _trace([Request(req_id=i, workload=0, input_len=il,
                                output_len=ol, arrival=ar)
                        for i, (il, ol, ar) in enumerate(reqs)])
        executor = CostModelExecutor([cfg], [TINY])
        res = ServingRuntime(_plan(cfg, len(reqs)), executor).run(trace)
        mgr = executor.kv_manager(0)
        assert res.num_completed == trace.num_requests
        assert mgr.peak_used <= mgr.num_blocks
        assert mgr.overflow_admissions == 0
        assert mgr.used_blocks == 0
        peak_bytes = mgr.peak_used * BLOCK_BYTES
        assert peak_bytes <= costmodel.kv_free_bytes(cfg.stages, TINY)

    run()


def test_block_usage_budget_random_traces_seeded():
    """Hypothesis-free version of the budget property (always runs)."""
    rng = np.random.default_rng(0)
    for _ in range(15):
        num_blocks = int(rng.integers(6, 41))
        n = int(rng.integers(1, 26))
        cfg = _replica(num_blocks)
        trace = _trace([Request(req_id=i, workload=0,
                                input_len=int(rng.integers(1, 41)),
                                output_len=int(rng.integers(1, 21)),
                                arrival=float(rng.uniform(0, 5)))
                        for i in range(n)])
        executor = CostModelExecutor([cfg], [TINY])
        res = ServingRuntime(_plan(cfg, n), executor).run(trace)
        mgr = executor.kv_manager(0)
        assert res.num_completed == n
        assert mgr.peak_used <= mgr.num_blocks
        assert mgr.overflow_admissions == 0
        assert mgr.used_blocks == 0


def test_manager_blocks_for_matches_ceil():
    m = KVCacheManager(10, BS)
    for tokens in (1, BS - 1, BS, BS + 1, 5 * BS):
        assert m.blocks_for(tokens) == math.ceil(tokens / BS)
