"""Cluster-simulator tests."""
import numpy as np
import pytest

from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_70B,
                        make_trace, simulate, solve)


@pytest.fixture(scope="module")
def plan_and_trace():
    trace = make_trace("trace1", num_requests=300, seed=3)
    plan = solve([LLAMA3_70B], trace, GPU_CATALOG,
                 AVAILABILITY_SNAPSHOTS["avail1"], budget=30.0)
    return plan, trace


def test_all_requests_complete(plan_and_trace):
    plan, trace = plan_and_trace
    res = simulate(plan, trace, [LLAMA3_70B])
    assert len(res.latencies) == trace.num_requests
    assert res.makespan > 0
    assert res.throughput > 0


def test_simulated_makespan_tracks_planned(plan_and_trace):
    """The simulator uses the same cost model as the planner, so the
    simulated makespan should be within ~2x of the planned one (simulation
    adds queueing, batching granularity, and random dispatch)."""
    plan, trace = plan_and_trace
    res = simulate(plan, trace, [LLAMA3_70B])
    assert res.makespan >= plan.makespan * 0.5
    assert res.makespan <= plan.makespan * 3.0


def test_latency_percentiles_monotone(plan_and_trace):
    plan, trace = plan_and_trace
    res = simulate(plan, trace, [LLAMA3_70B])
    ps = res.percentiles((10, 30, 50, 70, 90, 100))
    vals = list(ps.values())
    assert vals == sorted(vals)
    assert vals[0] > 0


def test_poisson_arrivals(plan_and_trace):
    plan, _ = plan_and_trace
    trace = make_trace("trace1", num_requests=200, arrival_rate=2.0, seed=4)
    res = simulate(plan, trace, [LLAMA3_70B])
    assert len(res.latencies) == 200
    last_arrival = max(r.arrival for r in trace.requests)
    assert res.makespan >= last_arrival


def test_more_replicas_not_slower():
    trace = make_trace("trace1", num_requests=300, seed=5)
    small = solve([LLAMA3_70B], trace, GPU_CATALOG,
                  AVAILABILITY_SNAPSHOTS["avail1"], budget=15.0)
    big = solve([LLAMA3_70B], trace, GPU_CATALOG,
                AVAILABILITY_SNAPSHOTS["avail1"], budget=60.0)
    r_small = simulate(small, trace, [LLAMA3_70B])
    r_big = simulate(big, trace, [LLAMA3_70B])
    assert r_big.makespan <= r_small.makespan * 1.15
