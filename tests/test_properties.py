"""Hypothesis property tests on the scheduler's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage, config_throughput
from repro.core.milp import SchedulingProblem, plan_makespan, solve_feasibility
from repro.core.binsearch import knapsack_feasible, solve_binary_search
from repro.core.plan import Config
from repro.core.workloads import WORKLOAD_TYPES, WorkloadType, make_trace

_GB = 1024**3
MODEL = ModelProfile(name="toy", n_layers=2, d_model=64, n_kv_heads=1,
                     head_dim=64, params_total=1e6, params_active=1e6)


def _dev(i: int, price: float) -> DeviceType:
    return DeviceType(f"g{i}", 1e12, 1e11, 64 * _GB, price, 8, 1e11, 1e9, "x")


@st.composite
def problems(draw):
    n_types = draw(st.integers(2, 4))
    n_workloads = draw(st.integers(1, 4))
    prices = [draw(st.floats(0.5, 5.0)) for _ in range(n_types)]
    configs = []
    h_rows = []
    for i in range(n_types):
        configs.append(Config(stages=(Stage(_dev(i, prices[i]), 1, 1.0),),
                              model_index=0, model=MODEL))
        h_rows.append([draw(st.floats(0.1, 4.0)) for _ in range(n_workloads)])
    lam = [draw(st.floats(1.0, 100.0)) for _ in range(n_workloads)]
    demands = [(0, w, lam[w]) for w in range(n_workloads)]
    avail = {f"g{i}": draw(st.integers(1, 4)) for i in range(n_types)}
    budget = draw(st.floats(max(prices) + 0.1, 4 * sum(prices)))
    return SchedulingProblem(configs=configs, h=np.array(h_rows),
                             demands=demands, budget=budget, availability=avail)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(problems())
def test_binary_search_plan_is_valid(problem):
    plan = solve_binary_search(problem, tol=0.5)
    # budget respected
    assert plan.cost <= problem.budget + 1e-6
    # availability respected
    for name, n in plan.composition().items():
        assert n <= problem.availability[name]
    # full coverage
    np.testing.assert_allclose(plan.assignment.sum(axis=0), 1.0, atol=1e-5)
    # reported makespan consistent with assignment + throughput table
    t = 0.0
    for i, cfg in enumerate(plan.replicas):
        c = problem.configs.index(cfg)
        tc = sum(plan.assignment[i, d] * problem.demands[d][2] / problem.h[c, d]
                 for d in range(len(problem.demands))
                 if plan.assignment[i, d] > 1e-9)
        t = max(t, tc)
    assert t <= plan.makespan * 1.05 + 0.5


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(problems())
def test_knapsack_witness_is_feasible(problem):
    """Greedy success must be a *certificate*: its witness satisfies all
    constraints and meets the claimed makespan."""
    t_ub = problem.makespan_upper_bound()
    witness = knapsack_feasible(problem, t_ub)
    if witness is None:
        return
    y, x = witness
    cost = sum(problem.configs[c].cost * y[c] for c in range(len(y)))
    assert cost <= problem.budget + 1e-6
    used = {}
    for c, cfg in enumerate(problem.configs):
        for n, k in cfg.device_counts().items():
            used[n] = used.get(n, 0) + k * y[c]
    for n, k in used.items():
        assert k <= problem.availability[n] + 1e-9
    np.testing.assert_allclose(x.sum(axis=0), 1.0, atol=1e-5)
    assert plan_makespan(problem, y, x) <= t_ub * 1.01


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(problems(), st.floats(0.01, 0.99))
def test_feasibility_monotone_in_t(problem, frac):
    """If T̂ is feasible then any larger T̂ must also be feasible."""
    t_ub = problem.makespan_upper_bound()
    t_small = frac * t_ub
    small = solve_feasibility(problem, t_small, time_limit=10)
    if small is not None:
        bigger = solve_feasibility(problem, t_small * 1.5, time_limit=10)
        assert bigger is not None


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(50, 400))
def test_trace_generation_deterministic_and_mixed(seed, n):
    t1 = make_trace("trace2", num_requests=n, seed=seed)
    t2 = make_trace("trace2", num_requests=n, seed=seed)
    assert t1.requests == t2.requests
    counts = t1.counts_by_type()
    assert counts.sum() == n


def test_cost_model_monotone_in_workload():
    """Longer outputs can't increase throughput (req/s) at fixed config."""
    from repro.core.catalog import GPU_CATALOG
    from repro.core.costmodel import LLAMA3_8B
    stages = (Stage(GPU_CATALOG["A100"], 1, 1.0),)
    prev = None
    for out in (18, 64, 253, 510):
        h = config_throughput(stages, LLAMA3_8B, WorkloadType(496, out))
        if prev is not None:
            assert h <= prev * 1.0001
        prev = h
