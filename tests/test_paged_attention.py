"""Paged flash-decode kernel numerics: the Pallas kernel (interpret mode)
must match the dense contiguous reference to fp32 tolerance after the
block-table gather, across GQA grouping, ragged lengths, permuted block
tables, and softcapping."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.decode_attention.ref import decode_attention_ref  # noqa: E402
from repro.kernels.paged_attention import (gather_kv,                # noqa: E402
                                           paged_decode_attention,
                                           paged_decode_attention_ref)


def _case(rng, b, h, kv, d, bs, mb, nb, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    kp = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), dtype)
    vp = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), dtype)
    # each sequence gets mb distinct blocks, deliberately scattered
    tables = jnp.asarray(
        rng.permutation(nb)[: b * mb].reshape(b, mb), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, mb * bs + 1, size=b), jnp.int32)
    return q, kp, vp, tables, lengths


@pytest.mark.parametrize("h,kv", [(8, 8), (8, 2), (4, 1)])
def test_paged_kernel_matches_contiguous_reference(h, kv):
    """Acceptance: paged kernel == dense decode_attention reference on the
    gathered cache, fp32 tolerance, interpret mode."""
    rng = np.random.default_rng(0)
    b, d, bs, mb, nb = 3, 64, 16, 4, 16
    q, kp, vp, tables, lengths = _case(rng, b, h, kv, d, bs, mb, nb)
    out = paged_decode_attention(q, kp, vp, tables, lengths, interpret=True)
    dense = decode_attention_ref(q, gather_kv(kp, tables),
                                 gather_kv(vp, tables), lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_matches_paged_reference_softcap():
    rng = np.random.default_rng(1)
    q, kp, vp, tables, lengths = _case(rng, 2, 8, 2, 64, 8, 3, 8)
    out = paged_decode_attention(q, kp, vp, tables, lengths, softcap=30.0,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, tables, lengths,
                                     softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_ignores_out_of_range_table_entries():
    """Blocks past a sequence's length may point anywhere (allocators pass
    scratch block 0): they must not contribute to the softmax."""
    rng = np.random.default_rng(2)
    b, h, kv, d, bs, mb, nb = 2, 4, 2, 64, 8, 4, 16
    q, kp, vp, tables, _ = _case(rng, b, h, kv, d, bs, mb, nb)
    lengths = jnp.asarray([bs + 3, 2 * bs], jnp.int32)   # 2 blocks each
    garbage = np.asarray(tables).copy()
    garbage[:, 2:] = 0                                   # stomp unused tail
    out_a = paged_decode_attention(q, kp, vp, tables, lengths,
                                   interpret=True)
    out_b = paged_decode_attention(q, kp, vp, jnp.asarray(garbage), lengths,
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


def test_paged_kernel_bf16_inputs():
    rng = np.random.default_rng(3)
    q, kp, vp, tables, lengths = _case(rng, 2, 8, 2, 64, 16, 2, 8,
                                       dtype=jnp.bfloat16)
    out = paged_decode_attention(q, kp, vp, tables, lengths, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, tables, lengths)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2)


def test_paged_ref_equals_dense_on_identity_tables():
    """With the identity block table the pool *is* a contiguous cache."""
    rng = np.random.default_rng(4)
    b, h, kv, d, bs, mb = 2, 4, 2, 32, 4, 3
    t = mb * bs
    kc = jnp.asarray(rng.normal(size=(b, t, kv, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, t, kv, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    lengths = jnp.asarray([t, t // 2], jnp.int32)
    # sequence-major pool: block i of sequence s lives at s*mb + i
    kp = kc.reshape(b * mb, bs, kv, d)
    vp = vc.reshape(b * mb, bs, kv, d)
    tables = jnp.arange(b * mb, dtype=jnp.int32).reshape(b, mb)
    ref = paged_decode_attention_ref(q, kp, vp, tables, lengths)
    dense = decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dense),
                               rtol=1e-6, atol=1e-6)
