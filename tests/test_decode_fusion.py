"""Horizon-fused decode tests: k fused greedy steps inside one jit must be
token-for-token identical to k stepwise calls — at the model layer (dense
ring caches, paged block pools, recurrent/hybrid state carries) and at the
runtime layer (identical token streams, admission logs, and preemption
counts for ``fused_steps=16`` vs ``fused_steps=1``, in both drive modes).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import costmodel
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage
from repro.core.plan import Config, ServingPlan
from repro.core.workloads import Request, Trace
from repro.runtime import CostModelExecutor, EngineExecutor, ServingRuntime
from repro.serving.engine import pow2_chunks

BS = 16
TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)
BLOCK_BYTES = BS * TINY.kv_bytes_per_token

# one arch per decode-path family: pure-attention (paged pools), hybrid
# attention+Mamba, and recurrent xLSTM — all must fuse token-exactly
ARCHS = ["llama3-8b", "jamba-v0.1-52b", "xlstm-125m"]


def _replica(num_blocks: int) -> Config:
    free = (num_blocks + 0.5) * BLOCK_BYTES
    mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
           / costmodel.MEMORY_UTIL)
    dev = DeviceType("kv-test", 1e12, 1e11, mem, 1.0, 8, 1e11, 1e9, "x")
    return Config(stages=(Stage(dev, 1, 1.0),), model_index=0, model=TINY)


def _plan(config: Config, n_requests: int, replicas: int = 1) -> ServingPlan:
    return ServingPlan(replicas=[config] * replicas,
                       assignment=np.full((replicas, 1), 1.0 / replicas),
                       demands=[(0, 0, float(n_requests))], makespan=1.0,
                       cost=config.cost * replicas)


def _requests(n, input_len=20, output_len=4, arrival=0.0):
    return [Request(req_id=i, workload=0, input_len=input_len,
                    output_len=output_len, arrival=arrival)
            for i in range(n)]


# ----------------------------------------------------------- unit helpers

def test_pow2_chunks_cover_exactly():
    for k in range(1, 40):
        chunks = pow2_chunks(k)
        assert sum(chunks) == k
        assert all(c & (c - 1) == 0 for c in chunks)       # powers of two
        assert chunks == sorted(chunks, reverse=True)


def test_steps_to_boundary_tracks_occupied_slots():
    from repro.configs import get_config
    from repro.runtime.kvcache.paged import PagedEngineCache
    cfg = get_config("llama3-8b").reduced()
    paged = PagedEngineCache(cfg, num_slots=2, t_max=20, block_size=8)
    assert paged.steps_to_boundary() == 8          # empty: full scratch block
    paged._slot_of = {1: 0}
    paged.lengths[0] = 13                          # 3 tokens to the boundary
    assert paged.steps_to_boundary() == 3
    paged.advance(3)
    assert paged.lengths[0] == 16
    assert paged.steps_to_boundary() == 8


# ------------------------------------------------- model-level equivalence

@pytest.mark.parametrize("arch_name", ARCHS)
def test_decode_steps_matches_stepwise(arch_name):
    """k fused steps (one scan) ≡ k single steps: identical greedy tokens
    and numerically identical caches, for every mixer family."""
    from repro.configs import get_config
    from repro.serving.engine import ReplicaEngine
    cfg = get_config(arch_name).reduced()
    eng = ReplicaEngine(cfg, seed=0)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    tok, caches = eng.prefill_batch(prompts, 8 + 8)
    tok_s, caches_s, steps = tok, caches, []
    for i in range(5):
        tok_s, caches_s = eng.decode_batch(caches_s, tok_s, 8 + i)
        steps.append(np.asarray(tok_s))
    fused, caches_f = eng.decode_batch_k(caches, tok, 8, 5)   # 4 + 1 pieces
    np.testing.assert_array_equal(np.stack(steps, 1), np.asarray(fused))
    for a, b in zip(jax.tree.leaves(caches_s), jax.tree.leaves(caches_f)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_paged_decode_steps_matches_stepwise():
    """Fused paged decode (block-boundary-split chunks) ≡ stepwise paged
    decode across a boundary crossing."""
    from repro.configs import get_config
    from repro.runtime.kvcache.paged import PagedEngineCache
    from repro.serving.engine import ReplicaEngine
    cfg = get_config("llama3-8b").reduced()
    eng = ReplicaEngine(cfg, seed=0)
    paged = PagedEngineCache(cfg, num_slots=2, t_max=8 + 12, block_size=8)
    rng = np.random.default_rng(2)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    tok, caches = eng.prefill_batch(prompts, 8)
    paged.admit_cohort([10, 11], caches, np.asarray(tok), 8)
    pools0, tables, lengths, toks = paged.step_args()

    pl, ls, tk, step_toks = pools0, lengths, toks, []
    for _ in range(10):
        t1, pl = eng.paged_decode(pl, tables, ls, tk)
        step_toks.append(np.asarray(t1))
        tk, ls = t1, ls + 1

    pl2, tk2 = pools0, toks
    ls_host = np.asarray(paged.lengths).copy()
    blocks, done, subs = [], 0, []
    while done < 10:
        sub = min(10 - done,
                  min(8 - int(ls_host[s]) % 8 for s in (0, 1)))
        tb, pl2 = eng.paged_decode_k(pl2, tables, jnp.asarray(ls_host),
                                     tk2, sub)
        blocks.append(np.asarray(tb))
        tk2 = tb[:, -1]
        ls_host[:2] += sub
        done += sub
        subs.append(sub)
    assert subs == [8, 2]                  # split exactly at the boundary
    np.testing.assert_array_equal(np.stack(step_toks, 1),
                                  np.concatenate(blocks, 1))


# ----------------------------------------------- runtime-level equivalence

def _serve(arch_name, *, fused_steps, mode, paged=None, concurrent=False,
           replicas=1, n=5, max_batch=2, output_len=5, max_new=6):
    """One engine-backend run; returns (token_log, admission_logs,
    preemptions-by-request, completed).

    The executor measures elapsed time around every jit call and schedules
    on it, so on a loaded machine admission cohorts could shift between
    the fused and stepwise runs; pinning a deterministic ``TickClock``
    makes every measured duration — hence every schedule — load-independent
    (each run gets a fresh clock, so both arms see identical time)."""
    from repro.configs import get_config
    from repro.obs import TickClock
    cfg = _replica(num_blocks=50)
    reqs = _requests(n, output_len=output_len)
    trace = Trace("fuse", tuple(reqs))
    plan = _plan(cfg, n, replicas=replicas)
    executor = EngineExecutor(plan, [get_config(arch_name).reduced()],
                              models=[TINY], max_batch=max_batch,
                              input_len=8, max_new=max_new, paged=paged,
                              concurrent=concurrent,
                              fused_steps=fused_steps,
                              clock=TickClock())
    runtime = ServingRuntime(plan, executor, mode=mode)
    res = runtime.run(trace)
    assert res.num_completed == n
    return (executor.token_log,
            [r.admission_log for r in runtime.replicas],
            {r.req.req_id: r.preemptions for r in res.records})


FAMILIES = [
    ("llama3-8b", None),        # pure attention -> paged block pools
    ("llama3-8b", False),       # same arch, dense per-cohort caches
    ("xlstm-125m", None),       # recurrent states (paged unsupported)
]


@pytest.mark.parametrize("arch_name,paged", FAMILIES,
                         ids=["paged", "dense", "recurrent"])
@pytest.mark.parametrize("mode", ["sequential", "events"])
def test_fused_runtime_matches_stepwise(arch_name, paged, mode):
    """fused_steps=16 vs fused_steps=1 through the full serving runtime:
    byte-identical token streams, admission cohorts, and preemption counts
    — fusion changes dispatch count, never scheduling or tokens."""
    stepwise = _serve(arch_name, fused_steps=1, mode=mode, paged=paged)
    fused = _serve(arch_name, fused_steps=16, mode=mode, paged=paged)
    assert fused[0] == stepwise[0]          # token streams
    assert fused[1] == stepwise[1]          # admission logs
    assert fused[2] == stepwise[2]          # preemptions


def test_fused_concurrent_matches_stepwise_sequential():
    """Fused chunks + concurrent per-replica workers (2 replicas) still
    reproduce the stepwise sequential token streams."""
    stepwise = _serve("llama3-8b", fused_steps=1, mode="sequential",
                      replicas=2, n=6)
    fused = _serve("llama3-8b", fused_steps=16, mode="events",
                   concurrent=True, replicas=2, n=6)
    assert fused[0] == stepwise[0]
    assert fused[1] == stepwise[1]
    assert fused[2] == stepwise[2]


def test_fused_preemption_matches_cost_backend():
    """The KV-overflow acceptance trace (cost vs engine identical admission
    / preemption) must hold with fused chunks: the scheduler pre-reserves
    the fused horizon's block growth, so preemption decisions are
    position-identical to stepwise execution."""
    from repro.configs import get_config
    cfg = _replica(num_blocks=5)
    reqs = _requests(3, input_len=30, output_len=4)
    trace = Trace("overflow", tuple(reqs))
    plan = _plan(cfg, 3)

    cost_rt = ServingRuntime(plan, CostModelExecutor([cfg], [TINY]))
    cost_res = cost_rt.run(trace)
    assert cost_res.num_preemptions > 0

    logs = {}
    for fused_steps in (1, 16):
        from repro.obs import TickClock
        engine = EngineExecutor(plan, [get_config("llama3-8b").reduced()],
                                models=[TINY], max_batch=8, input_len=8,
                                max_new=5, fused_steps=fused_steps,
                                clock=TickClock())
        rt = ServingRuntime(plan, engine)
        res = rt.run(trace)
        assert res.num_completed == 3
        logs[fused_steps] = (
            engine.token_log,
            [r.admission_log for r in rt.replicas],
            {r.req.req_id: r.preemptions for r in res.records})
        assert (logs[fused_steps][1]
                == [r.admission_log for r in cost_rt.replicas])
        assert logs[fused_steps][2] == {
            r.req.req_id: r.preemptions for r in cost_res.records}
    assert logs[1] == logs[16]              # fused ≡ stepwise, tokens too


def test_generate_single_transfer_tokens_deterministic():
    """Satellite: ``ReplicaEngine.generate`` accumulates on-device and
    returns the same greedy tokens as the stepwise decode loop."""
    from repro.configs import get_config
    from repro.serving.engine import ReplicaEngine
    cfg = get_config("llama3-8b").reduced()
    eng = ReplicaEngine(cfg, seed=0)
    rng = np.random.default_rng(7)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    gen = eng.generate(prompts, max_new=6)
    assert gen.tokens.shape == (2, 6)
    tok, caches = eng.prefill_batch(prompts, 8 + 6)
    out = [np.asarray(tok)]
    for i in range(5):
        tok, caches = eng.decode_batch(caches, tok, 8 + i)
        out.append(np.asarray(tok))
    np.testing.assert_array_equal(gen.tokens, np.stack(out, 1))
