"""Fault tolerance under spot GPU churn: fault plan/injector units, the
availability watcher, spec validation satellites, graceful-reclaim KV
migration (zero loss; byte-identical engine token streams), crash requeue
with a bounded retry budget (recovered streams are byte-identical tails),
worker-timeout structured failure, live-session failed handles, and the
trace-summary fault columns cross-checked against ``result.info``."""
import math
import time

import numpy as np
import pytest

from repro.core import costmodel
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage
from repro.core.plan import Config, ServingPlan
from repro.core.spec import DeploymentSpec
from repro.core.workloads import Request, Trace
from repro.runtime import (AvailabilityWatcher, CostModelExecutor,
                           FaultEvent, FaultInjector, FaultPlan,
                           ServingRuntime, WorkerTimeout, spot_schedule)
from repro.runtime.actor import ReplicaWorker
from repro.runtime.faults import as_injector
from repro.runtime.kvcache import KVCacheManager

BS = 16
TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)
GPU = "spot-gpu"


def _replica(num_blocks: int = 5, **dev_kw) -> Config:
    free = (num_blocks + 0.5) * BS * TINY.kv_bytes_per_token
    mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
           / costmodel.MEMORY_UTIL)
    dev = DeviceType(GPU, 1e12, 1e11, mem, 1.0, 8, 1e11, 1e9, "x", **dev_kw)
    return Config(stages=(Stage(dev, 1, 1.0),), model_index=0, model=TINY)


def _plan(cfgs, n_requests: int) -> ServingPlan:
    cfgs = list(cfgs)
    return ServingPlan(replicas=cfgs,
                       assignment=np.ones((len(cfgs), 1)) / len(cfgs),
                       demands=[(0, 0, float(n_requests))], makespan=1.0,
                       cost=sum(c.cost for c in cfgs))


def _trace(n=4, input_len=30, output_len=4) -> Trace:
    return Trace("faults", tuple(
        Request(req_id=i, workload=0, input_len=input_len,
                output_len=output_len, arrival=0.0) for i in range(n)))


def _tiny_watcher(cfg: Config, trace: Trace, n: int) -> AvailabilityWatcher:
    """Watcher over the tiny single-type pool whose planner just resizes
    the replica set to the surviving device count (bench-style custom
    planner: the plan does not come from the strategy registry)."""
    dev = cfg.stages[0].device
    spec = DeploymentSpec(models=[TINY], workload=trace,
                          catalog={GPU: dev}, availability={GPU: n},
                          budget=100.0)

    def planner(s: DeploymentSpec) -> ServingPlan:
        k = s.availability.get(GPU, 0)
        if k <= 0:
            raise ValueError("pool is empty")
        return _plan([cfg] * k, trace.num_requests)

    return AvailabilityWatcher(spec, planner=planner)


# --------------------------------------------------- unit: events and plans

def test_fault_event_validation():
    ev = FaultEvent(time=1.0, kind="reclaim", gpu_type="H100", grace=5.0)
    assert ev.grace == 5.0 and ev.count == 1
    with pytest.raises(ValueError):
        FaultEvent(time=1.0, kind="meteor", gpu_type="H100")
    with pytest.raises(ValueError):
        FaultEvent(time=-1.0, kind="crash", gpu_type="H100")
    with pytest.raises(ValueError):
        FaultEvent(time=1.0, kind="crash", gpu_type="H100", count=0)
    with pytest.raises(ValueError):
        # a grace window only makes sense on a reclaim
        FaultEvent(time=1.0, kind="crash", gpu_type="H100", grace=5.0)


def test_fault_plan_sorts_and_injector_protocol():
    e1 = FaultEvent(time=2.0, kind="recover", gpu_type="A100")
    e2 = FaultEvent(time=0.5, kind="crash", gpu_type="A100")
    plan = FaultPlan([e1, e2])
    assert [e.time for e in plan.events] == [0.5, 2.0]
    inj = as_injector(plan)
    assert isinstance(inj, FaultInjector) and not inj.exhausted
    assert inj.next_time() == 0.5
    assert inj.pop() is plan.events[0]
    assert inj.next_time() == 2.0
    assert inj.pop() is plan.events[1]
    assert inj.exhausted and inj.next_time() == math.inf
    inj.reset()
    assert inj.next_time() == 0.5
    # a bare event sequence and an existing injector pass through too
    assert as_injector([e2]).next_time() == 0.5
    assert as_injector(inj) is inj


def test_spot_schedule_deterministic():
    kw = dict(horizon=60.0, mtbf_s=8.0, mttr_s=8.0)
    a = spot_schedule(["H100", "A100"], seed=7, **kw)
    b = spot_schedule(["A100", "H100"], seed=7, **kw)
    assert a.events == b.events          # order-insensitive, seed-stable
    assert a.events != spot_schedule(["H100", "A100"], seed=8, **kw).events
    assert all(0.0 <= e.time <= 60.0 for e in a.events)
    # per type, losses and recoveries alternate starting with a loss
    for gpu in ("H100", "A100"):
        kinds = [e.kind for e in sorted(a.events, key=lambda e: e.time)
                 if e.gpu_type == gpu]
        assert all(k == "recover" if i % 2 else k != "recover"
                   for i, k in enumerate(kinds))
    graceful = spot_schedule(["H100"], horizon=60.0, seed=7, mtbf_s=8.0,
                             mttr_s=8.0, reclaim_frac=1.0, grace_s=3.0)
    assert all(e.kind == "reclaim" and e.grace == 3.0
               for e in graceful.events if e.kind != "recover")


# ------------------------------------------- satellites: spec validation

def test_spec_availability_validation():
    def spec(avail):
        return DeploymentSpec(models=[TINY], workload=_trace(1),
                              catalog={GPU: _replica().stages[0].device},
                              availability=avail, budget=10.0)
    with pytest.raises(ValueError):
        spec({GPU: -1})
    with pytest.raises(ValueError):
        spec({GPU: 1.5})
    with pytest.raises(ValueError):
        spec({GPU: True})           # bools are not device counts
    with pytest.raises(ValueError):
        spec({GPU: "four"})
    s = spec({GPU: np.int64(4)})    # numpy ints normalize to plain ints
    assert s.availability == {GPU: 4}
    assert type(s.availability[GPU]) is int


def test_with_availability_rejects_unknown_gpu_types():
    s = DeploymentSpec(models=[TINY], workload=_trace(1),
                       catalog={GPU: _replica().stages[0].device},
                       availability={GPU: 2}, budget=10.0)
    assert s.with_availability({GPU: 1}).availability == {GPU: 1}
    with pytest.raises(ValueError, match="unknown GPU type"):
        s.with_availability({"H100-typo": 4})


def test_watcher_tracks_availability_and_replans():
    cfg = _replica()
    trace = _trace(2)
    w = _tiny_watcher(cfg, trace, n=2)
    assert w.availability == {GPU: 2}
    w.observe(FaultEvent(time=1.0, kind="crash", gpu_type=GPU))
    assert w.availability == {GPU: 1}
    w.observe(FaultEvent(time=2.0, kind="crash", gpu_type=GPU, count=5))
    assert w.availability == {GPU: 0}        # clamped at zero
    with pytest.raises(ValueError):
        w.replan(_plan([cfg], 2))            # planner refuses an empty pool
    w.observe(FaultEvent(time=3.0, kind="recover", gpu_type=GPU, count=9))
    assert w.availability == {GPU: 2}        # clamped at the base snapshot
    new = w.replan(_plan([cfg], 2))
    assert len(new.replicas) == 2 and w.replans == 1
    w.reset()
    assert w.availability == {GPU: 2} and w.replans == 0


def test_retry_budget_validation():
    cfg = _replica()
    with pytest.raises(ValueError):
        ServingRuntime(_plan([cfg], 1), CostModelExecutor([cfg], [TINY]),
                       retry_budget=-1)


# -------------------------------------------- unit: symbolic KV migration

def test_manager_export_import_swapped():
    src = KVCacheManager(num_blocks=5, block_size=BS, host_blocks=4)
    dst = KVCacheManager(num_blocks=5, block_size=BS, host_blocks=4)
    assert src.admit(0, 31, solo=True)          # 2 blocks
    assert src.swap_out(0) == 2
    blocks = src.export_swapped(0)
    assert blocks == 2 and src.host_used_blocks == 0
    assert src.export_swapped(0) == 0           # already exported
    assert dst.import_swapped(0, blocks)
    assert dst.host_used_blocks == 2
    assert not dst.import_swapped(0, blocks)    # duplicate rejected
    assert dst.swap_in(0, 31, solo=True)
    assert (src.swap_exports, dst.swap_imports) == (1, 1)
    tight = KVCacheManager(num_blocks=5, block_size=BS, host_blocks=1)
    assert not tight.import_swapped(1, 2)       # over the host budget
    assert not tight.import_swapped(1, 0)       # nothing to adopt


# ------------------------------------- integration (cost): reclaim / crash

def _catalog_spec(n_requests=40):
    from repro.core import GPU_CATALOG, LLAMA3_70B, make_trace
    trace = make_trace("trace1", n_requests, arrival_rate=20.0, seed=0)
    return DeploymentSpec(models=[LLAMA3_70B], workload=trace,
                          catalog=GPU_CATALOG,
                          availability={"A100": 8, "H100": 4}, budget=40.0)


def _serve_catalog(spec, faults, *, retry_budget=2, watch=True,
                   preempt_mode="swap", host_blocks=256, obs=None):
    from repro.core import plan as plan_spec
    p = plan_spec(spec)
    executor = CostModelExecutor(p, host_blocks=host_blocks)
    runtime = ServingRuntime(p, executor, preempt_mode=preempt_mode,
                             retry_budget=retry_budget, obs=obs)
    injector = as_injector(faults)
    if watch and injector.watcher is None:
        injector = FaultInjector(FaultPlan(list(faults.events)),
                                 watcher=AvailabilityWatcher(spec))
    return runtime.run(spec.workload, faults=injector), runtime


def test_graceful_reclaim_zero_loss_cost():
    spec = _catalog_spec()
    fp = FaultPlan([FaultEvent(time=0.5, kind="reclaim", gpu_type="H100",
                               grace=5.0)])
    res, runtime = _serve_catalog(spec, fp)
    assert res.num_completed == spec.workload.num_requests
    assert res.num_failed == 0 and res.num_retries == 0
    assert res.info["fault_log"] == [(0.5, "reclaim", "H100", (2,))]
    assert res.info["fault_reclaims"] == 1.0
    assert res.info["swap_migrations"] > 0
    assert res.info["fault_replans"] == 1.0
    assert res.info["watcher_replans"] == 1.0
    dead = [e for e in res.info["per_replica"] if e["dead"]]
    assert [e["replica"] for e in dead] == [2]
    assert dead[0]["dead_at"] == 0.5
    assert runtime.replicas[2].dead and runtime.replicas[2].draining


def test_crash_and_recover_requeues_within_budget():
    spec = _catalog_spec()
    fp = FaultPlan([
        FaultEvent(time=0.5, kind="crash", gpu_type="H100"),
        FaultEvent(time=3.0, kind="recover", gpu_type="H100"),
    ])
    res, _ = _serve_catalog(spec, fp)
    assert res.num_completed == spec.workload.num_requests
    assert res.num_failed == 0
    assert res.num_retries > 0                  # crash re-serves work
    assert res.info["requests_requeued"] > 0
    assert res.info["fault_crashs"] == 1.0
    assert res.info["fault_recovers"] == 1.0
    assert res.info["watcher_replans"] == 2.0   # shrink, then grow back
    # the log records the recover with no victims
    kinds = [(kind, victims) for _, kind, _, victims in
             res.info["fault_log"]]
    assert ("recover", ()) in kinds


def test_no_recovery_baseline_loses_requests():
    spec = _catalog_spec()
    fp = FaultPlan([FaultEvent(time=0.5, kind="crash", gpu_type="H100")])
    res, _ = _serve_catalog(spec, fp, retry_budget=0, watch=False)
    assert res.num_failed > 0
    assert res.num_completed < spec.workload.num_requests
    assert res.num_completed + res.num_failed == spec.workload.num_requests
    assert res.info["requests_orphaned"] > 0
    for r in res.records:
        if r.failed:
            assert not r.done and r.phase.name != "DONE"


# ----------------------------- acceptance: identical logs on both backends

def _run_faulted(executor, plan, trace, watcher, fault_time, kind,
                 grace=0.0, **rt_kw):
    runtime = ServingRuntime(plan, executor, **rt_kw)
    fp = FaultPlan([FaultEvent(time=fault_time, kind=kind, gpu_type=GPU,
                               grace=grace)])
    injector = FaultInjector(fp, watcher=watcher)
    res = runtime.run(trace, faults=injector)
    return res, runtime, injector


def _engine_executor(plan, **kw):
    from repro.configs import get_config
    from repro.runtime import EngineExecutor
    return EngineExecutor(plan, [get_config("llama3-8b").reduced()],
                          models=[TINY], max_batch=8, input_len=8,
                          max_new=5, fused_steps=1, **kw)


def test_fault_schedule_identical_logs_cost_vs_engine():
    pytest.importorskip("jax")
    trace = _trace(n=4)
    cfg = _replica()
    outs = {}
    for backend in ("cost", "engine"):
        plan = _plan([cfg, cfg], trace.num_requests)
        executor = (CostModelExecutor([cfg, cfg], [TINY])
                    if backend == "cost" else _engine_executor(plan))
        res, runtime, injector = _run_faulted(
            executor, plan, trace, _tiny_watcher(cfg, trace, 2),
            fault_time=0.0, kind="crash")
        assert res.num_completed == trace.num_requests
        outs[backend] = (list(injector.log),
                         list(runtime.replicas[0].admission_log),
                         {r.req.req_id: r.retries for r in res.records})
    assert outs["cost"] == outs["engine"]


# ------------------- acceptance: byte-identical streams (engine backend)

def _engine_fault_run(trace, fault_time=None, kind="reclaim", grace=1e6,
                      retry_budget=2):
    from repro.obs import TickClock
    cfg = _replica()
    plan = _plan([cfg, cfg], trace.num_requests)
    executor = _engine_executor(plan, host_blocks=16, clock=TickClock())
    if fault_time is None:
        runtime = ServingRuntime(plan, executor, preempt_mode="swap")
        res = runtime.run(trace)
        return res, executor
    res, _, _ = _run_faulted(
        executor, plan, trace, _tiny_watcher(_replica(), trace, 2),
        fault_time, kind, grace=grace, preempt_mode="swap",
        retry_budget=retry_budget)
    return res, executor


def test_graceful_reclaim_streams_byte_identical_engine():
    """Acceptance: under a mid-run reclaim with a grace window, every
    affected request's token stream equals the fault-free run's stream
    exactly — the KV migrated to a surviving replica of the same model,
    so decode resumes with no re-prefill and no token drift."""
    pytest.importorskip("jax")
    trace = _trace(n=4)
    base_res, base_ex = _engine_fault_run(trace)
    assert base_res.num_completed == trace.num_requests
    makespan = max(r.finished_at for r in base_res.records)
    res, ex = _engine_fault_run(trace, fault_time=makespan / 2)
    assert res.num_completed == trace.num_requests
    assert res.num_failed == 0 and res.num_retries == 0
    assert res.info["swap_migrations"] > 0
    assert res.info.get("swap_migrations_failed", 0.0) == 0.0
    for rid in base_ex.token_log:
        assert list(ex.token_log[rid]) == list(base_ex.token_log[rid])


def test_crash_recovery_streams_are_tails_engine():
    """An ungraceful crash re-serves lost work from the prompt: the
    fault-free stream must be a byte-identical *tail* of the recovered
    stream (the recompute replays prefill, duplicating early tokens)."""
    pytest.importorskip("jax")
    trace = _trace(n=4)
    base_res, base_ex = _engine_fault_run(trace)
    makespan = max(r.finished_at for r in base_res.records)
    res, ex = _engine_fault_run(trace, fault_time=makespan / 2,
                                kind="crash", grace=0.0)
    assert res.num_completed == trace.num_requests
    assert res.num_retries > 0
    retried = {r.req.req_id for r in res.records if r.retries}
    assert retried
    for rid, base_log in base_ex.token_log.items():
        log = list(ex.token_log[rid])
        base_log = list(base_log)
        assert log[-len(base_log):] == base_log
        if rid in retried:
            assert len(log) > len(base_log)     # replayed prefill tokens
        else:
            assert log == base_log


# ------------------------------------ worker failure: structured, not hung

class _FlakyCostExecutor(CostModelExecutor):
    """Raises once from replica 1's first prefill (a died device call)."""

    armed = True

    def prefill(self, rep, states):
        if rep == 1 and self.armed:
            self.armed = False
            raise RuntimeError("injected device fault")
        return super().prefill(rep, states)


def test_worker_exception_becomes_structured_failure():
    trace = _trace(n=4)
    cfg = _replica()
    plan = _plan([cfg, cfg], trace.num_requests)
    runtime = ServingRuntime(plan, _FlakyCostExecutor([cfg, cfg], [TINY]))
    res = runtime.run(trace)
    assert res.info["worker_failures"] == 1.0
    assert runtime.replicas[1].dead
    assert res.num_completed + res.num_failed == trace.num_requests
    assert res.num_completed > 0                # survivors keep serving


def test_worker_call_timeout_unit():
    worker = ReplicaWorker("test-timeout", call_timeout=0.05)
    fut = worker.submit(lambda: time.sleep(1.0) or "late")
    with pytest.raises(WorkerTimeout):
        fut.result(timeout=5.0)
    assert not worker.alive                     # marked dead for rebuild
    with pytest.raises(RuntimeError):
        worker.submit(lambda: None)
    ok = ReplicaWorker("test-fast", call_timeout=5.0)
    assert ok.submit(lambda: 42).result(timeout=5.0) == 42
    ok.close()


# ----------------------------------------- live session: failed handles

class _HangingCostExecutor(CostModelExecutor):
    """Concurrent cost executor whose replica-1 calls wedge (a reclaimed
    accelerator that stops answering) — exercised through the actor
    workers so ``worker_timeout`` turns the hang into a WorkerTimeout."""

    concurrent = True

    def prefill(self, rep, states):
        if rep == 1:
            time.sleep(2.0)
        return super().prefill(rep, states)


def test_live_session_retry_exhausted_handle_fails():
    from repro.serving import serve
    cfg = _replica()
    plan = _plan([cfg, cfg], 2)
    session = serve(plan, executor=_HangingCostExecutor([cfg, cfg], [TINY]),
                    retry_budget=0, worker_timeout=0.2)
    with session:
        served = session.submit(input_len=30, output_len=4)   # replica 0
        doomed = session.submit(input_len=30, output_len=4)   # replica 1
        state = doomed.result(timeout=30.0)
        assert state is not None and state.failed
        assert doomed.failed and not doomed.done
        assert doomed.retries == 1
        assert list(doomed.tokens(timeout=5.0)) == []   # terminates empty
        assert served.result(timeout=30.0).done
    res = session.result
    assert res.num_failed == 1 and res.num_completed == 1
    assert res.info["worker_failures"] == 1.0


def test_session_replay_accepts_fault_plan():
    from repro.serving import Session
    trace = _trace(n=4)
    cfg = _replica()
    plan = _plan([cfg, cfg], trace.num_requests)
    session = Session(plan, CostModelExecutor([cfg, cfg], [TINY]))
    fp = FaultPlan([FaultEvent(time=0.0, kind="crash", gpu_type=GPU)])
    res = session.replay(trace, faults=FaultInjector(
        fp, watcher=_tiny_watcher(cfg, trace, 2)))
    assert res.num_completed == trace.num_requests
    assert res.info["fault_crashs"] == 1.0
    clean = session.replay(trace)               # fault plan does not stick
    assert "fault_log" not in clean.info


# --------------------------------------- trace summary: fault columns

def _load_summarizer():
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "tools"))
    import trace_summarize
    return trace_summarize


def test_trace_summarize_fault_columns_synthetic():
    tsz = _load_summarizer()
    doc = {"traceEvents": [
        {"ph": "M", "name": "thread_name", "tid": 0,
         "args": {"name": "replica-0 cfg"}},
        {"ph": "X", "tid": 0, "ts": 0.0, "dur": 2e6, "cat": "decode",
         "name": "decode[1]"},
        {"ph": "i", "tid": 0, "ts": 1e6, "name": "dead", "cat": "fault",
         "args": {"replica": 0}},
        {"ph": "i", "tid": 1000, "ts": 1e6, "name": "fault-crash",
         "cat": "fault", "args": {"kind": "crash", "gpu_type": "H100",
                                  "victims": [0]}},
        {"ph": "i", "tid": 1000, "ts": 1.5e6, "name": "request-failed",
         "cat": "fault", "args": {"req_id": 3, "retries": 2}},
    ]}
    s = tsz.summarize(doc)
    rep = s["replicas"][0]
    assert rep["faults"] == 1
    assert rep["dead_at_s"] == 1.0
    assert rep["downtime_s"] == pytest.approx(1.0)    # t_end(2.0) - dead
    assert s["requests_failed"] == 1
    text = tsz.format_summary(s)
    assert "down-s" in text and "fault-crash" in text
    assert "req 3 after 2 retries" in text


def test_trace_summarize_cross_checks_runtime_info(tmp_path):
    from repro.obs import Observability
    tsz = _load_summarizer()
    spec = _catalog_spec()
    obs = Observability()
    fp = FaultPlan([FaultEvent(time=0.5, kind="crash", gpu_type="H100")])
    res, runtime = _serve_catalog(spec, fp, retry_budget=0, watch=False,
                                  obs=obs)
    path = runtime.export_trace(str(tmp_path / "faults.json"))
    s = tsz.summarize(tsz.load_trace(path))
    assert sum(r["faults"] for r in s["replicas"]) \
        == res.info["replicas_lost"]
    assert s["requests_failed"] == res.info["requests_failed"]
    dead = [r for r in s["replicas"] if r["faults"]]
    assert dead and all(r["downtime_s"] > 0 for r in dead)
    injected = [c for c in s["faults"] if c["name"].startswith("fault-")]
    assert len(injected) == res.info["faults_injected"]
