"""Config-enumeration tests (App D constraints & heuristics)."""
import numpy as np
import pytest

from repro.core.catalog import GPU_CATALOG
from repro.core.configspace import (enumerate_configs, prune_dominated,
                                    throughput_table)
from repro.core.costmodel import LLAMA3_8B, LLAMA3_70B
from repro.core.workloads import WORKLOAD_TYPES


def test_memory_check_excludes_too_small_configs():
    """App D (i): every enumerated config can hold the model."""
    avail = {"4090": 8, "A40": 8}
    cfgs = enumerate_configs(LLAMA3_70B, GPU_CATALOG, avail)
    need = LLAMA3_70B.min_memory_bytes()
    for c in cfgs:
        assert sum(st.memory for st in c.stages) >= need
    # a single 24GB 4090 config must not appear for a 70B model
    assert all(c.num_devices > 1 or c.stages[0].device.name != "4090"
               for c in cfgs)


def test_availability_respected():
    avail = {"H100": 3}
    cfgs = enumerate_configs(LLAMA3_70B, GPU_CATALOG, avail)
    for c in cfgs:
        assert c.device_counts().get("H100", 0) <= 3


def test_tp_within_machine():
    """App D heuristic (i): TP never exceeds devices_per_machine."""
    avail = {name: 16 for name in GPU_CATALOG}
    cfgs = enumerate_configs(LLAMA3_8B, GPU_CATALOG, avail)
    for c in cfgs:
        for st in c.stages:
            assert st.tp <= st.device.devices_per_machine


def test_nonuniform_pp_layer_split_proportional_to_memory():
    """App D heuristic (ii): stage layer fractions follow stage memory."""
    avail = {"H100": 2, "A40": 4}
    cfgs = enumerate_configs(LLAMA3_70B, GPU_CATALOG, avail)
    mixed = [c for c in cfgs if len({st.device.name for st in c.stages}) > 1]
    assert mixed, "mixed-type pipelines must be enumerated"
    for c in mixed:
        mems = np.array([st.memory for st in c.stages])
        fracs = np.array([st.layer_frac for st in c.stages])
        np.testing.assert_allclose(fracs, mems / mems.sum(), rtol=1e-6)
        np.testing.assert_allclose(fracs.sum(), 1.0, rtol=1e-6)


def test_connectivity_constraint():
    """Disconnected type pairs never share a pipeline."""
    avail = {"H100": 4, "A40": 4}
    disconnected = lambda a, b: a == b   # nothing inter-connects
    cfgs = enumerate_configs(LLAMA3_70B, GPU_CATALOG, avail,
                             connected=disconnected)
    for c in cfgs:
        assert len({st.device.name for st in c.stages}) == 1


def test_prune_dominated_keeps_pareto_front():
    avail = {"H100": 8, "A40": 8}
    cfgs = enumerate_configs(LLAMA3_70B, GPU_CATALOG, avail)
    h = throughput_table(cfgs, WORKLOAD_TYPES)
    kept, h_kept = prune_dominated(cfgs, h)
    assert 0 < len(kept) <= len(cfgs)
    # no kept config is dominated by another kept config
    costs = [c.cost for c in kept]
    for i in range(len(kept)):
        for j in range(len(kept)):
            if i == j:
                continue
            dominates = (costs[j] <= costs[i] + 1e-9
                         and np.all(h_kept[j] >= h_kept[i] - 1e-9)
                         and (costs[j] < costs[i] - 1e-9
                              or np.any(h_kept[j] > h_kept[i] + 1e-9)))
            assert not dominates, (i, j)
    # every dropped config is dominated by some kept one
    kept_keys = {c.key for c in kept}
    for i, c in enumerate(cfgs):
        if c.key in kept_keys or h[i].max() <= 1e-9:
            continue
        assert any(kept[j].cost <= c.cost + 1e-9
                   and np.all(h_kept[j] >= h[i] - 1e-9)
                   for j in range(len(kept))), c.key
