"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(≤1 period of layers, d_model ≤ 256, ≤4 experts), run one forward/train step
and one prefill+decode step on CPU, assert output shapes and absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M

SEQ = 32
BATCH = 2


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch(request):
    cfg = get_config(request.param).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_train_step(arch):
    cfg, params = arch
    batch = M.synthetic_batch(cfg, BATCH, SEQ, jax.random.PRNGKey(1))

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p_: M.loss_fn(cfg, p_, b), has_aux=True)(p)
        new_p = jax.tree.map(lambda w, g: w - 1e-3 * g.astype(w.dtype),
                             p, grads)
        return loss, new_p

    loss, new_params = step(params, batch)
    assert jnp.isfinite(loss), f"{cfg.name}: non-finite loss"
    # params actually changed
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                           params, new_params)
    assert any(jax.tree.leaves(changed)), f"{cfg.name}: no param updated"


def test_forward_shapes_and_finite(arch):
    cfg, params = arch
    batch = M.synthetic_batch(cfg, BATCH, SEQ, jax.random.PRNGKey(2))
    from repro.models import transformer as T
    logits, aux = jax.jit(
        lambda p, t, pe: T.forward(cfg, p, t, prefix_embeds=pe)
    )(params, batch["tokens"], batch.get("prefix_embeds"))
    n_prefix = cfg.num_patches if cfg.frontend != "none" else 0
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size) if n_prefix == 0 else \
        logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{cfg.name}: NaN logits"
    if cfg.logit_softcap > 0:
        assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-3


def test_prefill_then_decode(arch):
    """prefill + N greedy decode steps run and stay finite."""
    cfg, params = arch
    batch = M.synthetic_batch(cfg, BATCH, SEQ, jax.random.PRNGKey(3))
    prefix = batch.get("prefix_embeds")
    t_max = SEQ + 8
    logits, caches = jax.jit(
        lambda p, t, pe: M.prefill(cfg, p, t, pe, t_max=t_max)
    )(params, batch["tokens"], prefix)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step = jax.jit(lambda p, c, tok, pos: M.decode_step(cfg, p, c, tok, pos))
    tok = M.greedy_sample(logits[:, -1])
    n_prefix = prefix.shape[1] if prefix is not None else 0
    pos = jnp.asarray(SEQ - n_prefix + n_prefix, jnp.int32) * 0 + (
        batch["tokens"].shape[1] + n_prefix)
    for i in range(3):
        logits_d, caches = step(params, caches, tok, pos + i)
        assert logits_d.shape == (BATCH, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits_d))), f"{cfg.name}: NaN decode"
        tok = M.greedy_sample(logits_d)


def test_decode_matches_forward(arch, monkeypatch):
    """Teacher-forced decode logits == full forward logits, position by
    position (validates cache correctness for every mixer kind)."""
    cfg, params = arch
    if cfg.frontend != "none":
        pytest.skip("prefix archs covered by test_prefill_then_decode")
    # No-drop capacity: forward and decode see different token counts, so
    # capacity-based drops would legitimately diverge; disable them here.
    from repro.models import moe as moe_mod
    monkeypatch.setattr(moe_mod, "CAPACITY_FACTOR", 1e9)
    s = 12
    tokens = jax.random.randint(jax.random.PRNGKey(4), (BATCH, s), 0,
                                cfg.vocab_size)
    from repro.models import transformer as T
    full_logits, _ = T.forward(cfg, params, tokens)

    # prefill on the first half, decode the second half teacher-forced
    half = s // 2
    _, caches = M.prefill(cfg, params, tokens[:, :half], t_max=s + 1)
    step = jax.jit(lambda p, c, tok, pos: M.decode_step(cfg, p, c, tok, pos))
    for i in range(half, s):
        logits_d, caches = step(params, caches, tokens[:, i],
                                jnp.asarray(i, jnp.int32))
        # decode_step consumed token i and predicts i+1 == full_logits[:, i]
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(full_logits[:, i], np.float32),
            rtol=0.05, atol=0.05,
        )


def test_full_config_instantiable():
    """The FULL configs must construct and report sane param counts
    (no allocation — arithmetic only)."""
    expected = {
        "codeqwen1.5-7b": (6e9, 9e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "starcoder2-3b": (2.5e9, 4e9),
        "gemma2-27b": (22e9, 30e9),
        "mixtral-8x22b": (125e9, 150e9),
        "chatglm3-6b": (5e9, 8e9),
        "musicgen-large": (1.2e9, 2.5e9),
        "internvl2-1b": (0.4e9, 1.2e9),
        "xlstm-125m": (0.08e9, 0.3e9),
    }
    for name, (lo, hi) in expected.items():
        cfg = get_config(name)
        n = cfg.param_count()
        assert lo <= n <= hi, f"{name}: param_count {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"
        assert cfg.active_param_count() <= n
