"""Cross-request prefix caching tests: allocator refcount/CoW/LRU
invariants, prefix-aware symbolic admission, refcount-aware preemption,
router warm-prefix affinity, suffix jit bucketing, and the engine-level
acceptance checks (warm token streams byte-identical to cold runs;
cost-model and engine backends make identical admission decisions on
shared-prefix traces with the cache enabled on both).

The property tests run as seeded randomized operation sequences (the
container has no ``hypothesis``; the invariants are the same ones a
``@given`` harness would drive, exercised across many seeds).
"""
import numpy as np
import pytest

from repro.core import costmodel
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage, config_throughput
from repro.core.plan import Config, ServingPlan
from repro.core.workloads import (Request, Trace, make_shared_prefix_trace,
                                  nearest_workload)
from repro.runtime import CostModelExecutor, ServingRuntime
from repro.runtime.kvcache import BlockAllocator, KVCacheManager, hash_blocks
from repro.runtime.router import AssignmentRouter

BS = 16
TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)
BLOCK_BYTES = BS * TINY.kv_bytes_per_token


def _replica(num_blocks: int) -> Config:
    free = (num_blocks + 0.5) * BLOCK_BYTES
    mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
           / costmodel.MEMORY_UTIL)
    dev = DeviceType("kv-test", 1e12, 1e11, mem, 1.0, 8, 1e11, 1e9, "x")
    return Config(stages=(Stage(dev, 1, 1.0),), model_index=0, model=TINY)


def _plan(config: Config, n_requests: int, replicas: int = 1) -> ServingPlan:
    return ServingPlan(replicas=[config] * replicas,
                       assignment=np.ones((replicas, 1)) / replicas,
                       demands=[(0, 0, float(n_requests))], makespan=1.0,
                       cost=config.cost * replicas)


# ------------------------------------------------------------- unit: hashes

def test_prefix_hash_blocks_chained_and_capped():
    p = list(range(40))
    h = hash_blocks(p, BS)
    assert len(h) == 2                       # two full 16-token blocks
    assert h == hash_blocks(p[:35], BS)      # same full blocks, same names
    q = list(p)
    q[3] = 999                               # diverge inside block 0
    h2 = hash_blocks(q, BS)
    assert h2[0] != h[0] and h2[1] != h[1]   # chained: all downstream differ
    r = list(p)
    r[20] = 999                              # diverge inside block 1 only
    h3 = hash_blocks(r, BS)
    assert h3[0] == h[0] and h3[1] != h[1]
    # the match cap always leaves >= 1 suffix token to prefill
    assert len(hash_blocks(p[:32], BS, max_match_tokens=31)) == 1
    assert hash_blocks(p, BS) == hash_blocks(tuple(p), BS)  # dtype-agnostic


# ------------------------------------------ property: allocator invariants

def _allocator_invariants(a: BlockAllocator, n: int):
    free = set(a._free)
    live = set(a._refs)
    lru = set(a._lru)
    assert free.isdisjoint(live), "block both free and referenced"
    assert free.isdisjoint(lru), "block both free and cached"
    assert lru.isdisjoint(live), "cached block still referenced"
    assert len(free) + len(live) + len(lru) == n, "blocks leaked"
    assert all(a._hash_of.get(i) is not None for i in lru), \
        "unhashed block parked in the cached pool"
    for h, i in a._index.items():
        assert a._hash_of.get(i) == h, "index/hash_of disagree"
    assert all(r >= 1 for r in a._refs.values())


def test_prefix_allocator_random_ops_property():
    """Random alloc/free/commit/adopt/cow sequences: no block is ever both
    free and referenced, LRU eviction only reclaims refcount-0 blocks, and
    the free/live/cached partition never leaks a block."""
    N = 24
    for seed in range(10):
        rng = np.random.default_rng(seed)
        a = BlockAllocator(N, first_id=1)
        owned = []            # simulated request block lists
        hashes = []           # hashes ever committed
        next_h = iter(range(1_000_000, 2_000_000))
        for step in range(300):
            op = int(rng.integers(0, 5))
            if op == 0:       # alloc a small request
                k = int(rng.integers(1, 4))
                if k <= a.available_blocks:
                    live_before = set(a._refs)
                    ids = a.alloc(k)
                    # eviction for this alloc never touched a live block
                    assert live_before <= set(a._refs)
                    owned.append(ids)
            elif op == 1 and owned:     # free a request
                ids = owned.pop(int(rng.integers(0, len(owned))))
                a.free(ids)
            elif op == 2 and owned:     # commit one owned block
                ids = owned[int(rng.integers(0, len(owned)))]
                i = ids[int(rng.integers(0, len(ids)))]
                if a.block_hash(i) is None:
                    h = next(next_h)
                    assert a.commit(i, h) == i
                    hashes.append(h)
            elif op == 3 and hashes:    # adopt a committed hash
                h = hashes[int(rng.integers(0, len(hashes)))]
                i = a.adopt(h)
                if i is not None:
                    owned.append([i])
            elif op == 4 and owned:     # cow one owned block
                r = int(rng.integers(0, len(owned)))
                j = int(rng.integers(0, len(owned[r])))
                i = owned[r][j]
                if a.writable(i) or a.available_blocks >= 1:
                    new, copied = a.cow(i)
                    owned[r][j] = new
                    assert a.writable(new) or not copied
            _allocator_invariants(a, N)
        for ids in owned:
            a.free(ids)
        _allocator_invariants(a, N)
        assert a.used_blocks == 0


def test_prefix_allocator_adopt_revives_and_evicts_lru():
    a = BlockAllocator(4, first_id=1)
    ids = a.alloc(2)
    a.commit(ids[0], 111)
    a.commit(ids[1], 222)
    a.free(ids)
    assert (a.free_blocks, a.cached_blocks, a.used_blocks) == (2, 2, 0)
    got = a.adopt(111)                       # revive from the cached pool
    assert got == ids[0] and a.ref_count(got) == 1
    assert a.cache_hits == 1
    big = a.alloc(3)                         # 2 free + 1 eviction (222)
    assert a.evictions == 1 and a.adopt(222) is None
    assert a.adopt(111) == ids[0] and a.ref_count(ids[0]) == 2
    a.free(big)
    a.free([got, ids[0]])
    assert a.used_blocks == 0


def test_prefix_allocator_cow_semantics():
    a = BlockAllocator(4, first_id=1)
    (i,) = a.alloc(1)
    assert a.writable(i)
    assert a.cow(i) == (i, False)            # private block: no copy
    a.commit(i, 7)
    assert not a.writable(i)                 # committed => immutable
    new, copied = a.cow(i)
    assert copied and new != i and a.writable(new)
    assert a.ref_count(i) == 0 and i in a._lru   # old parked, still indexed
    j = a.adopt(7)
    assert j == i
    a.free([new, j])
    assert a.used_blocks == 0


# ----------------------------------------------- unit: prefix-aware manager

def _p(n, seed=0):
    return tuple(int(t) for t in
                 np.random.default_rng(seed).integers(0, 1000, n))


def test_prefix_manager_warm_admission_reserves_suffix_only():
    m = KVCacheManager(num_blocks=20, block_size=BS, prefix_cache=True)
    prompt = _p(48)
    assert m.admit(0, 49, prompt=prompt)     # cold: 4 blocks (49 tokens)
    assert m.used_blocks == 4
    assert m.prefix_hit_tokens(0) == 0
    m.free(0)                                # 2 full blocks park in the LRU
    assert m.used_blocks == 0 and m.cached_blocks == 2
    assert m.cached_prefix_tokens(prompt, 49) == 32
    assert m.admit(1, 49, prompt=prompt)     # warm: revives 2, adds 2
    assert m.prefix_hit_tokens(1) == 32
    assert m.used_blocks == 4 and m.cached_blocks == 0
    # a third request sharing only block 0's worth of tokens
    other = prompt[:BS] + _p(32, seed=9)
    assert m.admit(2, 49, prompt=other)
    assert m.prefix_hit_tokens(2) == BS
    assert m.used_blocks == 7                # 1 shared + 3 new
    assert m.prefix_hit_rate > 0
    m.free(1)
    m.free(2)
    assert m.used_blocks == 0


def test_prefix_manager_preemption_respects_refcounts():
    """Freeing a preempted request never reclaims blocks shared with live
    requests, ``held_blocks`` reports only what eviction would reclaim,
    and readmission re-resolves the prefix index."""
    m = KVCacheManager(num_blocks=20, block_size=BS, prefix_cache=True)
    prompt = _p(48)
    assert m.admit(0, 49, prompt=prompt)
    assert m.admit(1, 49, prompt=prompt)     # shares 2 blocks with req 0
    assert m.used_blocks == 6                # 4 + 2 unique
    assert m.held_blocks(0) == 2             # 2 of its 4 are shared
    assert m.held_blocks(1) == 2
    m.free(0)                                # "preempt" req 0
    assert m.used_blocks == 4                # shared blocks stay: req 1 lives
    assert m.cached_blocks == 0              # nothing parked (still refed)
    assert m.held_blocks(1) == 4             # req 1 now sole holder
    assert m.admit(0, 49, prompt=prompt)     # readmission hits the index
    assert m.prefix_hit_tokens(0) == 32
    assert m.used_blocks == 6
    m.free(0)
    m.free(1)
    assert m.used_blocks == 0 and m.cached_blocks == 2


def test_prefix_manager_lru_eviction_under_pressure():
    m = KVCacheManager(num_blocks=6, block_size=BS, prefix_cache=True)
    a, b = _p(32, seed=1), _p(32, seed=2)
    assert m.admit(0, 33, prompt=a)          # 3 blocks, 1 full cached-able
    m.free(0)
    assert m.cached_blocks == 1
    assert m.admit(1, 33, prompt=b)          # different prefix: cold
    assert m.admit(2, 33, prompt=b)          # warm on b: 3 + 2 = 5 used
    assert m.used_blocks == 5
    assert m.cached_prefix_tokens(a, 33) == BS   # a's block still parked
    # pool pressure: growth must evict a's cached block, never b's live ones
    assert m.grow(1, 49)                     # +1 block -> 6 used, pool full
    assert m.prefix_evictions == 1
    assert m.cached_blocks == 0
    assert m.cached_prefix_tokens(a, 33) == 0    # evicted from the index
    assert m.cached_prefix_tokens(b, 33) == BS   # live shared block survives
    assert m.used_blocks == 6 <= m.num_blocks
    m.free(1)
    m.free(2)
    assert m.used_blocks == 0


def test_prefix_manager_cache_off_matches_legacy_arithmetic():
    """With the pool disabled, admission on a shared-prefix workload is
    byte-identical to the legacy count-only arithmetic — prompts are
    ignored entirely (cached-hit admission ≡ cold admission)."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        off = KVCacheManager(num_blocks=12, block_size=BS)
        legacy = KVCacheManager(num_blocks=12, block_size=BS)
        shared = _p(64, seed=seed)
        held = []
        for step in range(120):
            op = int(rng.integers(0, 3))
            if op == 0:
                rid = step
                tokens = int(rng.integers(1, 80))
                solo = not held
                r1 = off.admit(rid, tokens, solo=solo, prompt=shared)
                r2 = legacy.admit(rid, tokens, solo=solo)
                assert r1 == r2
                if r1:
                    held.append((rid, tokens))
            elif op == 1 and held:
                rid, tokens = held[int(rng.integers(0, len(held)))]
                g = int(rng.integers(1, 30))
                assert (off.grow(rid, tokens + g)
                        == legacy.grow(rid, tokens + g))
            elif op == 2 and held:
                rid, _ = held.pop(int(rng.integers(0, len(held))))
                off.free(rid)
                legacy.free(rid)
            assert off.used_blocks == legacy.used_blocks
            assert off.peak_used == legacy.peak_used
        assert off.prefix_hit_rate == 0.0 and off.cached_blocks == 0


# -------------------------------------------------- unit: cost-model knob

def test_prefix_hit_rate_discounts_costmodel_prefill():
    cfg = _replica(num_blocks=50)
    w = __import__("repro.core.workloads", fromlist=["WORKLOAD_TYPES"]
                   ).WORKLOAD_TYPES[0]
    cold = config_throughput(cfg.stages, TINY, w)
    warm = config_throughput(cfg.stages, TINY, w, prefix_hit_rate=0.9)
    assert warm > cold                       # cheaper prefill -> more req/s
    assert config_throughput(cfg.stages, TINY, w, prefix_hit_rate=0.0) == cold
    with pytest.raises(ValueError):
        config_throughput(cfg.stages, TINY, w, prefix_hit_rate=1.5)


# ------------------------------------------------ unit: shared-prefix trace

def test_prefix_trace_generator_shapes_and_sharing():
    tr = make_shared_prefix_trace("sp", 40, input_len=48, output_len=4,
                                  prefix_pool_size=2, prefix_len=32,
                                  hit_ratio=1.0, vocab=500, seed=3)
    assert tr.num_requests == 40
    prefixes = {r.prompt[:32] for r in tr.requests}
    assert len(prefixes) <= 2                # every prompt from the pool
    assert all(len(r.prompt) == 48 for r in tr.requests)
    suffixes = [r.prompt[32:] for r in tr.requests]
    assert len(set(suffixes)) > 30           # suffixes unique-ish
    cold = make_shared_prefix_trace("sp", 40, input_len=48, output_len=4,
                                    prefix_pool_size=2, prefix_len=32,
                                    hit_ratio=0.0, vocab=500, seed=3)
    assert len({r.prompt[:32] for r in cold.requests}) == 40
    # per-pool length distribution + clamping
    td = make_shared_prefix_trace("sp", 8, input_len=16, output_len=2,
                                  prefix_len=[8, 64], hit_ratio=1.0, seed=0)
    assert all(1 <= len(r.prompt) == 16 for r in td.requests)
    assert tr.requests[0].workload == nearest_workload(48, 4)


# -------------------------------------------------- unit: router affinity

def test_prefix_router_affinity_prefers_warm_replica():
    cfg = _replica(num_blocks=50)
    plan = _plan(cfg, 4, replicas=2)
    prompt = _p(48)
    warm_mgr = KVCacheManager(num_blocks=50, block_size=BS,
                              prefix_cache=True)
    warm_mgr.admit(0, 49, prompt=prompt)
    mgrs = [KVCacheManager(num_blocks=50, block_size=BS, prefix_cache=True),
            warm_mgr]

    def affinity(j, req):
        return mgrs[j].cached_prefix_tokens(req.prompt, req.input_len + 1)

    req = Request(req_id=9, workload=0, input_len=48, output_len=4,
                  arrival=0.0, prompt=prompt)
    cold_req = Request(req_id=10, workload=0, input_len=48, output_len=4,
                       arrival=0.0, prompt=_p(48, seed=5))
    # plain DRR would send the first request to replica 0; warmth wins
    assert AssignmentRouter(plan).route(req) == 0
    router = AssignmentRouter(plan, prefix_affinity=affinity)
    assert router.route(req) == 1
    # all-cold requests degenerate to DRR (replica 0 is owed one now)
    assert router.route(cold_req) == 0


def test_prefix_runtime_routes_to_warm_replica_and_reports_stats():
    """Live-session routing: a recorded trace is dispatched upfront (all
    replicas cold at routing time), but online submissions route after
    earlier requests published their prefix blocks — warm-prefix affinity
    then overrides DRR's alternating split and pins the shared pool's
    prefix to the replica it first landed on."""
    from repro.serving.session import Session
    cfg = _replica(num_blocks=50)
    executor = CostModelExecutor([cfg, cfg], [TINY], prefix_cache=True)
    session = Session(_plan(cfg, 12, replicas=2), executor)
    rng = np.random.default_rng(1)
    prefix = [int(t) for t in rng.integers(0, 1000, 32)]
    for _ in range(12):
        suffix = [int(t) for t in rng.integers(0, 1000, 16)]
        h = session.submit(prefix + suffix, output_len=4)
        h.result(timeout=60)        # wait: next submit routes against
    res = session.close(timeout=60)  # published warmth, not a cold pool
    assert res.num_completed == 12
    assert res.info["prefix_hit_rate"] > 0
    rates = [r["prefix_hit_rate"] for r in res.info["per_replica"]]
    assert all(v is not None for v in rates)
    served = [r["completed"] for r in res.info["per_replica"]]
    assert sorted(served) == [0, 12]


# -------------------------------------------- unit: suffix jit bucketing

def test_prefix_suffix_bucket_is_pow2_on_suffix_length():
    from repro.serving.engine import (MIN_SUFFIX_BUCKET, bucket_suffix,
                                      bucket_t_max)
    assert MIN_SUFFIX_BUCKET == 8
    assert bucket_suffix(1) == 8
    assert bucket_suffix(5) == bucket_suffix(7) == bucket_suffix(8) == 8
    assert bucket_suffix(9) == 16
    assert bucket_t_max(17) == 32            # full-prompt floor unchanged


def test_prefix_suffix_prefill_matches_cold_and_shares_jit_bucket():
    """The warm suffix-only prefill produces the same greedy first token
    as a cold full-prompt prefill of the identical prompt, and distinct
    suffix lengths inside one bucket share a single compiled entry."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.runtime.kvcache.paged import PagedEngineCache
    from repro.serving import engine as E

    cfg = get_config("llama3-8b").reduced()
    eng = E.ReplicaEngine(cfg, seed=0)
    paged = PagedEngineCache(cfg, num_slots=2, t_max=24, block_size=8,
                             prefix_cache=True)
    rng = np.random.default_rng(0)
    base = rng.integers(0, cfg.vocab_size, 16)
    rows = {s: np.concatenate([base[:8],
                               rng.integers(0, cfg.vocab_size, s)])
            for s in (5, 7, 8)}
    # cold-prefill the prefix owner and publish its full block
    h0 = paged.block_hashes(base, 16)
    assert len(h0) == 1
    tok, caches = eng.prefill_batch(jnp.asarray(base[None], jnp.int32), 16)
    paged.admit_cohort([1], caches, np.asarray(tok), 16,
                       block_hashes_per_req=[h0])
    keys_before = [k for k in E._shared_jit_cache
                   if k[0] == "prefill_suffix"]
    for rid, (s, row) in enumerate(sorted(rows.items()), start=2):
        t_prompt = 8 + s
        hs = paged.block_hashes(row, t_prompt)
        assert paged.match_len(hs) == 1
        pref = paged.adopt_prefix(hs[:1])
        tables = jnp.asarray(np.asarray([pref], np.int32))
        warm_tok, suf = eng.prefill_suffix_batch(
            jnp.asarray(row[None, 8:], jnp.int32), paged.pools, tables, 8)
        cold_tok, _ = eng.prefill_batch(
            jnp.asarray(row[None], jnp.int32), t_prompt)
        assert int(np.asarray(warm_tok)[0]) == int(np.asarray(cold_tok)[0])
        paged.admit_prefixed([rid], [pref], suf, np.asarray(warm_tok),
                             8, t_prompt, [hs])
        paged.release(rid)
    keys_after = [k for k in E._shared_jit_cache
                  if k[0] == "prefill_suffix"]
    # suffix lengths 5, 7, 8 all bucket to 8: exactly one new compilation
    assert len(set(keys_after) - set(keys_before)) == 1
    assert paged.physical_hit_requests == 3
    paged.release(1)
    assert paged.allocator.used_blocks == 0
    assert paged.allocator.cached_blocks >= 1


# --------------------------------- acceptance: engine warm ≡ cold streams

def _engine_runtime(trace, cfg, *, prefix_cache, num_requests, replicas=1,
                    input_len=16, max_new=6, max_batch=4, num_blocks=50):
    from repro.configs import get_config
    from repro.runtime import EngineExecutor
    plan = _plan(cfg, num_requests, replicas=replicas)
    executor = EngineExecutor(plan, [get_config("llama3-8b").reduced()],
                              models=[TINY], max_batch=max_batch,
                              input_len=input_len, max_new=max_new,
                              prefix_cache=prefix_cache)
    runtime = ServingRuntime(plan, executor)
    res = runtime.run(trace)
    return executor, runtime, res


def test_prefix_warm_token_streams_identical_to_cold_run():
    """Acceptance: the same shared-prefix trace served with the prefix
    cache on and off produces byte-identical per-request token trails —
    aliasing cached blocks and prefilling only suffixes changes compute,
    never tokens."""
    pytest.importorskip("jax")
    cfg = _replica(num_blocks=50)
    trace = make_shared_prefix_trace("sp", 6, input_len=48, output_len=4,
                                     prefix_pool_size=1, prefix_len=32,
                                     hit_ratio=1.0, arrival_rate=None,
                                     seed=2)
    cold_ex, cold_rt, cold_res = _engine_runtime(
        trace, cfg, prefix_cache=False, num_requests=6)
    warm_ex, warm_rt, warm_res = _engine_runtime(
        trace, cfg, prefix_cache=True, num_requests=6)
    assert cold_res.num_completed == warm_res.num_completed == 6
    assert warm_ex.token_log == cold_ex.token_log
    assert (warm_rt.replicas[0].admission_log
            == cold_rt.replicas[0].admission_log)
    paged = warm_ex._paged[0]
    assert paged is not None and paged.physical_hit_requests > 0
    assert paged.allocator.used_blocks == 0         # everything released
    mgr = warm_ex.kv_manager(0)
    assert mgr.prefix_hits > 0 and mgr.prefix_hit_rate > 0
    assert warm_res.info["prefix_hit_rate"] > 0


# ------------------- acceptance: backend equivalence + preemption, cache on

def test_prefix_backends_identical_admissions_under_preemption():
    """Acceptance: a shared-prefix trace that forces preemption, served
    with the prefix cache enabled on BOTH backends — identical admission
    cohorts (including readmissions) and identical preemption counts; the
    engine's preempted requests re-resolve the prefix index through real
    refcounted blocks and every physical block is freed at the end."""
    pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.runtime import EngineExecutor

    # input 62 + 1 + 4 outputs crosses a 4th->5th block boundary mid-decode
    # (65 tokens at BS=16), so concurrent warm requests that fit at
    # admission (3-block shared prefix, 1-block deltas) outgrow the pool
    cfg = _replica(num_blocks=7)
    trace = make_shared_prefix_trace("sp", 4, input_len=62, output_len=4,
                                     prefix_pool_size=1, prefix_len=48,
                                     hit_ratio=1.0, seed=4)
    plan = _plan(cfg, 4)

    cost_ex = CostModelExecutor([cfg], [TINY], prefix_cache=True)
    cost_rt = ServingRuntime(plan, cost_ex)
    cost_res = cost_rt.run(trace)

    eng_ex = EngineExecutor(plan, [get_config("llama3-8b").reduced()],
                            models=[TINY], max_batch=8, input_len=16,
                            max_new=5, prefix_cache=True)
    eng_rt = ServingRuntime(plan, eng_ex)
    eng_res = eng_rt.run(trace)

    assert cost_res.num_completed == eng_res.num_completed == 4
    assert (cost_rt.replicas[0].admission_log
            == eng_rt.replicas[0].admission_log)
    cost_pre = {r.req.req_id: r.preemptions for r in cost_res.records}
    eng_pre = {r.req.req_id: r.preemptions for r in eng_res.records}
    assert cost_pre == eng_pre
    assert cost_res.num_preemptions > 0
    assert cost_ex.kv_manager(0).prefix_hits > 0
    paged = eng_ex._paged[0]
    assert paged is not None
    assert paged.allocator.used_blocks == 0
    assert cost_ex.kv_manager(0).used_blocks == 0
    assert eng_ex.kv_manager(0).used_blocks == 0
