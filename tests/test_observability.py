"""Observability tests: metrics primitives, trace-export schema, the
pure-observer contract (byte-identical serving with tracing on vs off,
on both backends), and the trace-summary CLI cross-check.
"""
import json
import math
import os
import sys

import numpy as np
import pytest

from repro.core import costmodel
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage
from repro.core.plan import Config, ServingPlan
from repro.core.workloads import Request, Trace
from repro.obs import (CONTROL_TRACK, MetricsRegistry, Observability,
                       TickClock, Tracer)
from repro.obs.export import chrome_trace, prometheus_text
from repro.runtime import CostModelExecutor, ServingRuntime

BS = 16
TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)
BLOCK_BYTES = BS * TINY.kv_bytes_per_token


def _replica(num_blocks: int) -> Config:
    free = (num_blocks + 0.5) * BLOCK_BYTES
    mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
           / costmodel.MEMORY_UTIL)
    dev = DeviceType("obs-test", 1e12, 1e11, mem, 1.0, 8, 1e11, 1e9, "x")
    return Config(stages=(Stage(dev, 1, 1.0),), model_index=0, model=TINY)


def _plan(config: Config, n_requests: int, replicas: int = 1) -> ServingPlan:
    return ServingPlan(replicas=[config] * replicas,
                       assignment=np.full((replicas, 1), 1.0 / replicas),
                       demands=[(0, 0, float(n_requests))], makespan=1.0,
                       cost=config.cost * replicas)


def _trace(n, input_len=20, output_len=4, stagger=0.02):
    return Trace("obs", tuple(
        Request(req_id=i, workload=0, input_len=input_len,
                output_len=output_len, arrival=stagger * i)
        for i in range(n)))


def _cost_run(n=12, replicas=2, num_blocks=50, obs=None, **trace_kw):
    cfg = _replica(num_blocks)
    plan = _plan(cfg, n, replicas=replicas)
    runtime = ServingRuntime(plan, CostModelExecutor([cfg] * replicas,
                                                     [TINY]), obs=obs)
    return runtime, runtime.run(_trace(n, **trace_kw))


# ------------------------------------------------------------- primitives

def test_tick_clock_deterministic_monotone():
    clk = TickClock(tick=0.5, start=1.0)
    assert [clk() for _ in range(3)] == [1.5, 2.0, 2.5]
    assert clk.now == 2.5
    with pytest.raises(ValueError):
        TickClock(tick=0.0)


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry(series_capacity=4)
    c = reg.counter("requests_total", replica="0")
    c.inc()
    c.inc(2)
    assert c.value == 3
    assert reg.counter("requests_total", replica="0") is c   # same identity

    g = reg.gauge("queue_depth")
    g.set(5, t=0.1)
    g.set(7, t=0.2)
    assert g.value == 7
    assert g.series.items() == [(0.1, 5.0), (0.2, 7.0)]

    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count == 3 and h.sum == pytest.approx(5.55)
    assert h.mean == pytest.approx(1.85)
    assert h.quantile(0.5) == 1.0           # second obs falls in le=1.0
    assert h.quantile(0.99) == math.inf     # third is beyond every bound


def test_ring_series_drops_oldest():
    reg = MetricsRegistry(series_capacity=3)
    g = reg.gauge("x")
    for i in range(5):
        g.set(i, t=float(i))
    assert g.series.items() == [(2.0, 2.0), (3.0, 3.0), (4.0, 4.0)]
    assert g.series.appended == 5 and g.series.dropped == 2


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("n", replica="0")
    with pytest.raises(TypeError):
        reg.gauge("n", replica="0")
    reg.gauge("n", replica="1")             # different label set is fine


def test_snapshot_keys_and_histogram_stats():
    reg = MetricsRegistry()
    reg.counter("done_total").inc(4)
    reg.gauge("depth", replica="1").set(2.0, t=1.0)
    reg.histogram("lat_s").observe(0.3)
    snap = reg.snapshot()
    assert snap["done_total"] == 4
    assert snap['depth{replica="1"}'] == 2.0
    assert snap["lat_s"]["count"] == 1
    assert snap["lat_s"]["mean"] == pytest.approx(0.3)
    assert reg.series() == {'depth{replica="1"}': [(1.0, 2.0)]}


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("admissions_total", replica="0").inc(3)
    reg.gauge("queue_depth").set(2.0, t=0.1)
    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(5.0)
    text = prometheus_text(reg)
    lines = text.splitlines()
    assert "# TYPE admissions_total counter" in lines
    assert "# TYPE queue_depth gauge" in lines
    assert "# TYPE lat_s histogram" in lines
    counter = [l for l in lines
               if l.startswith('admissions_total{replica="0"}')]
    assert len(counter) == 1 and float(counter[0].split()[-1]) == 3.0
    buckets = [l for l in lines if l.startswith("lat_s_bucket{")]
    assert len(buckets) == 3                # 2 bounds + +Inf, cumulative
    assert float(buckets[-1].split()[-1]) == 2.0
    assert 'le="+Inf"' in buckets[-1]
    assert float([l for l in lines
                  if l.startswith("lat_s_count")][0].split()[-1]) == 2.0


# ----------------------------------------------------- trace export schema

def _valid_chrome_doc(doc, n_requests):
    assert isinstance(doc["traceEvents"], list)
    events = doc["traceEvents"]
    json.loads(json.dumps(doc))                       # JSON-serializable

    names = {e["tid"]: e["args"]["name"] for e in events
             if e.get("ph") == "M" and e.get("name") == "thread_name"}
    assert names[CONTROL_TRACK] == "control-plane"
    assert any(n.startswith("replica-0") for n in names.values())

    # per-replica X spans: required fields, non-negative dur, and no
    # overlap on one replica's serving-time track
    by_tid = {}
    for e in events:
        if e.get("ph") == "X" and e["tid"] < CONTROL_TRACK:
            assert e["dur"] >= 0 and "cat" in e and "name" in e
            by_tid.setdefault(e["tid"], []).append(e)
    assert by_tid, "no replica spans"
    for spans in by_tid.values():
        spans.sort(key=lambda e: e["ts"])
        for a, b in zip(spans, spans[1:]):
            assert b["ts"] >= a["ts"] + a["dur"] - 0.5    # 0.5us slack

    # request-lifecycle async pairs balance per request id
    per_id = {}
    for e in events:
        if e.get("ph") in ("b", "e"):
            assert e.get("cat") == "request"
            d = per_id.setdefault(e["id"], {"b": 0, "e": 0})
            d[e["ph"]] += 1
    assert len(per_id) == n_requests
    assert all(d["b"] == d["e"] and d["b"] >= 2 for d in per_id.values())

    # gauge ring series surface as counter tracks
    assert any(e.get("ph") == "C" for e in events)
    ts = [e["ts"] for e in events]
    assert ts == sorted(ts)                           # body sorted by time
    return names


def test_chrome_trace_schema_and_file_export(tmp_path):
    obs = Observability()
    runtime, res = _cost_run(n=10, replicas=2, obs=obs)
    assert res.num_completed == 10
    doc = chrome_trace(obs)
    _valid_chrome_doc(doc, n_requests=10)

    path = tmp_path / "trace.json"
    out = runtime.export_trace(str(path))
    assert out == str(path)
    assert json.loads(path.read_text())["traceEvents"]


def test_export_trace_requires_observability():
    runtime, _ = _cost_run(n=2, replicas=1, obs=None)
    with pytest.raises(RuntimeError, match="observability"):
        runtime.export_trace("nowhere.json")


def test_preemptions_traced_and_counted():
    """KV-overflow run: preempt instants + counters match the result."""
    obs = Observability()
    _, res = _cost_run(n=3, replicas=1, num_blocks=5, obs=obs,
                       input_len=30, output_len=4, stagger=0.0)
    assert res.num_preemptions > 0
    snap = obs.snapshot()
    assert snap['preemptions_total{replica="0"}'] == res.num_preemptions
    doc = chrome_trace(obs)
    instants = [e for e in doc["traceEvents"]
                if e.get("ph") == "i" and e.get("name") == "preempt"]
    assert len(instants) == res.num_preemptions


def test_metrics_snapshot_contents_cost_run():
    obs = Observability()
    _, res = _cost_run(n=12, replicas=2, obs=obs)
    snap = obs.snapshot()
    assert snap["routed_total"] == 12
    completed = sum(v for k, v in snap.items()
                    if k.startswith("completed_total"))
    assert completed == res.num_completed
    assert snap["ttft_s"]["count"] == 12
    assert snap["latency_s"]["count"] == 12
    assert snap['queue_depth{replica="0"}'] == 0.0    # drained
    assert snap["serving_time_s"] > 0
    assert snap["trace_records"] == obs.tracer.num_records > 0


# --------------------------------------------- pure-observer equivalence

def _cost_logs(obs):
    runtime, res = _cost_run(n=12, replicas=2, obs=obs)
    return ([r.admission_log for r in runtime.replicas],
            {r.req.req_id: (r.finished_at, r.preemptions)
             for r in res.records})


def test_on_off_equivalence_cost_backend():
    assert _cost_logs(None) == _cost_logs(Observability())


def test_on_off_equivalence_engine_backend():
    pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.runtime import EngineExecutor

    def logs(obs):
        cfg = _replica(num_blocks=50)
        n = 4
        plan = _plan(cfg, n)
        executor = EngineExecutor(plan, [get_config("llama3-8b").reduced()],
                                  models=[TINY], max_batch=2, input_len=8,
                                  max_new=5, fused_steps=8,
                                  clock=TickClock())
        runtime = ServingRuntime(plan, executor, obs=obs)
        res = runtime.run(_trace(n, output_len=4))
        assert res.num_completed == n
        return (executor.token_log,
                [r.admission_log for r in runtime.replicas])

    assert logs(None) == logs(Observability())


# ------------------------------------------------------- trace summarize

def test_trace_summarize_matches_runtime_accounting(tmp_path):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "tools"))
    import trace_summarize as tsum
    obs = Observability()
    runtime, res = _cost_run(n=12, replicas=2, obs=obs)
    path = tmp_path / "t.json"
    runtime.export_trace(str(path))
    s = tsum.summarize(tsum.load_trace(str(path)))

    info = {row["replica"]: row for row in res.info["per_replica"]}
    assert len(s["replicas"]) == len(info)
    for i, row in enumerate(s["replicas"]):
        assert row["busy_s"] == pytest.approx(info[i]["busy_s"], abs=1e-6)
        assert row["completed"] == info[i]["completed"]
    assert s["routes"] == 12 and s["drops"] == 0
    text = tsum.format_summary(s)
    assert "replica-0" in text and "routed: 12" in text

    assert tsum.main([str(path)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert tsum.main([str(bad)]) == 1


# -------------------------------------------------------- session surface

def test_session_metrics_live_and_export(tmp_path):
    import repro
    cfg = _replica(num_blocks=50)
    plan = _plan(cfg, 4, replicas=1)
    with repro.serve(plan, backend="cost", models=[TINY],
                     observability=True) as session:
        handles = [session.submit(workload=0, input_len=8, output_len=2)
                   for _ in range(4)]
        for h in handles:
            h.result(timeout=60)
        snap = session.metrics()            # live, mid-session
        assert snap["routed_total"] == 4
    path = tmp_path / "session.json"
    session.export_trace(str(path))
    assert json.loads(path.read_text())["traceEvents"]


def test_session_metrics_requires_observability():
    import repro
    cfg = _replica(num_blocks=50)
    session = repro.serve(_plan(cfg, 2), backend="cost", models=[TINY])
    with pytest.raises(RuntimeError, match="observability"):
        session.metrics()


# ------------------------------------------------- control-plane tracing

def test_control_plane_hooks_in_trace():
    obs = Observability()
    obs.begin_run(_plan(_replica(50), 1))
    obs.on_replan(1.0, ["a"], ["a", "b"], migrated=2, kept=1)

    class _Decision:
        action, config_key, reason = "add", "cfg", "queue_high"

        class plan:
            replicas = ()
    obs.on_scale_decision(2.0, _Decision(), ["a"])
    obs.on_scale_observe(2.5, queue_depth=3.0, kv_util=0.5)

    doc = chrome_trace(obs)
    control = [e for e in doc["traceEvents"]
               if e.get("tid") == CONTROL_TRACK and e.get("ph") == "i"]
    by_cat = {e["cat"] for e in control}
    assert {"run", "replan", "autoscale"} <= by_cat
    replan = next(e for e in control if e["cat"] == "replan")
    assert replan["args"]["before"] == ["a"]
    assert replan["args"]["after"] == ["a", "b"]
    snap = obs.snapshot()
    assert snap["replans_total"] == 1
    assert snap['autoscale_total{action="add"}'] == 1
    assert snap["autoscale_queue_depth"] == 3.0


def test_tracer_worker_tracks():
    obs = Observability()
    obs.begin_run(_plan(_replica(50), 1))
    obs.on_worker_task("replica-0", obs.wall_start + 0.1,
                       obs.wall_start + 0.2)
    obs.on_worker_task("replica-1", obs.wall_start + 0.1,
                       obs.wall_start + 0.3)
    obs.on_worker_task("replica-0", obs.wall_start + 0.4,
                       obs.wall_start + 0.5)
    doc = chrome_trace(obs)
    wall = [e for e in doc["traceEvents"]
            if e.get("ph") == "X" and e.get("cat") == "wall"]
    assert len(wall) == 3
    assert len({e["tid"] for e in wall}) == 2      # one track per worker


def test_tracer_record_counts_and_clear():
    tr = Tracer()
    tr.track(0, "replica-0")
    tr.span(0, "prefill", 0.0, 1.0, cat="prefill")
    tr.instant(0, "done", 1.0)
    tr.async_span(7, "queued", 0.0, 0.5)
    assert tr.num_records == 4          # span + instant + b/e pair counts 2
    tr.clear()
    assert tr.num_records == 0
