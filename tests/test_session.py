"""Session API tests: arrival sources (TraceSource byte-identical to the
historical trace loop, LiveSource wall-clock semantics), live submit /
token streaming equivalence against ``EngineExecutor.token_log``, and the
server's persistent-runtime lifecycle."""
import math
import threading
import time
import warnings

import numpy as np
import pytest

import repro
from repro.core import GPU_CATALOG, make_trace
from repro.core.costmodel import ModelProfile
from repro.core.scheduler import _solve
from repro.runtime import (CostModelExecutor, LiveSource, ServingRuntime,
                           SLO, TraceSource)

TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)


@pytest.fixture(scope="module")
def small_plan():
    trace = make_trace("trace1", num_requests=24, arrival_rate=50.0, seed=0)
    plan = _solve([TINY], trace, GPU_CATALOG,
                  {"A40": 4, "4090": 4, "H100": 2}, budget=8.0)
    return plan, trace


@pytest.fixture(scope="module")
def tiny_cfg():
    from repro.configs import get_config
    return get_config("llama3-8b").reduced()


def _exact_schedule(result):
    return {r.req.req_id: (r.replica, r.admitted_at, r.first_token_at,
                           r.finished_at, r.preemptions)
            for r in result.records}


# ------------------------------------------------------------ TraceSource

def test_trace_source_byte_identical_to_run(small_plan):
    """run(trace) is a thin wrapper over run_source(TraceSource(trace)):
    both paths must produce byte-identical schedules and admission logs
    on the cost backend (the acceptance bar for the source refactor)."""
    plan, trace = small_plan
    rt_a = ServingRuntime(plan, CostModelExecutor(plan.replicas, [TINY]))
    a = rt_a.run(trace)
    rt_b = ServingRuntime(plan, CostModelExecutor(plan.replicas, [TINY]))
    b = rt_b.run_source(TraceSource(trace))
    assert _exact_schedule(a) == _exact_schedule(b)
    assert a.makespan == b.makespan
    assert ([r.admission_log for r in rt_a.replicas]
            == [r.admission_log for r in rt_b.replicas])
    np.testing.assert_array_equal(a.latencies, b.latencies)


def test_trace_source_interface(small_plan):
    _, trace = small_plan
    src = TraceSource(trace)
    src.start()
    assert not src.exhausted()
    assert src.first_arrival() == min(r.arrival for r in trace.requests)
    got = src.take_until(math.inf)
    assert [s.req.req_id for s in got] \
        == [r.req_id for r in sorted(trace.requests, key=lambda q: q.arrival)]
    assert src.exhausted()
    assert src.take_until(math.inf) == []


# ------------------------------------------------------------- LiveSource

def test_live_source_stamps_and_orders():
    src = LiveSource(clock=time.monotonic)
    src.start()
    s1 = src.submit(lambda t: _state(0, t))
    s2 = src.submit(lambda t: _state(1, t))
    assert 0.0 <= s1.req.arrival <= s2.req.arrival
    assert [s.req.req_id for s in src.take_until(math.inf)] == [0, 1]
    assert not src.exhausted()        # open: more may come
    src.close()
    assert src.exhausted()
    with pytest.raises(RuntimeError):
        src.submit(lambda t: _state(2, t))


def test_live_source_wait_wakes_on_submit():
    src = LiveSource()
    src.start()
    seen = src.version()
    woke = []

    def waiter():
        woke.append(src.wait(seen, timeout=5.0))

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    src.submit(lambda ts: _state(0, ts))
    t.join(timeout=5.0)
    assert woke == [True]
    # a version observed before the submit returns immediately
    assert src.wait(seen, timeout=0.0)


def _state(rid, arrival, *, model=0, workload=0):
    from repro.core.workloads import Request
    from repro.runtime import RequestState
    return RequestState(req=Request(req_id=rid, workload=workload,
                                    input_len=16, output_len=4,
                                    arrival=arrival, model=model))


# ------------------------------------------------- live session (cost)

def test_session_cost_backend_completes(small_plan):
    plan, trace = small_plan
    with repro.serve(plan, backend="cost", models=[TINY]) as session:
        handles = [session.submit(workload=r.workload,
                                  input_len=r.input_len,
                                  output_len=r.output_len)
                   for r in trace.requests[:12]]
        recs = [h.result(timeout=30) for h in handles]
    result = session.result
    assert result.num_completed == 12
    assert all(r.done for r in recs)
    assert all(list(h.tokens()) == [] for h in handles)   # no tokens: cost
    for h in handles:
        assert math.isfinite(h.ttft) and h.ttft >= 0
        assert h.latency >= h.ttft


def test_session_unroutable_request_fails_fast(small_plan):
    plan, _ = small_plan
    with repro.serve(plan, backend="cost", models=[TINY, TINY]) as session:
        ok = session.submit(workload=0)
        alien = session.submit(workload=0, model=1)   # no model-1 replica
        alien_rec = alien.result(timeout=30)
        ok.result(timeout=30)
    assert alien.failed and not alien_rec.done
    assert ok.done
    assert session.result.dropped == 1


def test_session_slo_scoring(small_plan):
    plan, _ = small_plan
    with repro.serve(plan, backend="cost", models=[TINY],
                     slo=SLO(ttft=1e9)) as session:
        loose = session.submit(workload=0)
        tight = session.submit(workload=0, slo=SLO(ttft=1e-12))
        loose.result(timeout=30), tight.result(timeout=30)
    assert loose.slo_met() is True
    assert tight.slo_met() is False


def test_session_close_is_idempotent_and_reports(small_plan):
    plan, _ = small_plan
    session = repro.serve(plan, backend="cost", models=[TINY])
    session.submit(workload=0).result(timeout=30)
    r1 = session.close(timeout=30)
    r2 = session.close()
    assert r1 is r2 is session.result
    assert r1.num_completed == 1
    with pytest.raises(RuntimeError):
        session.submit(workload=0)


# ----------------------------------------------- live session (engine)

@pytest.mark.parametrize("concurrent", [False, True])
def test_streaming_matches_token_log(small_plan, tiny_cfg, concurrent):
    """Satellite: tokens yielded by RequestHandle.tokens() must exactly
    equal EngineExecutor.token_log per request, and the handle's TTFT
    (available once the first token streamed) must equal the record's
    metric — under both the plain event loop and concurrent execution."""
    plan, trace = small_plan
    session = repro.serve(plan, arch_cfgs=[tiny_cfg], input_len=8,
                          max_new=4, max_batch=8, concurrent=concurrent)
    handles = [session.submit(workload=r.workload, input_len=r.input_len,
                              output_len=r.output_len)
               for r in trace.requests]
    streams = [list(h.tokens(timeout=120)) for h in handles]
    session.close(timeout=120)
    log = session.executor.token_log
    assert set(log) == {h.req_id for h in handles}
    for h, stream in zip(handles, streams):
        assert stream == log[h.req_id]
        assert len(stream) >= 1                      # first token streamed
        rec = h.result()
        assert h.ttft == rec.first_token_at - rec.req.arrival
        assert math.isfinite(h.ttft) and h.ttft >= 0
    assert session.result.num_completed == trace.num_requests


def test_live_session_matches_trace_replay_tokens(small_plan, tiny_cfg):
    """Acceptance: a LiveSource session submitting the trace's requests at
    their arrival times completes all of them with per-request token
    streams identical to the trace replay on the engine backend."""
    plan, trace = small_plan
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.serving import HeterogeneousServer
        server = HeterogeneousServer(plan, [tiny_cfg], max_batch=8)
        server.serve(trace, input_len=8, max_new=4)
    replay_log = {k: list(v) for k, v in server.executor.token_log.items()}

    session = repro.serve(plan, arch_cfgs=[tiny_cfg], input_len=8,
                          max_new=4, max_batch=8)
    t0 = time.monotonic()
    handles = []
    for req in sorted(trace.requests, key=lambda q: q.arrival):
        lag = req.arrival - (time.monotonic() - t0)
        if lag > 0:
            time.sleep(lag)
        handles.append(session.submit(workload=req.workload,
                                      input_len=req.input_len,
                                      output_len=req.output_len))
    streams = [list(h.tokens(timeout=120)) for h in handles]
    result = session.close(timeout=120)
    assert result.num_completed == trace.num_requests
    # submit order == trace arrival order, so req_ids line up 1:1
    assert all(streams[i] == replay_log[i] for i in range(len(handles)))
    for h in handles:
        assert h.ttft >= 0        # wall-clock submit -> first-token latency


def test_session_prompt_override_changes_tokens(small_plan, tiny_cfg):
    plan, _ = small_plan
    with repro.serve(plan, arch_cfgs=[tiny_cfg], input_len=8, max_new=4,
                     max_batch=8) as session:
        a = session.submit("hello heterogeneous world", workload=0,
                           input_len=16, output_len=3)
        b = session.submit(workload=0, input_len=16, output_len=3)
        sa, sb = list(a.tokens(timeout=120)), list(b.tokens(timeout=120))
    assert len(sa) == len(sb) == 4            # prefill + 3 decode steps
    assert sa != sb                           # the prompt steered the tokens
    assert session.executor.prompt_overrides == {}   # released at completion


# ------------------------------------------------- server lifecycle

def test_server_reuses_runtime_across_serves(small_plan, tiny_cfg):
    """Satellite: HeterogeneousServer.serve must reuse one persistent
    ServingRuntime across calls (reset, not rebuild), with results
    identical call over call."""
    plan, trace = small_plan
    with pytest.warns(DeprecationWarning, match="HeterogeneousServer"):
        from repro.serving import HeterogeneousServer
        server = HeterogeneousServer(plan, [tiny_cfg], max_batch=8)
    st1 = server.serve(trace, input_len=8, max_new=4)
    rt1 = server.runtime
    log1 = {k: list(v) for k, v in server.executor.token_log.items()}
    st2 = server.serve(trace, input_len=8, max_new=4)
    assert server.runtime is rt1              # reused, not rebuilt
    assert server.last_runtime is rt1         # legacy alias stays truthful
    log2 = server.executor.token_log
    assert log1 == log2               # identical token streams run over run
    # the clock is *measured* wall time (run 1 pays jit compiles), so
    # timestamps differ — routing and completions must not
    assert ({r.req.req_id: r.replica for r in st1.result.records}
            == {r.req.req_id: r.replica for r in st2.result.records})
    assert st1.completed == st2.completed == trace.num_requests
    # switching drive mode is the one thing that rebuilds
    server.serve(trace, input_len=8, max_new=4, mode="sequential")
    assert server.runtime is not rt1


def test_session_replay_resets_state(small_plan):
    plan, trace = small_plan
    session = repro.Session(plan,
                            CostModelExecutor(plan.replicas, [TINY]))
    a = session.replay(trace)
    b = session.replay(trace)
    assert _exact_schedule(a) == _exact_schedule(b)
    assert a.num_completed == trace.num_requests


def test_session_replay_trims_replan_replicas_cost_backend(small_plan):
    """A replay whose replan added executor replicas must not leak them
    into the next run (replica indices would misalign)."""
    from repro.core.plan import ServingPlan
    from repro.runtime import ReplanEvent
    plan, trace = small_plan
    executor = CostModelExecutor(plan.replicas, [TINY])
    session = repro.Session(plan, executor)
    base_n = len(executor.configs)
    grown = ServingPlan(replicas=list(plan.replicas) * 2,
                        assignment=np.vstack([plan.assignment] * 2) / 2,
                        demands=plan.demands, makespan=plan.makespan,
                        cost=plan.cost * 2)
    session.replay(trace, replan=ReplanEvent(time=0.05, plan=grown))
    assert len(executor.configs) > base_n          # replan grew the pool
    plain = session.replay(trace)
    assert len(executor.configs) == base_n         # trimmed on reset
    fresh = repro.Session(plan, CostModelExecutor(plan.replicas, [TINY])
                          ).replay(trace)
    assert _exact_schedule(plain) == _exact_schedule(fresh)


def test_concurrent_first_submits_share_one_loop(small_plan):
    """Racing first submits from many threads must start exactly one
    serving loop/source, and every handle must complete."""
    plan, _ = small_plan
    session = repro.serve(plan, backend="cost", models=[TINY])
    handles: list = [None] * 16
    barrier = threading.Barrier(len(handles))

    def submit_one(i):
        barrier.wait()
        handles[i] = session.submit(workload=0)

    threads = [threading.Thread(target=submit_one, args=(i,))
               for i in range(len(handles))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    for h in handles:
        assert h.result(timeout=30).done
    res = session.close(timeout=30)
    assert res.num_completed == len(handles)


def test_handle_without_state_reports_failed():
    """A handle finished before its request was built (serve-loop crash
    path) must report failed, not raise."""
    from repro.serving.session import RequestHandle
    h = RequestHandle(session=None)
    h._finish()
    assert h.failed and not h.done
    assert h.result(timeout=1) is None
    assert list(h.tokens()) == []


def test_session_replay_resets_engine_state(small_plan, tiny_cfg):
    """Back-to-back engine replays must not accumulate token trails or
    generation counters from the previous run."""
    plan, trace = small_plan
    session = repro.serve(plan, arch_cfgs=[tiny_cfg], input_len=8,
                          max_new=4, max_batch=8)
    session.replay(trace)
    log1 = {k: list(v) for k, v in session.executor.token_log.items()}
    gen1 = session.executor.generated_tokens
    session.replay(trace)
    assert session.executor.token_log == log1    # not doubled
    assert session.executor.generated_tokens == gen1


def test_session_live_after_replay_streams_cleanly(small_plan, tiny_cfg):
    """replay() then live submit(): the live run must start from clean
    state (fresh clocks, empty token trails) with streaming re-attached."""
    plan, trace = small_plan
    session = repro.serve(plan, arch_cfgs=[tiny_cfg], input_len=8,
                          max_new=4, max_batch=8)
    session.replay(trace)
    assert len(session.executor.token_log) == trace.num_requests
    h = session.submit(workload=0, output_len=3)
    stream = list(h.tokens(timeout=120))
    session.close(timeout=120)
    assert stream == session.executor.token_log[0]   # sink re-attached,
    assert len(stream) == 4                          # trails reset (req 0
    rec = h.result()                                 # is the live request)
    assert rec.done and rec.req.arrival < 1.0        # fresh wall clock


def test_session_replay_allowed_after_drain(small_plan):
    """A drained session is explicitly valid for replay (the error message
    says 'fresh or drained')."""
    plan, trace = small_plan
    session = repro.serve(plan, backend="cost", models=[TINY])
    session.submit(workload=0).result(timeout=30)
    session.close(timeout=30)
    res = session.replay(trace)
    assert res.num_completed == trace.num_requests


def test_serve_preserves_prebuilt_executor_scale(small_plan, tiny_cfg):
    """serve(executor=...) must not clobber the scale the caller built
    into the executor with serve()'s own defaults."""
    from repro.runtime import EngineExecutor
    plan, _ = small_plan
    ex = EngineExecutor(plan, [tiny_cfg], models=[TINY], max_batch=8,
                        input_len=32, max_new=16, seed=7)
    session = repro.serve(plan, executor=ex)
    assert ex.input_len == 32 and ex.max_new == 16 and ex._seed == 7
    session.close(timeout=30)
    # explicit arguments still win
    ex2 = EngineExecutor(plan, [tiny_cfg], models=[TINY], max_batch=8,
                         input_len=32, max_new=16)
    repro.serve(plan, executor=ex2, input_len=8, max_new=4).close(timeout=30)
    assert ex2.input_len == 8 and ex2.max_new == 4


def test_session_releases_completed_handles(small_plan):
    """A long-lived session must not hold one handle per served request."""
    plan, _ = small_plan
    with repro.serve(plan, backend="cost", models=[TINY]) as session:
        handles = [session.submit(workload=0) for _ in range(8)]
        for h in handles:
            h.result(timeout=30)
        assert session._handles == {}     # popped at completion
    # consumers' own references still work after release
    assert all(h.done for h in handles)
