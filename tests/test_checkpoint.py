"""Checkpoint round-trip + corruption-detection tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.training import AdamW, init_state, make_train_step, data_stream
from repro.training.checkpoint import restore, save


def test_roundtrip_train_state(tmp_path):
    cfg = get_config("chatglm3-6b").reduced()
    opt = AdamW(lr=1e-3)
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = next(data_stream(cfg, 2, 16, seed=0))
    state, _ = step(state, batch)

    path = str(tmp_path / "ckpt.npz")
    save(path, state, step=7)
    restored, at_step = restore(path, state)
    assert at_step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    # training continues identically from the restored state
    s1, m1 = step(state, batch)
    s2, m2 = step(restored, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)


def test_restore_rejects_structure_mismatch(tmp_path):
    cfg = get_config("xlstm-125m").reduced()
    opt = AdamW()
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    path = str(tmp_path / "ckpt.npz")
    save(path, state)
    other = init_state(get_config("starcoder2-3b").reduced(),
                       jax.random.PRNGKey(0), opt)
    with pytest.raises(ValueError):
        restore(path, other)


def test_bf16_leaves_roundtrip_exactly(tmp_path):
    tree = {"w": (jnp.arange(7, dtype=jnp.float32) / 3).astype(jnp.bfloat16),
            "b": jnp.float32(1.5)}
    path = str(tmp_path / "t.npz")
    save(path, tree)
    out, _ = restore(path, tree)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))
