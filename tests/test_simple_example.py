"""Reproduce the paper's §4.2 / Appendix C worked example *exactly*.

Three abstract GPU types {t1,t2,t3} (2 units each, 4/2/2 $/h), two workloads
(λ1=80, λ2=20), budget 8 $/h.  Given the paper's throughput table, the three
cases must evaluate to 44.05 s, 35.24 s, 30.94 s, and the optimized plan to
28.67 s — and our MILP must find a plan at least as good as 28.67 s.
"""
import numpy as np
import pytest

from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage
from repro.core.milp import SchedulingProblem, plan_makespan, solve_milp
from repro.core.binsearch import solve_binary_search
from repro.core.plan import Config

_GB = 1024**3

MODEL = ModelProfile(name="toy", n_layers=2, d_model=64, n_kv_heads=1,
                     head_dim=64, params_total=1e6, params_active=1e6)

T1 = DeviceType("t1", 1e12, 1e11, 64 * _GB, 4.0, 8, 1e11, 1e9, "datacenter")
T2 = DeviceType("t2", 1e12, 1e11, 64 * _GB, 2.0, 8, 1e11, 1e9, "workstation")
T3 = DeviceType("t3", 1e12, 1e11, 64 * _GB, 2.0, 8, 1e11, 1e9, "consumer")

# Paper's throughput table: (device, tp) -> (h_w1, h_w2) req/s.
H = {
    ("t1", 1): (1.0, 1.2),
    ("t2", 1): (0.9, 0.9),
    ("t3", 1): (0.3, 0.5),
    ("t2", 2): (2.4, 1.5),   # TP over two t2 GPUs (Case 2)
}

LAM = np.array([80.0, 20.0])
AVAIL = {"t1": 2, "t2": 2, "t3": 2}
BUDGET = 8.0


def _cfg(dev: DeviceType, tp: int) -> Config:
    return Config(stages=(Stage(dev, tp, 1.0),), model_index=0, model=MODEL)


def _problem() -> SchedulingProblem:
    configs = [_cfg(T1, 1), _cfg(T2, 1), _cfg(T3, 1), _cfg(T2, 2)]
    h = np.array([H[("t1", 1)], H[("t2", 1)], H[("t3", 1)], H[("t2", 2)]])
    return SchedulingProblem(configs=configs, h=h,
                             demands=[(0, 0, 80.0), (0, 1, 20.0)],
                             budget=BUDGET, availability=AVAIL)


def _proportional_time(rates_w1, rates_w2) -> float:
    """Cases 1-2: workload split proportional to per-replica rate — the
    system-wide rate is the sum, time = Σ_w λ_w / Σ_replicas rate."""
    return LAM[0] / sum(rates_w1) + LAM[1] / sum(rates_w2)


def test_case1_composition():
    comp1 = _proportional_time([1.0, 0.9, 0.3], [1.2, 0.9, 0.5])
    comp2 = _proportional_time([1.0, 0.9, 0.9], [1.2, 0.9, 0.9])
    assert comp1 == pytest.approx(44.05, abs=0.01)
    assert comp2 == pytest.approx(35.24, abs=0.01)
    assert (comp1 - comp2) / comp1 == pytest.approx(0.20, abs=0.01)


def test_case2_deployment_configuration():
    cfg2 = _proportional_time([1.0, 2.4], [1.2, 1.5])
    assert cfg2 == pytest.approx(30.94, abs=0.01)


def test_case3_workload_assignment():
    # 15% of w1 + 100% of w2 on t1; 85% of w1 on TP(2×t2).
    t_t1 = 0.15 * LAM[0] / 1.0 + LAM[1] / 1.2
    t_tp = 0.85 * LAM[0] / 2.4
    assert max(t_t1, t_tp) == pytest.approx(28.67, abs=0.01)


def test_milp_finds_at_least_paper_plan():
    plan = solve_milp(_problem(), time_limit=60)
    assert plan.cost <= BUDGET + 1e-6
    assert plan.makespan <= 28.67 + 0.01
    # Composition must match the paper's: 1×t1 + 2×t2 (the TP replica).
    assert plan.composition() == {"t1": 1, "t2": 2}


def test_binary_search_matches_milp():
    plan_bs = solve_binary_search(_problem(), tol=0.05)
    plan_milp = solve_milp(_problem(), time_limit=60)
    assert plan_bs.makespan <= plan_milp.makespan * 1.01 + 0.05
    assert plan_bs.cost <= BUDGET + 1e-6


def test_makespan_evaluator_consistency():
    problem = _problem()
    y = np.array([1.0, 0.0, 0.0, 1.0])
    x = np.array([[0.15, 1.0], [0, 0], [0, 0], [0.85, 0.0]])
    assert plan_makespan(problem, y, x) == pytest.approx(28.67, abs=0.01)
