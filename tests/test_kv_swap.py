"""Two-tier KV cache tests: host spill/revive on the allocator, the
manager's swap accounting, swap-based preemption end to end (token streams
byte-identical to recompute), the cost-aware auto policy, backend-identical
admission in swap mode, and the planner's prefix-hit-rate spec input."""
import math

import numpy as np
import pytest

from repro.core import costmodel
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage
from repro.core.plan import Config, ServingPlan
from repro.core.workloads import Request, Trace
from repro.runtime import CostModelExecutor, ServingRuntime
from repro.runtime.kvcache import BlockAllocator, KVCacheManager

BS = 16
TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)
BLOCK_BYTES = BS * TINY.kv_bytes_per_token


def _replica(num_blocks: int, **dev_kw) -> Config:
    free = (num_blocks + 0.5) * BLOCK_BYTES
    mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
           / costmodel.MEMORY_UTIL)
    dev = DeviceType("kv-swap-test", 1e12, 1e11, mem, 1.0, 8, 1e11, 1e9,
                     "x", **dev_kw)
    return Config(stages=(Stage(dev, 1, 1.0),), model_index=0, model=TINY)


def _plan(config: Config, n_requests: int) -> ServingPlan:
    return ServingPlan(replicas=[config], assignment=np.ones((1, 1)),
                       demands=[(0, 0, float(n_requests))], makespan=1.0,
                       cost=config.cost)


def _trace(reqs) -> Trace:
    return Trace("kv-swap", tuple(reqs))


def _overflow_requests(n=3, input_len=30, output_len=4):
    return [Request(req_id=i, workload=0, input_len=input_len,
                    output_len=output_len, arrival=0.0) for i in range(n)]


# -------------------------------------------------- unit: two-tier allocator

class _SpillRecorder:
    """Callback triple that mirrors what a pool owner would do, plus the
    assertions a physical pool depends on (spill never sees a live id)."""

    def __init__(self):
        self.host = {}            # hash -> device id it was spilled from
        self.allocator = None

    def on_spill(self, block_id, h):
        assert self.allocator.ref_count(block_id) == 0, \
            "spill callback fired for a live block"
        assert h not in self.host
        self.host[h] = block_id

    def on_host_evict(self, h):
        del self.host[h]

    def on_revive(self, block_id, h):
        assert self.allocator.ref_count(block_id) == 1
        del self.host[h]


def _two_tier(num_blocks, host_blocks):
    rec = _SpillRecorder()
    a = BlockAllocator(num_blocks, first_id=1, host_blocks=host_blocks,
                       on_spill=rec.on_spill,
                       on_host_evict=rec.on_host_evict,
                       on_revive=rec.on_revive)
    rec.allocator = a
    return a, rec


def test_allocator_spills_to_host_and_revives():
    a, rec = _two_tier(num_blocks=2, host_blocks=2)
    ids = a.alloc(2)
    a.commit(ids[0], 101)
    a.commit(ids[1], 102)
    a.free(ids)                       # both park in the device LRU
    fresh = a.alloc(2)                # evicts both -> spills to host
    assert a.spilled_blocks == 2 and set(rec.host) == {101, 102}
    assert a.host_contains(101) and a.lookup(101) is None
    assert a.adopt(101) is None       # no device block free to revive into
    a.free([fresh[0]])
    revived = a.adopt(101)            # revive host -> device
    assert revived is not None and a.lookup(101) == revived
    assert not a.host_contains(101) and a.host_revives == 1
    assert set(rec.host) == {102}
    a.free([revived, fresh[1]])


def test_allocator_host_tier_is_bounded():
    a, rec = _two_tier(num_blocks=3, host_blocks=2)
    ids = a.alloc(3)
    for i, h in zip(ids, (1, 2, 3)):
        a.commit(i, h)
    a.free(ids)
    a.alloc(3)                        # evict all three, host holds only 2
    assert a.host_used_blocks == 2 and a.host_evictions == 1
    assert set(rec.host) == {2, 3}    # oldest spilled hash dropped first
    assert not a.host_contains(1)


def _allocator_invariant_sweep(num_blocks, host_blocks, ops):
    """Drive a random op sequence and check the two-tier invariants after
    every step: device partition exact, host bound respected, host hashes
    never shadowing device-indexed ones, spills only of refcount-0 blocks
    (asserted inside the callbacks)."""
    a, rec = _two_tier(num_blocks, host_blocks)
    live = []                         # ids we hold references on
    next_hash = [1]
    for kind, val in ops:
        if kind == "alloc":
            n = 1 + val % max(1, num_blocks)
            if n <= a.available_blocks:
                live.extend(a.alloc(n))
        elif kind == "commit" and live:
            bid = live[val % len(live)]
            if a.block_hash(bid) is None:
                a.commit(bid, next_hash[0])
                next_hash[0] += 1
        elif kind == "free" and live:
            a.free([live.pop(val % len(live))])
        elif kind == "adopt" and next_hash[0] > 1:
            got = a.adopt(1 + val % (next_hash[0] - 1))
            if got is not None:
                live.append(got)
        # --- invariants ---
        assert (a.free_blocks + a.used_blocks + a.cached_blocks
                == num_blocks)
        assert a.host_used_blocks <= host_blocks
        assert len(rec.host) == a.host_used_blocks
        assert set(a._free).isdisjoint(a._refs)
        assert set(a._free).isdisjoint(a._lru)
        assert all(bid in a._refs or bid in a._lru
                   for bid in a._index.values())
        for h in rec.host:
            assert a.lookup(h) is None      # host never shadows device
        for bid in live:
            assert a.ref_count(bid) >= 1    # a held block is never evicted
    a.free(live)
    assert a.used_blocks == 0


_OP_KINDS = ("alloc", "commit", "free", "adopt")


def test_two_tier_allocator_invariants_seeded():
    rng = np.random.default_rng(42)
    for _ in range(20):
        num_blocks = int(rng.integers(2, 24))
        host_blocks = int(rng.integers(0, 16))
        ops = [(_OP_KINDS[int(rng.integers(0, 4))], int(rng.integers(0, 64)))
               for _ in range(int(rng.integers(5, 60)))]
        _allocator_invariant_sweep(num_blocks, host_blocks, ops)


def test_two_tier_allocator_invariants_property():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=40, deadline=None)
    @hypothesis.given(
        num_blocks=st.integers(min_value=2, max_value=24),
        host_blocks=st.integers(min_value=0, max_value=16),
        ops=st.lists(st.tuples(st.sampled_from(_OP_KINDS),
                               st.integers(0, 63)),
                     min_size=1, max_size=60),
    )
    def run(num_blocks, host_blocks, ops):
        _allocator_invariant_sweep(num_blocks, host_blocks, ops)

    run()


# ----------------------------------------------------- unit: manager swap

def test_manager_swap_roundtrip_accounting():
    m = KVCacheManager(num_blocks=5, block_size=BS, host_blocks=4)
    assert m.admit(0, 31, solo=True)            # 2 blocks
    assert m.can_swap_out(0)
    assert m.swap_out(0) == 2
    assert m.used_blocks == 0 and m.host_used_blocks == 2
    assert m.swapped_blocks(0) == 2
    assert not m.can_swap_out(0)                # nothing held any more
    assert m.swap_in(0, 31, solo=True)
    assert m.used_blocks == 2 and m.host_used_blocks == 0
    assert (m.swap_outs, m.swap_ins) == (1, 1)
    assert m.swapped_in_blocks == 2
    m.free(0)
    assert m.used_blocks == 0


def test_manager_swap_gated_by_host_capacity():
    m = KVCacheManager(num_blocks=5, block_size=BS, host_blocks=1)
    assert m.admit(0, 31, solo=True)            # 2 blocks > 1 host block
    assert not m.can_swap_out(0)
    m2 = KVCacheManager(num_blocks=5, block_size=BS, host_blocks=0)
    assert m2.admit(0, 31, solo=True)
    assert not m2.can_swap_out(0)               # tier off: never swappable
    m3 = KVCacheManager(num_blocks=5, block_size=BS, host_blocks=3)
    assert m3.admit(0, 31, solo=True) and m3.admit(1, 31)
    assert m3.swap_out(0) == 2
    assert not m3.can_swap_out(1)               # 1 free host block < 2 held
    m3.drop_swapped(0)
    assert m3.host_used_blocks == 0 and m3.swap_drops == 1
    assert m3.can_swap_out(1)


# --------------------------------------------------- unit: cost-model terms

def test_host_link_bandwidth_is_slowest_stage():
    fast = DeviceType("fast", 1e12, 1e11, 1e11, 1.0, 8, 1e11, 1e9, "x",
                      host_bw=50e9)
    slow = DeviceType("slow", 1e12, 1e11, 1e11, 1.0, 8, 1e11, 1e9, "x",
                      host_bw=10e9)
    stages = (Stage(fast, 2, 0.5), Stage(slow, 1, 0.5))
    assert costmodel.host_link_bandwidth(stages) == 10e9
    t = costmodel.swap_time_s(stages, 10e9 * costmodel.HOST_LINK_UTIL)
    assert math.isclose(t, 1.0)
    assert costmodel.swap_time_s(stages, 0.0) == 0.0


def test_preempt_costs_direction():
    """The auto policy's two regimes: a compute-rich replica with a slow
    host link should recompute; a compute-starved one with a fast link
    should swap."""
    compute_rich = Config(stages=(Stage(DeviceType(
        "rich", 1e15, 1e12, 1e11, 1.0, 8, 1e11, 1e9, "x", host_bw=1e6),
        1, 1.0),), model_index=0, model=TINY)
    link_rich = Config(stages=(Stage(DeviceType(
        "linky", 1e9, 1e9, 1e11, 1.0, 8, 1e11, 1e9, "x", host_bw=1e12),
        1, 1.0),), model_index=0, model=TINY)
    swap_bytes = 4 * BLOCK_BYTES
    s1, r1 = costmodel.preempt_costs(compute_rich.stages, TINY,
                                     swap_bytes=swap_bytes,
                                     prompt_tokens=50)
    assert r1 < s1                    # recompute wins on the fat GPU
    s2, r2 = costmodel.preempt_costs(link_rich.stages, TINY,
                                     swap_bytes=swap_bytes,
                                     prompt_tokens=50)
    assert s2 < r2                    # swap wins over the fast link


# ---------------------------------------- integration: swap preemption (cost)

def _run_cost(num_blocks, reqs, *, preempt_mode, host_blocks, **dev_kw):
    cfg = _replica(num_blocks, **dev_kw)
    executor = CostModelExecutor([cfg], [TINY], host_blocks=host_blocks)
    runtime = ServingRuntime(_plan(cfg, len(reqs)), executor,
                             preempt_mode=preempt_mode)
    res = runtime.run(_trace(reqs))
    return res, runtime, executor


def test_swap_preemption_completes_and_accounts():
    reqs = _overflow_requests(n=4, input_len=30, output_len=8)
    res, runtime, executor = _run_cost(5, reqs, preempt_mode="swap",
                                       host_blocks=16)
    mgr = executor.kv_manager(0)
    assert res.num_completed == 4
    assert res.num_preemptions > 0
    assert mgr.swap_outs == mgr.swap_ins > 0
    assert res.info["swap_ins"] == mgr.swap_ins
    assert res.info["swapped_out_bytes"] == \
        mgr.swapped_out_blocks * BLOCK_BYTES
    assert mgr.used_blocks == 0 and mgr.host_used_blocks == 0
    # a swap-readmitted request does NOT pay prefill again: its id shows
    # up in a swap-in admission group, and total admissions still cover
    # every preemption
    readmitted = [rid for g in runtime.replicas[0].admission_log for rid in g]
    assert len(readmitted) == len(reqs) + res.num_preemptions


def test_swap_mode_without_host_tier_degrades_to_recompute():
    reqs = _overflow_requests(n=4, input_len=30, output_len=8)
    rec_res, rec_rt, _ = _run_cost(5, reqs, preempt_mode="recompute",
                                   host_blocks=0)
    swp_res, swp_rt, executor = _run_cost(5, reqs, preempt_mode="swap",
                                          host_blocks=0)
    # no host budget -> can_swap is always False -> byte-identical schedule
    assert (rec_rt.replicas[0].admission_log
            == swp_rt.replicas[0].admission_log)
    assert rec_res.num_preemptions == swp_res.num_preemptions
    assert executor.kv_manager(0).swap_outs == 0
    assert "swap_ins" not in swp_res.info


def test_auto_mode_picks_the_modeled_cheaper_policy():
    reqs = _overflow_requests(n=4, input_len=30, output_len=8)
    # fast host link on a tiny model: swap is modeled cheaper -> auto swaps
    auto_res, _, ex = _run_cost(5, reqs, preempt_mode="auto",
                                host_blocks=16, host_bw=1e12)
    assert auto_res.num_completed == 4
    assert ex.kv_manager(0).swap_outs > 0
    # pathologically slow host link: recompute is cheaper -> auto recomputes
    slow_res, _, ex2 = _run_cost(5, reqs, preempt_mode="auto",
                                 host_blocks=16, host_bw=1.0)
    assert slow_res.num_completed == 4
    assert ex2.kv_manager(0).swap_outs == 0
    assert slow_res.num_preemptions > 0


def test_invalid_preempt_mode_rejected():
    cfg = _replica(5)
    with pytest.raises(ValueError):
        ServingRuntime(_plan(cfg, 1), CostModelExecutor([cfg], [TINY]),
                       preempt_mode="maybe")


# -------------------------------------------- integration: engine backend

def test_engine_host_revive_bitwise_equal():
    """A hashed block evicted to the host tier and revived via adopt must
    come back with bitwise-identical pool contents."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.runtime.kvcache.paged import PagedEngineCache
    from repro.serving.engine import ReplicaEngine

    cfg = get_config("llama3-8b").reduced()
    eng = ReplicaEngine(cfg, seed=0)
    t_prompt = 33                     # 4 full 8-token blocks matchable
    paged = PagedEngineCache(cfg, num_slots=2, t_max=40, block_size=8,
                             prefix_cache=True, host_blocks=8)
    rng = np.random.default_rng(0)
    row = rng.integers(0, cfg.vocab_size, t_prompt)
    tok, caches = eng.prefill_batch(jnp.asarray(row[None], jnp.int32),
                                    t_prompt)
    hashes = paged.block_hashes(row, t_prompt)
    paged.admit_cohort([0], caches, np.asarray(tok), t_prompt,
                       block_hashes_per_req=[hashes])
    owned = list(paged._blocks_of[0])
    before = {key: np.asarray(paged.pools[0][key][:, np.asarray(
        owned[:len(hashes)], np.int32)]) for key in ("k", "v")}
    paged.release(0)                  # hashed blocks park in the LRU
    # exhaust the free list so further allocation evicts + spills
    hog = paged.allocator.alloc(paged.allocator.free_blocks)
    evict = paged.allocator.alloc(len(hashes))
    assert paged.allocator.spilled_blocks >= len(hashes)
    assert all(paged.allocator.host_contains(h) for h in hashes)
    paged.allocator.free(evict)
    assert paged.match_len(hashes) == len(hashes)   # visible via host tier
    revived = paged.adopt_prefix(hashes)
    after = {key: np.asarray(paged.pools[0][key][:, np.asarray(
        revived, np.int32)]) for key in ("k", "v")}
    for key in ("k", "v"):
        assert np.array_equal(before[key], after[key])
    assert paged.allocator.host_revives == len(hashes)
    assert paged.host_revive_bytes > 0
    paged.allocator.free(revived)
    paged.allocator.free(hog)


def _run_engine(reqs, *, preempt_mode, host_blocks, num_blocks=5):
    from repro.configs import get_config
    from repro.runtime import EngineExecutor

    cfg = _replica(num_blocks)
    plan = _plan(cfg, len(reqs))
    # max_new=5 -> engine decode quota min(output_len, 4) == cost quota.
    # fused_steps=1: cross-schedule token comparisons need every decode
    # step to run the same single-step program — fused chunk boundaries
    # differ between preemption modes, and distinct XLA programs can flip
    # a bf16 argmax near-tie.
    executor = EngineExecutor(plan, [get_config("llama3-8b").reduced()],
                              models=[TINY], max_batch=8, input_len=8,
                              max_new=5, fused_steps=1,
                              host_blocks=host_blocks)
    runtime = ServingRuntime(plan, executor, preempt_mode=preempt_mode)
    res = runtime.run(_trace(reqs))
    return res, runtime, executor


def test_swap_readmission_token_stream_matches_recompute():
    """Acceptance: resuming from swapped-in KV must generate exactly the
    tokens recompute would — a swapped request's log is the tail of its
    recompute log (recompute re-enters prefill, duplicating early tokens),
    and untouched requests log identically."""
    pytest.importorskip("jax")
    reqs = _overflow_requests(n=3, input_len=30, output_len=4)
    rec_res, _, rec_ex = _run_engine(reqs, preempt_mode="recompute",
                                     host_blocks=0)
    swp_res, _, swp_ex = _run_engine(reqs, preempt_mode="swap",
                                     host_blocks=16)
    assert rec_res.num_completed == swp_res.num_completed == 3
    assert swp_res.info["swap_ins"] > 0
    swapped_rids = {r.req.req_id for r in swp_res.records if r.swap_ins}
    assert swapped_rids
    for rid in (r.req.req_id for r in swp_res.records):
        rec_log = list(rec_ex.token_log[rid])
        swp_log = list(swp_ex.token_log[rid])
        if rid in swapped_rids:
            assert len(swp_log) < len(rec_log)      # no re-prefill tokens
            assert swp_log == rec_log[-len(swp_log):]
        else:
            assert swp_log == rec_log
    paged = swp_ex._paged[0]
    assert paged.allocator.used_blocks == 0
    assert paged.swap_in_bytes == paged.swap_out_bytes > 0


def test_swap_mode_backend_admission_equivalence():
    """Cost-model and engine backends make identical admission AND swap
    decisions on the same overflow trace with the host tier on."""
    pytest.importorskip("jax")
    reqs = _overflow_requests(n=3, input_len=30, output_len=4)
    cost_res, cost_rt, cost_ex = _run_cost(5, reqs, preempt_mode="swap",
                                           host_blocks=16)
    eng_res, eng_rt, eng_ex = _run_engine(reqs, preempt_mode="swap",
                                          host_blocks=16)
    assert cost_res.num_completed == eng_res.num_completed == 3
    assert (cost_rt.replicas[0].admission_log
            == eng_rt.replicas[0].admission_log)
    cm, em = cost_ex.kv_manager(0), eng_ex.kv_manager(0)
    assert (cm.swap_outs, cm.swap_ins) == (em.swap_outs, em.swap_ins)
    assert cm.swap_outs > 0
    cost_swaps = {r.req.req_id: r.swap_ins for r in cost_res.records}
    eng_swaps = {r.req.req_id: r.swap_ins for r in eng_res.records}
    assert cost_swaps == eng_swaps


# ------------------------------------------------ trace tooling: swap rows

def test_trace_summarize_reports_swap_traffic():
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "tools"))
    import trace_summarize

    doc = {"traceEvents": [
        {"ph": "M", "name": "thread_name", "tid": 0,
         "args": {"name": "replica-0 cfg"}},
        {"ph": "X", "tid": 0, "ts": 0.0, "dur": 2e6, "cat": "prefill",
         "name": "prefill[2]"},
        {"ph": "i", "tid": 0, "ts": 2.5e6, "name": "swap-out",
         "args": {"bytes": 4096.0}},
        {"ph": "X", "tid": 0, "ts": 3e6, "dur": 1e6, "cat": "swapin",
         "name": "swapin[B=1]", "args": {"bytes": 4096.0}},
    ]}
    s = trace_summarize.summarize(doc)
    rep = s["replicas"][0]
    assert rep["preemptions"] == 1
    assert rep["swap_ins"] == 1 and rep["swap_in_s"] == 1.0
    assert rep["swap_out_bytes"] == rep["swap_in_bytes"] == 4096.0
    text = trace_summarize.format_summary(s)
    assert "swapin" in text and "out-MB" in text


# --------------------------------------- planner: prefix-hit-rate spec input

def test_spec_prefix_hit_rates_validated_and_fed_to_planner():
    from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_8B,
                            DeploymentSpec, make_trace, plan)

    trace = make_trace("trace1", num_requests=120, seed=0)
    spec = DeploymentSpec(models=[LLAMA3_8B], workload=trace,
                          catalog=GPU_CATALOG,
                          availability=AVAILABILITY_SNAPSHOTS["avail1"],
                          budget=20.0)
    with pytest.raises(ValueError):
        spec.with_prefix_hit_rates({0: 1.5})
    with pytest.raises(ValueError):
        spec.with_prefix_hit_rates({0: -0.1})
    warm = spec.with_prefix_hit_rates({i: 0.9 for i in range(9)})
    assert warm.prefix_hit_rates[0] == 0.9
    assert spec.prefix_hit_rates is None        # original untouched
    base = plan(spec, tol=2.0)
    hot = plan(warm, tol=2.0)
    # cached prompt tokens skip prefill FLOPs -> the same budget finishes
    # the trace strictly faster
    assert hot.makespan < base.makespan
    # an explicit throughput_fn wins over the spec's hit rates
    from repro.core.costmodel import config_throughput
    override = plan(warm, tol=2.0,
                    throughput_fn=lambda cfg, w: config_throughput(
                        cfg.stages, cfg.model, w))
    assert math.isclose(override.makespan, base.makespan, rel_tol=1e-6)
