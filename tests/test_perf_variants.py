"""The §Perf optimization levers must be semantics-preserving: every variant
produces the same numbers as the paper-faithful baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models import runtime_flags as RF
from repro.models import transformer as T


@pytest.fixture(autouse=True)
def _reset_flags():
    RF.reset()
    yield
    RF.reset()


def test_decode_cache_donate_variant_matches_baseline():
    cfg = get_config("mixtral-8x22b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                cfg.vocab_size)
    _, caches = M.prefill(cfg, params, tokens, t_max=16)

    tok = jnp.array([3, 5], jnp.int32)
    logits_base, caches_base = T.decode_step(cfg, params, caches, tok,
                                             jnp.asarray(10, jnp.int32))
    RF.configure(decode_cache_donate=True)
    logits_opt, caches_opt = T.decode_step(cfg, params, caches, tok,
                                           jnp.asarray(10, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_base, np.float32),
                               np.asarray(logits_opt, np.float32),
                               rtol=1e-3, atol=1e-3)
    for a, b in zip(jax.tree.leaves(caches_base), jax.tree.leaves(caches_opt)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-3)


def test_act_seq_shard_noop_without_mesh():
    """Flag on but no mesh context -> baseline math, no crash."""
    cfg = get_config("starcoder2-3b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    base, _ = T.forward(cfg, params, tokens)
    RF.configure(act_seq_shard=True, mesh=None)
    opt, _ = T.forward(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32), rtol=1e-5,
                               atol=1e-5)


def test_kv_cache_int8_decode_close_to_baseline():
    """int8 KV cache: decode logits within quantization tolerance of bf16."""
    cfg = get_config("llama3-8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    # baseline
    _, caches = T.prefill(cfg, params, tokens, t_max=24)
    tok = jnp.array([7, 9], jnp.int32)
    base, _ = T.decode_step(cfg, params, caches, tok, jnp.asarray(16, jnp.int32))
    # int8 path (prefill + decode both quantized)
    RF.configure(kv_cache_int8=True)
    _, caches_q = T.prefill(cfg, params, tokens, t_max=24)
    quant, _ = T.decode_step(cfg, params, caches_q, tok,
                             jnp.asarray(16, jnp.int32))
    base = np.asarray(base, np.float32)
    quant = np.asarray(quant, np.float32)
    # int8 absmax quantization: small relative error on logits
    err = np.abs(base - quant).max() / (np.abs(base).max() + 1e-6)
    assert err < 0.08, f"int8 KV error too large: {err}"
    # and greedy argmax is overwhelmingly preserved
    agree = (base.argmax(-1) == quant.argmax(-1)).mean()
    assert agree >= 0.5


def test_pallas_attention_path_matches_xla(monkeypatch):
    """Flag-gated Pallas kernels (interpret mode on CPU) == XLA attention
    for prefill + decode on a reduced dense arch."""
    cfg = get_config("gemma2-27b").reduced()  # exercises softcap + SWA
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    logits_x, caches_x = T.prefill(cfg, params, tokens, t_max=20)
    tok = jnp.array([1, 2], jnp.int32)
    dec_x, _ = T.decode_step(cfg, params, caches_x, tok,
                             jnp.asarray(16, jnp.int32))

    RF.configure(use_pallas_attention=True)
    logits_p, caches_p = T.prefill(cfg, params, tokens, t_max=20)
    dec_p, _ = T.decode_step(cfg, params, caches_p, tok,
                             jnp.asarray(16, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_x, np.float32),
                               np.asarray(logits_p, np.float32),
                               rtol=0.03, atol=0.03)
    np.testing.assert_allclose(np.asarray(dec_x, np.float32),
                               np.asarray(dec_p, np.float32),
                               rtol=0.03, atol=0.03)
