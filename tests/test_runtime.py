"""Unified-runtime tests: backend equivalence (cost-model vs real engine),
SLO accounting, model-aware fallback routing, and online replanning."""
import math

import numpy as np
import pytest

from repro.core import (GPU_CATALOG, AVAILABILITY_SNAPSHOTS, LLAMA3_70B,
                        make_trace, simulate, solve)
from repro.core.costmodel import ModelProfile
from repro.core.plan import ServingPlan
from repro.core.scheduler import replan
from repro.core.workloads import Request, Trace
from repro.runtime import (SLO, CostModelExecutor, Phase, ReplanEvent,
                           ServingRuntime)

TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)


@pytest.fixture(scope="module")
def small_plan():
    trace = make_trace("trace1", num_requests=40, arrival_rate=8.0, seed=0)
    plan = solve([TINY], trace, GPU_CATALOG,
                 {"A40": 4, "4090": 4, "H100": 2}, budget=8.0)
    return plan, trace


def _routing(result):
    return {r.req.req_id: r.replica for r in result.records}


def test_backends_agree_on_routing_and_completions(small_plan):
    """Same trace + plan through the cost-model and real-engine backends:
    identical routing decisions and completion counts (the refactor's core
    guarantee — plan evaluation and plan execution share one code path)."""
    from repro.configs import get_config
    from repro.serving import HeterogeneousServer
    plan, trace = small_plan
    predicted = simulate(plan, trace, [TINY])
    server = HeterogeneousServer(plan, [get_config("llama3-8b").reduced()],
                                 max_batch=8)
    executed = server.serve(trace, input_len=8, max_new=4)
    assert _routing(predicted) == _routing(executed.result)
    assert predicted.num_completed == executed.completed == trace.num_requests
    assert executed.generated_tokens == trace.num_requests * 4
    assert sum(executed.per_replica_requests) == trace.num_requests
    # both backends report the full SLO metric set
    for res in (predicted, executed.result):
        assert len(res.ttfts) == trace.num_requests
        assert np.isfinite(res.ttfts).all()
        assert (res.tpots >= 0).all()


def test_goodput_monotone_in_slo(small_plan):
    plan, trace = small_plan
    res = simulate(plan, trace, [TINY])
    bounds = [0.1, 1.0, 5.0, 20.0, math.inf]
    goodputs = [res.goodput(SLO(ttft=b)) for b in bounds]
    attain = [res.slo_attainment(SLO(ttft=b)) for b in bounds]
    assert goodputs == sorted(goodputs)
    assert attain == sorted(attain)
    assert attain[-1] == 1.0
    assert res.goodput(SLO()) == pytest.approx(res.throughput)
    # tightening a second dimension can only lose requests
    assert res.goodput(SLO(ttft=5.0, tpot=1e-9)) <= res.goodput(SLO(ttft=5.0))


def test_streaming_dispatch_respects_arrivals(small_plan):
    plan, trace = small_plan
    res = simulate(plan, trace, [TINY])
    for rec in res.records:
        assert rec.done
        assert rec.first_token_at >= rec.req.arrival
        assert rec.finished_at >= rec.first_token_at
    last_arrival = max(r.arrival for r in trace.requests)
    assert res.makespan >= last_arrival


def test_model_blind_fallback_fixed(small_plan):
    """A request whose demand column is missing must only land on replicas
    serving its model — and is dropped when no such replica exists."""
    plan, _ = small_plan
    # model 1 never appears in the plan's demands or replicas
    alien = Request(req_id=999, workload=0, input_len=10, output_len=4,
                    arrival=0.0, model=1)
    known = Request(req_id=1000, workload=0, input_len=10, output_len=4,
                    arrival=0.0, model=0)
    trace = Trace("fallback", (alien, known))
    res = simulate(plan, trace, [TINY, TINY])
    by_id = {r.req.req_id: r for r in res.records}
    assert by_id[999].replica == -1 and not by_id[999].done
    assert by_id[1000].done
    assert res.dropped == 1
    # zero-probability demand column: falls back among same-model replicas
    zeroed = ServingPlan(replicas=plan.replicas,
                         assignment=np.zeros_like(plan.assignment),
                         demands=plan.demands, makespan=plan.makespan,
                         cost=plan.cost)
    res0 = simulate(zeroed, trace, [TINY, TINY])
    rec = {r.req.req_id: r for r in res0.records}[1000]
    assert rec.replica >= 0
    assert plan.replicas[rec.replica].model_index == 0


@pytest.fixture(scope="module")
def replan_setup():
    trace = make_trace("trace1", num_requests=300, arrival_rate=6.0, seed=1)
    avail = dict(AVAILABILITY_SNAPSHOTS["avail1"])
    plan = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, 30.0, tol=1.0)
    dropped = dict(avail, H100=0)
    new_plan = replan(plan, [LLAMA3_70B], trace, GPU_CATALOG, dropped, 30.0,
                      tol=1.0)
    return trace, plan, new_plan


def test_replan_mid_trace_preserves_survivors(replan_setup):
    trace, plan, new_plan = replan_setup
    t_drop = max(r.arrival for r in trace.requests) / 2
    executor = CostModelExecutor(plan.replicas, [LLAMA3_70B])
    runtime = ServingRuntime(plan, executor)
    res = runtime.run(trace, replan=ReplanEvent(time=t_drop, plan=new_plan))
    # nothing is lost: every request completes on some replica
    assert res.num_completed == trace.num_requests
    assert all(r.phase is Phase.DONE for r in res.records)
    # the runtime's key-matched survivor count agrees with the scheduler's
    # multiset replicas_kept accounting
    assert res.info["replicas_kept"] == new_plan.solver_info["replicas_kept"]
    assert (res.info["replicas_kept"] + res.info["replicas_added"]
            == len(new_plan.replicas))
    # drained H100 replicas admit nothing after the drop: every request that
    # ran on a non-surviving replica was admitted before the replan point
    survivors = {r.index for r in runtime._route_map}
    for rec in res.records:
        if rec.replica not in survivors:
            assert rec.admitted_at <= t_drop + 1e-9
    # post-replan arrivals only land on new-plan replicas
    for rec in res.records:
        if rec.req.arrival > t_drop:
            assert rec.replica in survivors


def test_replan_migrates_backlogged_queue():
    """A small plan with a huge t=0 backlog replans to different configs:
    the queued (unadmitted) requests must migrate and still complete."""
    trace = make_trace("trace1", num_requests=200, seed=2)   # all at t=0
    plan = solve([LLAMA3_70B], trace, GPU_CATALOG, {"A100": 4}, 10.0,
                 tol=1.0)
    new_plan = solve([LLAMA3_70B], trace, GPU_CATALOG, {"H100": 8}, 30.0,
                     tol=1.0)
    executor = CostModelExecutor(plan.replicas, [LLAMA3_70B])
    res = ServingRuntime(plan, executor).run(
        trace, replan=ReplanEvent(time=1.0, plan=new_plan))
    assert res.num_completed == trace.num_requests
    assert res.info["replicas_added"] >= 1
    assert res.info["requests_migrated"] > 0


def test_replan_clamps_idle_survivor_clocks(small_plan):
    """A survivor that idled before the replan must not admit migrated
    requests in the past: its clock is clamped to the event time."""
    plan, _ = small_plan
    executor = CostModelExecutor(plan.replicas, [TINY])
    runtime = ServingRuntime(plan, executor)
    runtime._advance_all(until=50.0)       # nothing dispatched: all idle at 0
    runtime._apply_replan(ReplanEvent(time=50.0, plan=plan))
    assert all(r.now >= 50.0 for r in runtime._route_map)
    assert runtime.info["replicas_kept"] == len(plan.replicas)


def test_simulate_wrapper_matches_direct_runtime(small_plan):
    plan, trace = small_plan
    a = simulate(plan, trace, [TINY])
    b = ServingRuntime(plan, CostModelExecutor(plan.replicas, [TINY])
                       ).run(trace)
    assert a.makespan == pytest.approx(b.makespan)
    np.testing.assert_allclose(a.latencies, b.latencies)
    assert _routing(a) == _routing(b)
