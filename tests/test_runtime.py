"""Unified-runtime tests: backend equivalence (cost-model vs real engine),
SLO accounting, model-aware fallback routing, and online replanning."""
import math

import numpy as np
import pytest

from repro.core import (GPU_CATALOG, AVAILABILITY_SNAPSHOTS, LLAMA3_70B,
                        make_trace, simulate, solve)
from repro.core.costmodel import ModelProfile
from repro.core.plan import ServingPlan
from repro.core.scheduler import replan
from repro.core.workloads import Request, Trace
from repro.runtime import (SLO, CostModelExecutor, Phase, ReplanEvent,
                           ServingRuntime)

TINY = ModelProfile(name="tiny", n_layers=2, d_model=256, n_kv_heads=2,
                    head_dim=64, params_total=2e6, params_active=2e6)


@pytest.fixture(scope="module")
def small_plan():
    trace = make_trace("trace1", num_requests=40, arrival_rate=8.0, seed=0)
    plan = solve([TINY], trace, GPU_CATALOG,
                 {"A40": 4, "4090": 4, "H100": 2}, budget=8.0)
    return plan, trace


def _routing(result):
    return {r.req.req_id: r.replica for r in result.records}


def test_backends_agree_on_routing_and_completions(small_plan):
    """Same trace + plan through the cost-model and real-engine backends:
    identical routing decisions and completion counts (the refactor's core
    guarantee — plan evaluation and plan execution share one code path)."""
    from repro.configs import get_config
    from repro.serving import HeterogeneousServer
    plan, trace = small_plan
    predicted = simulate(plan, trace, [TINY])
    server = HeterogeneousServer(plan, [get_config("llama3-8b").reduced()],
                                 max_batch=8)
    executed = server.serve(trace, input_len=8, max_new=4)
    assert _routing(predicted) == _routing(executed.result)
    assert predicted.num_completed == executed.completed == trace.num_requests
    assert executed.generated_tokens == trace.num_requests * 4
    assert sum(executed.per_replica_requests) == trace.num_requests
    # both backends report the full SLO metric set
    for res in (predicted, executed.result):
        assert len(res.ttfts) == trace.num_requests
        assert np.isfinite(res.ttfts).all()
        assert (res.tpots >= 0).all()


def test_goodput_monotone_in_slo(small_plan):
    plan, trace = small_plan
    res = simulate(plan, trace, [TINY])
    bounds = [0.1, 1.0, 5.0, 20.0, math.inf]
    goodputs = [res.goodput(SLO(ttft=b)) for b in bounds]
    attain = [res.slo_attainment(SLO(ttft=b)) for b in bounds]
    assert goodputs == sorted(goodputs)
    assert attain == sorted(attain)
    assert attain[-1] == 1.0
    assert res.goodput(SLO()) == pytest.approx(res.throughput)
    # tightening a second dimension can only lose requests
    assert res.goodput(SLO(ttft=5.0, tpot=1e-9)) <= res.goodput(SLO(ttft=5.0))


def test_streaming_dispatch_respects_arrivals(small_plan):
    plan, trace = small_plan
    res = simulate(plan, trace, [TINY])
    for rec in res.records:
        assert rec.done
        assert rec.first_token_at >= rec.req.arrival
        assert rec.finished_at >= rec.first_token_at
    last_arrival = max(r.arrival for r in trace.requests)
    assert res.makespan >= last_arrival


def test_model_blind_fallback_fixed(small_plan):
    """A request whose demand column is missing must only land on replicas
    serving its model — and is dropped when no such replica exists."""
    plan, _ = small_plan
    # model 1 never appears in the plan's demands or replicas
    alien = Request(req_id=999, workload=0, input_len=10, output_len=4,
                    arrival=0.0, model=1)
    known = Request(req_id=1000, workload=0, input_len=10, output_len=4,
                    arrival=0.0, model=0)
    trace = Trace("fallback", (alien, known))
    res = simulate(plan, trace, [TINY, TINY])
    by_id = {r.req.req_id: r for r in res.records}
    assert by_id[999].replica == -1 and not by_id[999].done
    assert by_id[1000].done
    assert res.dropped == 1
    # zero-probability demand column: falls back among same-model replicas
    zeroed = ServingPlan(replicas=plan.replicas,
                         assignment=np.zeros_like(plan.assignment),
                         demands=plan.demands, makespan=plan.makespan,
                         cost=plan.cost)
    res0 = simulate(zeroed, trace, [TINY, TINY])
    rec = {r.req.req_id: r for r in res0.records}[1000]
    assert rec.replica >= 0
    assert plan.replicas[rec.replica].model_index == 0


@pytest.fixture(scope="module")
def replan_setup():
    trace = make_trace("trace1", num_requests=300, arrival_rate=6.0, seed=1)
    avail = dict(AVAILABILITY_SNAPSHOTS["avail1"])
    plan = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, 30.0, tol=1.0)
    dropped = dict(avail, H100=0)
    new_plan = replan(plan, [LLAMA3_70B], trace, GPU_CATALOG, dropped, 30.0,
                      tol=1.0)
    return trace, plan, new_plan


def test_replan_mid_trace_preserves_survivors(replan_setup):
    trace, plan, new_plan = replan_setup
    t_drop = max(r.arrival for r in trace.requests) / 2
    executor = CostModelExecutor(plan.replicas, [LLAMA3_70B])
    runtime = ServingRuntime(plan, executor)
    res = runtime.run(trace, replan=ReplanEvent(time=t_drop, plan=new_plan))
    # nothing is lost: every request completes on some replica
    assert res.num_completed == trace.num_requests
    assert all(r.phase is Phase.DONE for r in res.records)
    # the runtime's key-matched survivor count agrees with the scheduler's
    # multiset replicas_kept accounting
    assert res.info["replicas_kept"] == new_plan.solver_info["replicas_kept"]
    assert (res.info["replicas_kept"] + res.info["replicas_added"]
            == len(new_plan.replicas))
    # drained H100 replicas admit nothing after the drop: every request that
    # ran on a non-surviving replica was admitted before the replan point
    survivors = {r.index for r in runtime._route_map}
    for rec in res.records:
        if rec.replica not in survivors:
            assert rec.admitted_at <= t_drop + 1e-9
    # post-replan arrivals only land on new-plan replicas
    for rec in res.records:
        if rec.req.arrival > t_drop:
            assert rec.replica in survivors


def test_replan_migrates_backlogged_queue():
    """A small plan with a huge t=0 backlog replans to different configs:
    the queued (unadmitted) requests must migrate and still complete."""
    trace = make_trace("trace1", num_requests=200, seed=2)   # all at t=0
    plan = solve([LLAMA3_70B], trace, GPU_CATALOG, {"A100": 4}, 10.0,
                 tol=1.0)
    new_plan = solve([LLAMA3_70B], trace, GPU_CATALOG, {"H100": 8}, 30.0,
                     tol=1.0)
    executor = CostModelExecutor(plan.replicas, [LLAMA3_70B])
    res = ServingRuntime(plan, executor).run(
        trace, replan=ReplanEvent(time=1.0, plan=new_plan))
    assert res.num_completed == trace.num_requests
    assert res.info["replicas_added"] >= 1
    assert res.info["requests_migrated"] > 0


def test_replan_clamps_idle_survivor_clocks(small_plan):
    """A survivor that idled before the replan must not admit migrated
    requests in the past: its clock is clamped to the event time."""
    plan, _ = small_plan
    executor = CostModelExecutor(plan.replicas, [TINY])
    runtime = ServingRuntime(plan, executor)
    runtime._advance_all(until=50.0)       # nothing dispatched: all idle at 0
    runtime._apply_replan(ReplanEvent(time=50.0, plan=plan))
    assert all(r.now >= 50.0 for r in runtime._route_map)
    assert runtime.info["replicas_kept"] == len(plan.replicas)


def test_simulate_wrapper_matches_direct_runtime(small_plan):
    plan, trace = small_plan
    a = simulate(plan, trace, [TINY])
    b = ServingRuntime(plan, CostModelExecutor(plan.replicas, [TINY])
                       ).run(trace)
    assert a.makespan == pytest.approx(b.makespan)
    np.testing.assert_allclose(a.latencies, b.latencies)
    assert _routing(a) == _routing(b)


# ------------------------------------------------ global event-heap runtime

def _run_mode(plan, trace, mode, **kw):
    runtime = ServingRuntime(plan, CostModelExecutor(plan.replicas, [TINY]),
                             mode=mode)
    result = runtime.run(trace, **kw)
    return runtime, result


def _exact_schedule(result):
    """Every per-request timestamp, for byte-identical comparison."""
    return {r.req.req_id: (r.replica, r.admitted_at, r.first_token_at,
                           r.finished_at, r.preemptions)
            for r in result.records}


def test_event_heap_matches_sequential_exactly(small_plan):
    """The global event heap must reproduce the sequential runtime's
    admission log and metrics byte-for-byte on the cost-model backend."""
    plan, trace = small_plan
    seq_rt, seq = _run_mode(plan, trace, "sequential")
    evt_rt, evt = _run_mode(plan, trace, "events")
    assert ([r.admission_log for r in seq_rt.replicas]
            == [r.admission_log for r in evt_rt.replicas])
    assert _exact_schedule(seq) == _exact_schedule(evt)
    assert seq.makespan == evt.makespan                   # not approx: exact
    np.testing.assert_array_equal(seq.latencies, evt.latencies)
    np.testing.assert_array_equal(seq.ttfts, evt.ttfts)
    np.testing.assert_array_equal(seq.tpots, evt.tpots)
    assert seq.goodput(SLO(ttft=5.0)) == evt.goodput(SLO(ttft=5.0))


def test_event_heap_matches_sequential_barrier_sweep(small_plan):
    """Equivalence must hold wherever a barrier lands — including inside a
    prefill window (neither mode may *start* a decode at/after the
    barrier) and while decode chunks are mid-flight."""
    plan, trace = small_plan
    probe = simulate(plan, trace, [TINY])
    for frac in np.linspace(0.05, 0.95, 13):
        event = ReplanEvent(time=frac * probe.makespan, plan=plan)
        seq_rt, seq = _run_mode(plan, trace, "sequential", replan=event)
        evt_rt, evt = _run_mode(plan, trace, "events", replan=event)
        assert ([r.admission_log for r in seq_rt.replicas]
                == [r.admission_log for r in evt_rt.replicas]), frac
        assert _exact_schedule(seq) == _exact_schedule(evt), frac


def test_event_heap_matches_sequential_prefill_straddles_barrier():
    """A barrier landing *inside* a prefill window: neither mode may start
    the follow-up decode at/after the barrier, so the decode chunking (and
    hence the cost-model timings) must stay byte-identical."""
    from repro.core.plan import ServingPlan
    trace = Trace("straddle", (
        Request(req_id=0, workload=0, input_len=512, output_len=32,
                arrival=1.0),))
    cfg = _kv_tight_plan().replicas[0]
    plan = ServingPlan(replicas=[cfg], assignment=np.ones((1, 1)),
                       demands=[(0, 0, 1.0)], makespan=1.0, cost=cfg.cost)
    probe = ServingRuntime(plan, CostModelExecutor(plan.replicas, [TINY])
                           ).run(trace)
    rec = probe.records[0]
    assert rec.first_token_at > rec.admitted_at
    barrier = (rec.admitted_at + rec.first_token_at) / 2
    event = ReplanEvent(time=barrier, plan=plan)
    seq_rt, seq = _run_mode(plan, trace, "sequential", replan=event)
    evt_rt, evt = _run_mode(plan, trace, "events", replan=event)
    assert _exact_schedule(seq) == _exact_schedule(evt)
    assert seq.makespan == evt.makespan


def test_event_heap_matches_sequential_arrival_at_barrier():
    """A request arriving at *exactly* the barrier time (realistic under
    autoscale ticks at arrival0 + k*interval) must not be admitted at the
    barrier by one mode and deferred/migrated by the other."""
    from repro.core import costmodel
    from repro.core.catalog import DeviceType
    from repro.core.costmodel import Stage
    from repro.core.plan import Config, ServingPlan

    def one_replica_plan(dev_name):
        free = (4096 + 0.5) * 16 * TINY.kv_bytes_per_token
        mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
               / costmodel.MEMORY_UTIL)
        dev = DeviceType(dev_name, 1e12, 1e11, mem, 1.0, 8, 1e11, 1e9, "x")
        cfg = Config(stages=(Stage(dev, 1, 1.0),), model_index=0,
                     model=TINY)
        return ServingPlan(replicas=[cfg], assignment=np.ones((1, 1)),
                           demands=[(0, 0, 2.0)], makespan=1.0,
                           cost=cfg.cost)

    plan = one_replica_plan("barrier-a")
    new_plan = one_replica_plan("barrier-b")    # different key: migration
    trace = Trace("at-barrier", (
        Request(req_id=0, workload=0, input_len=16, output_len=4,
                arrival=0.0),
        Request(req_id=1, workload=0, input_len=16, output_len=4,
                arrival=10.0)))
    event = ReplanEvent(time=10.0, plan=new_plan)
    results = {}
    for mode in ("sequential", "events"):
        executor = CostModelExecutor(plan.replicas, [TINY])
        runtime = ServingRuntime(plan, executor, mode=mode)
        res = runtime.run(trace, replan=event)
        results[mode] = ([r.admission_log for r in runtime.replicas],
                         _exact_schedule(res))
    assert results["sequential"] == results["events"]
    # the barrier-time arrival lands on the *new* plan's replica
    schedule = results["events"][1]
    assert schedule[1][0] == 1


def test_event_heap_matches_sequential_across_replan(replan_setup):
    """Equivalence must survive mid-trace replans (barriers, migration,
    drained replicas)."""
    trace, plan, new_plan = replan_setup
    t_drop = max(r.arrival for r in trace.requests) / 2
    event = ReplanEvent(time=t_drop, plan=new_plan)
    results = {}
    for mode in ("sequential", "events"):
        executor = CostModelExecutor(plan.replicas, [LLAMA3_70B])
        runtime = ServingRuntime(plan, executor, mode=mode)
        res = runtime.run(trace, replan=event)
        results[mode] = (
            [r.admission_log for r in runtime.replicas],
            _exact_schedule(res), res.makespan)
    assert results["sequential"] == results["events"]


def test_per_replica_info_breakdown(small_plan):
    """result.info carries per-replica busy/KV-peak breakdowns (not just
    the max across replicas)."""
    plan, trace = small_plan
    res = simulate(plan, trace, [TINY])
    per = res.info["per_replica"]
    assert len(per) == len(plan.replicas)
    for i, row in enumerate(per):
        assert row["replica"] == i
        assert row["config"] == plan.replicas[i].key
        assert row["busy_s"] == pytest.approx(res.per_replica_busy[i])
        assert row["kv_peak_blocks"] <= row["kv_blocks"]
    assert res.info["kv_peak_blocks"] == max(
        row["kv_peak_blocks"] for row in per)
    assert sum(row["completed"] for row in per) == trace.num_requests


# ------------------------------------------- concurrent engine execution

@pytest.fixture(scope="module")
def engine_servers(small_plan):
    from repro.configs import get_config
    from repro.serving import HeterogeneousServer
    plan, trace = small_plan
    cfg = get_config("llama3-8b").reduced()
    # This test is about thread interleaving, not scheduling jitter: both
    # arms pin fused_steps=1 and a deterministic TickClock so admission
    # cohorts — hence batch shapes, hence every bf16 greedy argmax — are
    # identical across runs.  Unpinned, measured step durations shift
    # cohorts under machine load and distinct decode programs can flip a
    # near-tie (the same root cause the decode-fusion tests pin away).
    from repro.obs import TickClock
    seq = HeterogeneousServer(plan, [cfg], max_batch=8, concurrent=False,
                              fused_steps=1)
    seq.executor.clock = TickClock()
    seq_stats = seq.serve(trace, input_len=8, max_new=4)
    conc = HeterogeneousServer(plan, [cfg], max_batch=8, concurrent=True,
                               fused_steps=1)
    conc.executor.clock = TickClock()
    conc_stats = conc.serve(trace, input_len=8, max_new=4)
    return seq, seq_stats, conc, conc_stats


def test_concurrent_engine_tokens_match_sequential(engine_servers):
    """Threaded per-replica execution must not change any request's token
    stream: per-request prompts are interleaving-independent and each
    replica's calls are serialized on its own worker."""
    seq, seq_stats, conc, conc_stats = engine_servers
    assert seq.executor.token_log == conc.executor.token_log
    assert set(seq.executor.token_log) == {
        r.req.req_id for r in seq_stats.result.records}
    assert seq_stats.completed == conc_stats.completed
    assert seq_stats.generated_tokens == conc_stats.generated_tokens


@pytest.fixture(scope="module")
def engine_wall_server(small_plan):
    """A concurrent server on the *real* clock: the overlap acceptance
    below compares genuine wall time against in-call compute seconds, so
    it cannot share the TickClock-pinned fixture above."""
    from repro.configs import get_config
    from repro.serving import HeterogeneousServer
    plan, trace = small_plan
    cfg = get_config("llama3-8b").reduced()
    conc = HeterogeneousServer(plan, [cfg], max_batch=8, concurrent=True)
    conc_stats = conc.serve(trace, input_len=8, max_new=4)
    return conc, conc_stats


def test_concurrent_execution_overlaps_wall_time(engine_wall_server):
    """Acceptance: with >= 2 replicas, wall-clock run() time is below the
    sum of per-replica in-call compute seconds — replicas genuinely
    overlap instead of serializing on one device."""
    conc, conc_stats = engine_wall_server
    assert len(conc.plan.replicas) >= 2
    total_compute = conc.executor.compute_s
    assert conc_stats.wall_s < total_compute, (
        f"no overlap: wall {conc_stats.wall_s:.2f}s >= "
        f"sum(compute) {total_compute:.2f}s")
    # decode-step EMA is measured (satellite: step_time no longer 0.0)
    # and surfaces through the snapshot/reporting channel
    assert any(conc.executor.step_time(i, []) > 0
               for i in range(len(conc.plan.replicas)))
    assert any(row["step_time_s"] > 0
               for row in conc_stats.result.info["per_replica"])


# ------------------------------------------------- preemption victim policy

def _kv_tight_plan():
    """One replica whose budget holds exactly 4 KV blocks of 16 tokens."""
    from repro.core import costmodel
    from repro.core.catalog import DeviceType
    from repro.core.costmodel import Stage
    from repro.core.plan import Config, ServingPlan
    bs = 16
    block_bytes = bs * TINY.kv_bytes_per_token
    free = (4 + 0.5) * block_bytes
    mem = ((free + TINY.weight_bytes + costmodel.RUNTIME_OVERHEAD_BYTES)
           / costmodel.MEMORY_UTIL)
    dev = DeviceType("kv-tight", 1e12, 1e11, mem, 1.0, 8, 1e11, 1e9, "x")
    cfg = Config(stages=(Stage(dev, 1, 1.0),), model_index=0, model=TINY)
    return ServingPlan(replicas=[cfg], assignment=np.ones((1, 1)),
                       demands=[(0, 0, 2.0)], makespan=1.0, cost=cfg.cost)


@pytest.mark.parametrize("policy,victim", [("latest", 1),
                                           ("fewest-blocks", 0)])
def test_preempt_policy_picks_victim(policy, victim):
    """'latest' evicts the most-recently-admitted request (vLLM recompute
    default); 'fewest-blocks' evicts the cheapest recompute.  Request 0
    holds 1 block, request 1 (admitted second) holds 2."""
    plan = _kv_tight_plan()
    trace = Trace("preempt", (
        Request(req_id=0, workload=0, input_len=4, output_len=64,
                arrival=0.0),
        Request(req_id=1, workload=0, input_len=20, output_len=64,
                arrival=0.0)))
    executor = CostModelExecutor(plan.replicas, [TINY])
    runtime = ServingRuntime(plan, executor, preempt_policy=policy)
    res = runtime.run(trace)
    assert res.num_completed == 2
    by_id = {r.req.req_id: r for r in res.records}
    assert by_id[victim].preemptions >= 1
    assert by_id[1 - victim].preemptions == 0


def test_preempt_policy_rejects_unknown():
    plan = _kv_tight_plan()
    executor = CostModelExecutor(plan.replicas, [TINY])
    with pytest.raises(ValueError):
        ServingRuntime(plan, executor, preempt_policy="oldest")
