"""Dry-run infrastructure tests: HLO collective parsing, per-device byte
accounting, and one real (arch x shape x mesh) lower+compile via subprocess
(the 512-device env var must be set before jax initializes)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_collective_parser_operand_sizes():
    sys.path.insert(0, SRC)
    from repro.launch.dryrun import collective_bytes_per_device
    hlo = """
  %ar = f32[16,4096]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %ag = bf16[32,128]{1,0} all-gather(%y), replica_groups=[8,4]<=[32]
  %rs = f32[8,64]{1,0} reduce-scatter(%z), replica_groups=[2,4]<=[8]
  %a2a = bf16[4,16,8]{2,1,0} all-to-all(%w), replica_groups=[1,4]<=[4]
  %cp = f32[10]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %dot = f32[4,4]{1,0} dot(%a, %b)
"""
    out = collective_bytes_per_device(hlo)
    assert out["all-reduce"] == 16 * 4096 * 4
    assert out["all-gather"] == 32 * 128 * 2 / 4          # result / group
    assert out["reduce-scatter"] == 8 * 64 * 4 * 4        # result * group
    assert out["all-to-all"] == 4 * 16 * 8 * 2
    assert out["collective-permute"] == 10 * 4
    assert "dot" not in out


def test_leaf_device_bytes_sharded():
    sys.path.insert(0, SRC)
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.dryrun import _leaf_device_bytes

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    sds = jax.ShapeDtypeStruct((256, 1024), np.dtype("float32"))
    assert _leaf_device_bytes(sds, P("data", "model"), FakeMesh()) == \
        256 * 1024 * 4 / 256
    assert _leaf_device_bytes(sds, P(None, ("data", "model")), FakeMesh()) == \
        256 * 1024 * 4 / 256
    assert _leaf_device_bytes(sds, P(), FakeMesh()) == 256 * 1024 * 4


@pytest.mark.parametrize("arch,shape", [("xlstm-125m", "decode_32k"),
                                        ("starcoder2-3b", "train_4k")])
def test_dryrun_lowers_and_compiles(arch, shape, tmp_path):
    """Real 512-host-device lower+compile in a fresh subprocess."""
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    rec = json.loads(out.read_text().strip().splitlines()[-1])
    assert "error" not in rec, rec
    assert rec["chips"] == 256
    assert rec["hlo_flops_per_device"] > 0
    assert rec["fits_hbm"]
    assert rec["bottleneck"] in ("compute", "memory", "collective")
