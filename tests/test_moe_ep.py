"""Expert-parallel MoE (shard_map + all-to-all) == single-shard MoE.

Runs in a subprocess with 4 host devices (device count must be set before
jax initializes)."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.models.config import ArchConfig, LayerDesc, ATTN, MOE as FFN_MOE

cfg = ArchConfig(name="m", arch_type="moe", n_layers=1, d_model=32,
                 n_heads=2, n_kv_heads=2, head_dim=16, d_ff=48,
                 vocab_size=64, period=(LayerDesc(ATTN, FFN_MOE),),
                 n_experts=8, n_experts_active=2, moe_d_ff=48)
key = jax.random.PRNGKey(0)
p = jax.tree.map(lambda x: x[0], T._init_ffn(cfg, LayerDesc(ATTN, FFN_MOE), key, 1))
b, s = 8, 16
x = (jax.random.normal(key, (b, s, cfg.d_model)) * 0.5).astype(jnp.bfloat16)

mesh = jax.make_mesh((4,), ("data",))
cf = float(cfg.n_experts) / cfg.n_experts_active  # no-drop capacity

def ep_fn(p_local, x_local):
    return MOE.moe_block_ep(cfg, p_local, x_local, "data", capacity_factor=cf)

p_specs = {"router": P(), "w_gate": P("data", None, None),
           "w_up": P("data", None, None), "w_down": P("data", None, None)}
ep = shard_map(ep_fn, mesh=mesh, in_specs=(p_specs, P("data", None, None)),
               out_specs=P("data", None, None))
y_ep = ep(p, x)
y_ref = MOE.moe_block(cfg, p, x, capacity_factor=cf)
np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                           np.asarray(y_ref, np.float32), rtol=0.05, atol=0.02)
print("EP-OK")
"""


def test_moe_ep_matches_single_shard():
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "EP-OK" in res.stdout
