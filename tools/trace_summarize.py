#!/usr/bin/env python3
"""Summarize an exported serving trace (Chrome trace-event JSON).

Reads a trace written by ``ServingRuntime.export_trace(path)`` /
``Session.export_trace(path)`` and prints, per replica track: busy
fraction, prefill vs decode time split, event counts, preemptions, and —
when the run used a host KV tier — swap-in counts with per-replica
swap-out/swap-in bytes; when faults were injected — per-replica fault
kills and downtime (from each replica's ``dead`` instant to trace end);
plus the control-plane timeline (route drops, replans, autoscale
decisions, fault injections, worker failures, dropped requests).  The
busy seconds and fault/downtime figures printed here are recomputed
purely from the trace's spans and instants, so they cross-check the
runtime's own ``result.info`` accounting (asserted in
``tests/test_observability.py`` and ``tests/test_faults.py``).

    python tools/trace_summarize.py trace.json

Importable: ``summarize(doc)`` returns the summary dict; ``format_summary``
renders the text report.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

CONTROL_TRACK = 1000     # repro.obs.CONTROL_TRACK
WORKER_TRACK0 = 2000     # repro.obs.WORKER_TRACK0


def load_trace(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome trace-event document "
                         f"(no 'traceEvents' key)")
    return doc


def _track_names(events: List[dict]) -> Dict[int, str]:
    names = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            names[e["tid"]] = e["args"]["name"]
    return names


def summarize(doc: dict) -> dict:
    """Aggregate one trace document into per-replica + control summaries.
    Times come back in seconds (trace timestamps are microseconds)."""
    events = doc["traceEvents"]
    names = _track_names(events)
    replicas: Dict[int, dict] = {}
    t_end = 0.0

    def rep(tid: int) -> dict:
        name = names.get(tid, f"track-{tid}")
        # Disaggregated replicas carry their phase role in the config key
        # that register_replica() bakes into the track name
        # ("replica-0 (model:H100x1|prefill)").
        role = "both"
        for r in ("prefill", "decode"):
            if name.endswith(f"|{r})"):
                role = r
        return replicas.setdefault(tid, {
            "track": name, "role": role,
            "busy_s": 0.0, "prefill_s": 0.0, "decode_s": 0.0,
            "prefill_events": 0, "decode_chunks": 0,
            "preemptions": 0, "completed": 0,
            "swap_ins": 0, "swap_in_s": 0.0,
            "swap_in_bytes": 0.0, "swap_out_bytes": 0.0,
            "handoffs": 0, "handoff_s": 0.0,
            "handoff_blocks": 0, "handoff_bytes": 0.0,
            "faults": 0, "dead_at_s": None, "downtime_s": 0.0})

    control: List[dict] = []
    for e in events:
        ph, tid = e.get("ph"), e.get("tid", 0)
        ts = e.get("ts", 0.0) / 1e6
        if ph == "X" and tid < CONTROL_TRACK:
            dur = e.get("dur", 0.0) / 1e6
            r = rep(tid)
            r["busy_s"] += dur
            kind = e.get("cat", "")
            if kind == "prefill":
                r["prefill_s"] += dur
                r["prefill_events"] += 1
            elif kind == "decode":
                r["decode_s"] += dur
                r["decode_chunks"] += 1
            elif kind == "swapin":
                r["swap_ins"] += 1
                r["swap_in_s"] += dur
                r["swap_in_bytes"] += float(
                    e.get("args", {}).get("bytes", 0.0))
            elif kind == "handoff":
                args = e.get("args", {})
                # one span = one exported group; count per request so the
                # figure cross-checks result.info's per-replica "handoffs"
                r["handoffs"] += len(args.get("req_ids", []))
                r["handoff_s"] += dur
                r["handoff_blocks"] += int(args.get("blocks", 0))
                r["handoff_bytes"] += float(args.get("bytes", 0.0))
            t_end = max(t_end, ts + dur)
        elif ph == "i" and tid < CONTROL_TRACK:
            name = e.get("name")
            if name == "preempt":
                rep(tid)["preemptions"] += 1
            elif name == "swap-out":
                r = rep(tid)
                r["preemptions"] += 1
                r["swap_out_bytes"] += float(
                    e.get("args", {}).get("bytes", 0.0))
            elif name == "done":
                rep(tid)["completed"] += 1
            elif name == "dead":
                r = rep(tid)
                r["faults"] += 1
                # Replicas die at most once per run; keep the first stamp.
                if r["dead_at_s"] is None:
                    r["dead_at_s"] = ts
            t_end = max(t_end, ts)
        elif tid == CONTROL_TRACK and ph == "i":
            control.append({"t": ts, "name": e.get("name", ""),
                            "cat": e.get("cat", ""),
                            "args": e.get("args", {})})
            t_end = max(t_end, ts)

    span = t_end if t_end > 0 else 1.0
    for r in replicas.values():
        r["busy_frac"] = r["busy_s"] / span
        # A reclaimed/crashed replica serves nothing after its "dead"
        # instant: its downtime is the tail of the trace (spot replicas
        # never resurrect under the same index — recovery adds capacity
        # through a replan instead).
        if r["dead_at_s"] is not None:
            r["downtime_s"] = max(0.0, t_end - r["dead_at_s"])
    routes = sum(1 for c in control if c["name"] == "route")
    drops = sum(1 for c in control if c["name"] == "drop")
    faults = [c for c in control if c["cat"] == "fault"]
    return {
        "t_end_s": t_end,
        "replicas": [replicas[tid] for tid in sorted(replicas)],
        "routes": routes,
        "drops": drops,
        "replans": [c for c in control if c["cat"] == "replan"],
        "autoscale": [c for c in control if c["cat"] == "autoscale"],
        "faults": faults,
        "worker_failures": sum(1 for c in faults
                               if c["name"] == "worker-failure"),
        "requests_failed": sum(1 for c in faults
                               if c["name"] == "request-failed"),
    }


def format_summary(s: dict) -> str:
    header = (f"trace span: {s['t_end_s']:.4f}s   "
              f"routed: {s['routes']}   dropped: {s['drops']}")
    if s.get("faults"):
        injected = sum(1 for c in s["faults"]
                       if c["name"].startswith("fault-"))
        header += (f"   faults: {injected}   "
                   f"requests failed: {s['requests_failed']}")
    lines = [header]
    swapping = any(r["swap_ins"] or r["swap_out_bytes"]
                   for r in s["replicas"])
    faulty = any(r["faults"] for r in s["replicas"])
    disagg = any(r["role"] != "both" or r["handoffs"]
                 for r in s["replicas"])
    lines.append(f"{'replica':<28}{'busy':>7}{'prefill':>10}{'decode':>10}"
                 f"{'chunks':>8}{'preempt':>9}{'done':>6}"
                 + (f"{'role':>9}{'handoff':>9}{'hnd-MB':>9}"
                    if disagg else "")
                 + (f"{'swapin':>8}{'out-MB':>9}{'in-MB':>8}"
                    if swapping else "")
                 + (f"{'faults':>8}{'down-s':>9}" if faulty else ""))
    for r in s["replicas"]:
        line = (
            f"{r['track']:<28}{r['busy_frac']:>6.1%}"
            f"{r['prefill_s']:>9.4f}s{r['decode_s']:>9.4f}s"
            f"{r['decode_chunks']:>8}{r['preemptions']:>9}"
            f"{r['completed']:>6}")
        if disagg:
            line += (f"{r['role']:>9}{r['handoffs']:>9}"
                     f"{r['handoff_bytes'] / 1e6:>9.2f}")
        if swapping:
            line += (f"{r['swap_ins']:>8}"
                     f"{r['swap_out_bytes'] / 1e6:>9.2f}"
                     f"{r['swap_in_bytes'] / 1e6:>8.2f}")
        if faulty:
            line += f"{r['faults']:>8}{r['downtime_s']:>9.4f}"
        lines.append(line)
    timeline = s["replans"] + s["autoscale"] + s.get("faults", [])
    if timeline:
        lines.append("control-plane timeline:")
        for c in sorted(timeline, key=lambda c: c["t"]):
            args = c["args"]
            if c["cat"] == "autoscale":
                detail = (f"{args.get('action')} {args.get('config')} "
                          f"({args.get('reason')}): "
                          f"{args.get('before')} -> {args.get('after')}")
            elif c["cat"] == "fault":
                if c["name"] == "worker-failure":
                    detail = (f"replica {args.get('replica')}: "
                              f"{args.get('error')}")
                elif c["name"] == "request-failed":
                    detail = (f"req {args.get('req_id')} after "
                              f"{args.get('retries')} retries")
                else:   # fault-reclaim / fault-crash / fault-recover
                    detail = (f"{args.get('gpu_type')} "
                              f"victims={args.get('victims')}")
            else:
                detail = (f"{args.get('before')} -> {args.get('after')} "
                          f"(migrated {args.get('migrated')})")
            lines.append(f"  t={c['t']:>9.4f}s  {c['name']:<16} {detail}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace JSON written by export_trace()")
    args = ap.parse_args(argv)
    try:
        doc = load_trace(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    print(format_summary(summarize(doc)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
