#!/usr/bin/env python3
"""CI gate: compare a pytest junit-xml report against the known-failure
allowlist.  The build fails on any *new* failure/error (regression) and
reports allowlisted entries that now pass (candidates for removal).

    python tools/check_test_baseline.py report.xml tests/known_failures.txt
"""
from __future__ import annotations

import sys
import xml.etree.ElementTree as ET


def load_allowlist(path: str) -> set:
    allow = set()
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                allow.add(line)
    return allow


def failed_tests(report: str):
    failed, total = set(), 0
    root = ET.parse(report).getroot()
    for case in root.iter("testcase"):
        total += 1
        if case.find("failure") is not None or case.find("error") is not None:
            name = f"{case.get('classname', '')}::{case.get('name', '')}"
            failed.add(name)
    return failed, total


def main() -> int:
    report, allowlist_path = sys.argv[1], sys.argv[2]
    allow = load_allowlist(allowlist_path)
    failed, total = failed_tests(report)
    if total == 0:
        # ci.yml swallows pytest's exit code; a report with no testcases
        # means collection itself broke and must not pass as green.
        print("[FAIL] junit report contains zero testcases — "
              "pytest collected nothing")
        return 1
    new = sorted(failed - allow)
    fixed = sorted(allow - failed)
    if fixed:
        print(f"[info] {len(fixed)} allowlisted tests now pass "
              f"(consider removing from {allowlist_path}):")
        for name in fixed:
            print(f"  {name}")
    if new:
        print(f"[FAIL] {len(new)} regressions (failures not in the "
              f"known-failure allowlist):")
        for name in new:
            print(f"  {name}")
        return 1
    print(f"[ok] no regressions: {len(failed)} failures, all allowlisted")
    return 0


if __name__ == "__main__":
    sys.exit(main())
