"""Quickstart: schedule a cost-efficient heterogeneous serving plan.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core loop in ~20 lines: take a workload trace, a
real-time GPU availability snapshot, and a price budget; solve for the GPU
composition + deployment configurations + workload assignment; evaluate the
plan in the cluster simulator.
"""
import sys

from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_70B,
                        make_trace, simulate, solve)


def main():
    budget = float(sys.argv[1]) if len(sys.argv) > 1 else 30.0

    # 1. A workload trace: 1000 requests, Swiss-AI-Center mixture (Table 4).
    trace = make_trace("trace1", num_requests=1000, seed=0)

    # 2. Real-time availability (paper Table 3, Vast.ai snapshot 1).
    availability = AVAILABILITY_SNAPSHOTS["avail1"]

    # 3. Solve: binary-search-on-T over the MILP (App F).
    plan = solve([LLAMA3_70B], trace, GPU_CATALOG, availability, budget)
    print(plan.summary())

    # 4. Evaluate with the event-driven cluster simulator.
    result = simulate(plan, trace, [LLAMA3_70B])
    print(f"\nsimulated: {result.throughput:.2f} req/s over "
          f"{result.makespan:.0f}s makespan")
    print("latency percentiles:",
          {k: round(v, 1) for k, v in result.percentiles().items()})


if __name__ == "__main__":
    main()
