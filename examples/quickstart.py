"""Quickstart: declare a deployment, plan it, evaluate it.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core loop in ~20 lines with the declarative API:
describe *what* to serve (models, workload trace, GPU catalog, real-time
availability snapshot, price budget) as a DeploymentSpec, hand it to
plan() (the MILP planner; strategies "homogeneous" / "uniform" / "fixed"
give the paper's baselines from the same spec), and evaluate the plan in
the cluster simulator.
"""
import sys

from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_70B,
                        DeploymentSpec, make_trace, plan, simulate)


def main():
    budget = float(sys.argv[1]) if len(sys.argv) > 1 else 30.0

    # 1. Declare the deployment: a 1000-request Swiss-AI-Center trace
    #    (Table 4) against the Vast.ai availability snapshot (Table 3).
    spec = DeploymentSpec(
        models=[LLAMA3_70B],
        workload=make_trace("trace1", num_requests=1000, seed=0),
        catalog=GPU_CATALOG,
        availability=AVAILABILITY_SNAPSHOTS["avail1"],
        budget=budget,
    )

    # 2. Plan: binary-search-on-T over the MILP (App F).
    deployment = plan(spec)          # strategy="milp" is the default
    print(deployment.summary())

    # 3. Evaluate with the event-driven cluster simulator.
    result = simulate(deployment, spec.workload, spec.models)
    print(f"\nsimulated: {result.throughput:.2f} req/s over "
          f"{result.makespan:.0f}s makespan")
    print("latency percentiles:",
          {k: round(v, 1) for k, v in result.percentiles().items()})


if __name__ == "__main__":
    main()
