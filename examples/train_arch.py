"""Train any assigned architecture end-to-end (reduced config, real steps).

    PYTHONPATH=src python examples/train_arch.py xlstm-125m 100

All 10 assigned architectures (dense / MoE / hybrid-Mamba / xLSTM / audio /
VLM) train through the same loop; production shapes (train_4k on the 256-chip
mesh) are exercised by ``repro.launch.dryrun``.
"""
import sys
import time

import jax

from repro.configs import get_config, list_archs
from repro.training import AdamW, data_stream, init_state, make_train_step


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "xlstm-125m"
    steps = int(sys.argv[2]) if len(sys.argv) > 2 else 50
    cfg = get_config(arch).reduced()
    print(f"training {cfg.name}: {cfg.n_layers} layers, d={cfg.d_model}, "
          f"~{cfg.param_count()/1e6:.1f}M params")

    opt = AdamW(lr=1e-3)
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    stream = data_stream(cfg, batch=8, seq_len=128, seed=0)

    t0 = time.perf_counter()
    for i in range(steps):
        state, metrics = step(state, next(stream))
        if i % 10 == 0 or i == steps - 1:
            tok_s = (i + 1) * 8 * 128 / (time.perf_counter() - t0)
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"{tok_s:,.0f} tok/s")


if __name__ == "__main__":
    main()
