"""Beyond-paper scheduler extensions in action (declarative edition):

  1. SLO-constrained min-cost planning — "finish the trace within T seconds,
     spend as little as possible": the same DeploymentSpec with
     objective="cost" (the dual of the paper's min-T-under-budget);
  2. availability-drop replanning — the H100 pool is reclaimed *mid-trace*
     (the paper's Fig-2 fluctuation): repro.core.replan re-solves the spec
     against the new snapshot and the event-driven runtime applies the new
     plan online, keeping surviving replicas warm and migrating queued
     requests off the reclaimed ones.

    PYTHONPATH=src python examples/slo_and_replan.py
"""
from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_70B,
                        DeploymentSpec, make_trace, plan, replan, simulate)
from repro.runtime import SLO, ReplanEvent


def main():
    spec = DeploymentSpec(models=[LLAMA3_70B],
                          workload=make_trace("trace1", num_requests=400,
                                              seed=0),
                          catalog=GPU_CATALOG,
                          availability=AVAILABILITY_SNAPSHOTS["avail1"],
                          budget=60.0)

    print("== min-T under budget (the paper's objective) ==")
    fast = plan(spec)
    print(f"T={fast.makespan:.1f}s at {fast.cost:.2f} $/h  "
          f"{fast.composition()}")

    print("\n== min-cost under SLO (ours: objective='cost') ==")
    for factor in (1.2, 2.0, 4.0):
        slo = fast.makespan * factor
        cheap = plan(spec.with_objective("cost", slo_makespan=slo))
        print(f"SLO {slo:6.1f}s -> T={cheap.makespan:6.1f}s at "
              f"{cheap.cost:5.2f} $/h  {cheap.composition()}")

    print("\n== mid-trace availability drop: all H100s reclaimed ==")
    # Streaming arrivals; halfway through, the H100 pool evaporates and the
    # runtime consumes the spec-level replan online.
    live = make_trace("trace1", num_requests=400, arrival_rate=4.0, seed=0)
    t_drop = max(r.arrival for r in live.requests) / 2
    live_spec = spec.with_workload(live)
    dropped = dict(live_spec.availability, H100=0)
    new_plan = replan(fast, live_spec, availability=dropped)
    res = simulate(fast, live, spec.models,
                   replan=ReplanEvent(time=t_drop, plan=new_plan))
    slo = SLO(ttft=60.0, tpot=0.5)
    print(f"replanned at t={t_drop:.0f}s: new plan T={new_plan.makespan:.1f}s "
          f"at {new_plan.cost:.2f} $/h {new_plan.composition()}")
    print(f"runtime: kept {res.info['replicas_kept']:.0f} replicas warm, "
          f"added {res.info['replicas_added']:.0f}, drained "
          f"{res.info['replicas_drained']:.0f}, migrated "
          f"{res.info['requests_migrated']:.0f} queued requests")
    print(f"served {res.num_completed}/{live.num_requests} requests, "
          f"makespan {res.makespan:.1f}s, goodput {res.goodput(slo):.2f} "
          f"req/s ({100 * res.slo_attainment(slo):.0f}% in SLO)")


if __name__ == "__main__":
    main()
