"""Beyond-paper scheduler extensions in action:

  1. SLO-constrained min-cost planning — "finish the trace within T seconds,
     spend as little as possible" (the dual of the paper's min-T-under-budget);
  2. availability-drop replanning — the H100 pool is reclaimed mid-serving
     (the paper's Fig-2 fluctuation) and the scheduler re-rents around it.

    PYTHONPATH=src python examples/slo_and_replan.py
"""
from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_70B,
                        make_trace, simulate, solve)
from repro.core.scheduler import replan, solve_min_cost


def main():
    trace = make_trace("trace1", num_requests=400, seed=0)
    avail = AVAILABILITY_SNAPSHOTS["avail1"]

    print("== min-T under budget (the paper's objective) ==")
    fast = solve([LLAMA3_70B], trace, GPU_CATALOG, avail, 60.0)
    print(f"T={fast.makespan:.1f}s at {fast.cost:.2f} $/h  "
          f"{fast.composition()}")

    print("\n== min-cost under SLO (ours) ==")
    for factor in (1.2, 2.0, 4.0):
        slo = fast.makespan * factor
        plan = solve_min_cost([LLAMA3_70B], trace, GPU_CATALOG, avail, 60.0,
                              slo)
        print(f"SLO {slo:6.1f}s -> T={plan.makespan:6.1f}s at "
              f"{plan.cost:5.2f} $/h  {plan.composition()}")

    print("\n== availability drop: all H100s reclaimed ==")
    dropped = dict(avail, H100=0)
    new_plan = replan(fast, [LLAMA3_70B], trace, GPU_CATALOG, dropped, 60.0)
    sim = simulate(new_plan, trace, [LLAMA3_70B])
    print(f"replanned: T={new_plan.makespan:.1f}s at {new_plan.cost:.2f} $/h "
          f"{new_plan.composition()} "
          f"(kept {new_plan.solver_info.get('replicas_kept', 0):.0f} replicas; "
          f"simulated {sim.throughput:.2f} req/s)")


if __name__ == "__main__":
    main()
