"""Multi-model serving under one budget (App E) + budget-scaling study
(App K): how the scheduler splits heterogeneous resources between Llama3-8B
and Llama3-70B as the budget grows, and how the heterogeneity advantage
varies with budget — one DeploymentSpec, swept with .with_budget().

    PYTHONPATH=src python examples/multimodel_budget.py
"""
from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_8B,
                        LLAMA3_70B, DeploymentSpec, make_trace, plan,
                        simulate)


def main():
    base = DeploymentSpec(
        models=[LLAMA3_8B, LLAMA3_70B],
        workload=make_trace("trace1", num_requests=600, model_mix=(0.8, 0.2),
                            seed=0),
        catalog=GPU_CATALOG,
        availability=AVAILABILITY_SNAPSHOTS["avail2"],
        budget=15.0,
    )

    print(f"{'budget':>7} {'ours rps':>9} {'best-homo rps':>13} "
          f"{'8B share':>9} {'70B share':>10}  composition")
    for budget in (15.0, 30.0, 60.0):
        spec = base.with_budget(budget)
        deployment = plan(spec)
        ours = simulate(deployment, spec.workload, spec.models).throughput
        cost = {0: 0.0, 1: 0.0}
        for cfg in deployment.replicas:
            cost[cfg.model_index] += cfg.cost
        total = max(sum(cost.values()), 1e-9)
        best = 0.0
        for gpu in ("H100", "A6000", "4090"):
            try:
                homo = plan(spec, strategy="homogeneous", gpu_type=gpu)
                best = max(best,
                           simulate(homo, spec.workload,
                                    spec.models).throughput)
            except (RuntimeError, ValueError):
                continue
        print(f"{budget:>7.0f} {ours:>9.2f} {best:>13.2f} "
              f"{100*cost[0]/total:>8.1f}% {100*cost[1]/total:>9.1f}%  "
              f"{deployment.composition()}")


if __name__ == "__main__":
    main()
