"""End-to-end serving driver (the paper's kind of system is a *server*):

  1. schedule a heterogeneous plan for a trace + budget (MILP core),
  2. evaluate it against homogeneous baselines on the unified event-driven
     runtime (cost-model backend): streaming dispatch at arrival time,
     continuous batching, per-request TTFT/TPOT and goodput under an SLO,
  3. EXECUTE the plan with real JAX model replicas through the *same*
     runtime scheduler — the EngineExecutor generates real tokens batch-for-
     batch with the plan evaluation (reduced-config Llama3 on CPU; full
     configs are exercised by the multi-pod dry-run).  Replicas execute
     CONCURRENTLY: the global event heap dispatches each replica's
     prefill/decode calls onto per-replica actor workers,
  4. demonstrate ONLINE AUTOSCALING: a deliberately under-provisioned plan
     served under a ScalePolicy that watches queue depth / KV watermark
     and rents extra replicas mid-trace (cost-model backend).

    PYTHONPATH=src python examples/serve_heterogeneous.py
"""
from repro.configs import get_config
from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_8B,
                        make_trace, simulate, solve, solve_homogeneous)
from repro.core.scheduler import ScalePolicy
from repro.runtime import SLO, CostModelExecutor, ServingRuntime
from repro.serving import HeterogeneousServer


def main():
    budget = 12.0
    trace = make_trace("trace3", num_requests=120, arrival_rate=4.0, seed=0)
    avail = AVAILABILITY_SNAPSHOTS["avail2"]
    slo = SLO(ttft=20.0, tpot=0.5)

    print("== scheduling ==")
    plan = solve([LLAMA3_8B], trace, GPU_CATALOG, avail, budget)
    print(plan.summary())

    print("\n== plan quality vs homogeneous baselines (runtime-predicted) ==")
    ours = simulate(plan, trace, [LLAMA3_8B])
    print(f"ours      : {ours.throughput:.2f} req/s, p90 "
          f"{ours.percentile(90):.1f}s, ttft_p90 "
          f"{ours.ttft_percentile(90):.1f}s, goodput {ours.goodput(slo):.2f} "
          f"req/s ({100 * ours.slo_attainment(slo):.0f}% in SLO)")
    for gpu in ("H100", "A6000", "4090"):
        try:
            homo = solve_homogeneous([LLAMA3_8B], trace, GPU_CATALOG, gpu,
                                     budget)
            sim = simulate(homo, trace, [LLAMA3_8B])
            print(f"homo-{gpu:<6}: {sim.throughput:.2f} req/s, "
                  f"p90 {sim.percentile(90):.1f}s, "
                  f"goodput {sim.goodput(slo):.2f} req/s "
                  f"({100 * sim.slo_attainment(slo):.0f}% in SLO)")
        except (RuntimeError, ValueError) as e:
            print(f"homo-{gpu:<6}: infeasible ({e})")

    print("\n== executing the plan with real JAX replicas (concurrent) ==")
    cfg = get_config("llama3-8b").reduced()
    server = HeterogeneousServer(plan, [cfg], max_batch=8, concurrent=True)
    stats = server.serve(trace, input_len=8, max_new=4)
    res = stats.result
    print(f"served {stats.completed} requests "
          f"({stats.generated_tokens} tokens) on {len(plan.replicas)} "
          f"replicas in {stats.wall_s:.1f}s -> {stats.tokens_per_s:.0f} tok/s")
    print(f"requests per replica: {stats.per_replica_requests}")
    print(f"executed ttft_p90 {res.ttft_percentile(90):.2f}s, "
          f"tpot_p90 {res.tpot_percentile(90):.3f}s "
          f"(same scheduler, measured step times)")
    overlap = server.executor.compute_s / max(stats.wall_s, 1e-9)
    print(f"overlap: {server.executor.compute_s:.1f}s of in-call compute in "
          f"{stats.wall_s:.1f}s wall ({overlap:.2f}x — per-replica actor "
          f"workers run prefill/decode in parallel)")

    print("\n== per-replica breakdown (result.info['per_replica']) ==")
    # Both backends admit by block accounting against the same modeled HBM
    # budget; the engine additionally decodes through real block pools.
    for row in res.info["per_replica"]:
        i = row["replica"]
        paged = server.executor._paged[i]
        backing = (f"paged pool: {paged.num_blocks} x "
                   f"{paged.block_size}-token blocks" if paged is not None
                   else "dense cohort caches")
        print(f"  [{i}] {row['config']}: busy {row['busy_s']:.1f}s, "
              f"completed {row['completed']}, "
              f"kv peak {row['kv_peak_blocks']}/{row['kv_blocks']} blocks — "
              f"{backing}")
    print(f"preemptions (recompute): {int(res.info.get('preemptions', 0))}")

    print("\n== online autoscaling (utilization-driven) ==")
    # Under-provision on purpose: keep only the first replica, then let the
    # ScalePolicy rent the rest back as the queue builds (cost backend).
    from repro.core.plan import ServingPlan
    small = ServingPlan(replicas=plan.replicas[:1],
                        assignment=plan.assignment[:1],
                        demands=plan.demands, makespan=plan.makespan,
                        cost=plan.replicas[0].cost)
    static = simulate(small, trace, [LLAMA3_8B])
    policy = ScalePolicy(candidates=list(plan.replicas), budget=budget,
                         interval=max(static.makespan / 50, 1e-3),
                         window=2, queue_high=2.0, cooldown=1)
    runtime = ServingRuntime(small, CostModelExecutor(small.replicas,
                                                      [LLAMA3_8B]))
    auto = runtime.run(trace, autoscale=policy)
    print(f"static 1-replica: goodput {static.goodput(slo):.2f} req/s, "
          f"makespan {static.makespan:.1f}s")
    print(f"autoscaled      : goodput {auto.goodput(slo):.2f} req/s, "
          f"makespan {auto.makespan:.1f}s "
          f"({int(auto.info.get('autoscale_adds', 0))} adds, "
          f"{int(auto.info.get('autoscale_drains', 0))} drains)")
    for d in runtime.scale_log:
        print(f"  t={d.time:8.2f}s {d.action:5s} {d.config_key} ({d.reason})")


if __name__ == "__main__":
    main()
