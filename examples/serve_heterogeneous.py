"""End-to-end serving driver (the paper's kind of system is a *server*):

  1. declare the deployment (DeploymentSpec) and plan it (MILP core),
  2. evaluate it against homogeneous baselines on the unified event-driven
     runtime (cost-model backend): streaming dispatch at arrival time,
     continuous batching, per-request TTFT/TPOT and goodput under an SLO,
  3. open a LIVE SESSION over the plan — repro.serve(plan) — and submit
     requests online: each submit() returns a handle whose .tokens()
     iterator streams the engine's real tokens as its replica decodes
     them, concurrently across replicas (reduced-config Llama3 on CPU;
     set REPRO_EXAMPLES_BACKEND=cost for a token-free dry run, as the CI
     examples-smoke job does),
  4. demonstrate ONLINE AUTOSCALING: a deliberately under-provisioned plan
     served under a ScalePolicy built from the same spec
     (ScalePolicy.from_spec), renting replicas back as the queue builds.

    PYTHONPATH=src python examples/serve_heterogeneous.py
"""
import os
import sys
import tempfile

import repro
from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_8B,
                        DeploymentSpec, make_trace, plan, simulate)
from repro.core.scheduler import ScalePolicy
from repro.runtime import SLO, CostModelExecutor, ServingRuntime


def main():
    trace = make_trace("trace3", num_requests=120, arrival_rate=4.0, seed=0)
    slo = SLO(ttft=20.0, tpot=0.5)
    spec = DeploymentSpec(models=[LLAMA3_8B], workload=trace,
                          catalog=GPU_CATALOG,
                          availability=AVAILABILITY_SNAPSHOTS["avail2"],
                          budget=12.0, slo=slo)

    print("== scheduling ==")
    deployment = plan(spec)
    print(deployment.summary())

    print("\n== plan quality vs homogeneous baselines (runtime-predicted) ==")
    ours = simulate(deployment, trace, spec.models)
    print(f"ours      : {ours.throughput:.2f} req/s, p90 "
          f"{ours.percentile(90):.1f}s, ttft_p90 "
          f"{ours.ttft_percentile(90):.1f}s, goodput {ours.goodput(slo):.2f} "
          f"req/s ({100 * ours.slo_attainment(slo):.0f}% in SLO)")
    for gpu in ("H100", "A6000", "4090"):
        try:
            homo = plan(spec, strategy="homogeneous", gpu_type=gpu)
            sim = simulate(homo, trace, spec.models)
            print(f"homo-{gpu:<6}: {sim.throughput:.2f} req/s, "
                  f"p90 {sim.percentile(90):.1f}s, "
                  f"goodput {sim.goodput(slo):.2f} req/s "
                  f"({100 * sim.slo_attainment(slo):.0f}% in SLO)")
        except (RuntimeError, ValueError) as e:
            print(f"homo-{gpu:<6}: infeasible ({e})")

    print("\n== live session: online submit() + token streaming ==")
    backend = os.environ.get("REPRO_EXAMPLES_BACKEND", "engine")
    if backend == "engine":
        from repro.configs import get_config
        cfg = get_config("llama3-8b").reduced()
        session = repro.serve(deployment, arch_cfgs=[cfg], input_len=8,
                              max_new=4, max_batch=8, slo=slo)
    else:   # token-free capacity dry run through the identical session code
        session = repro.serve(deployment, backend="cost", models=spec.models,
                              slo=slo)
    with session:
        first = session.submit("why are heterogeneous GPUs cheaper?",
                               workload=4, output_len=3)
        streamed = list(first.tokens(timeout=300))
        print(f"request 0 streamed {len(streamed)} tokens: {streamed}")
        handles = [session.submit(workload=r.workload, input_len=r.input_len,
                                  output_len=r.output_len)
                   for r in trace.requests[:40]]
        for h in handles:
            h.result(timeout=300)
    res = session.result
    print(f"served {res.num_completed} requests live on "
          f"{len(deployment.replicas)} replicas "
          f"(ttft_p90 {res.ttft_percentile(90):.3f}s wall, "
          f"{100 * res.slo_attainment(slo):.0f}% in SLO)")
    print(f"request 0: ttft {first.ttft:.3f}s, tpot {first.tpot:.4f}s, "
          f"slo_met={first.slo_met()}")

    print("\n== per-replica breakdown (result.info['per_replica']) ==")
    for row in res.info["per_replica"]:
        print(f"  [{row['replica']}] {row['config']}: "
              f"busy {row['busy_s']:.2f}s, completed {row['completed']}, "
              f"kv peak {row['kv_peak_blocks']}/{row['kv_blocks']} blocks")
    print(f"preemptions (recompute): {int(res.info.get('preemptions', 0))}")

    print("\n== online autoscaling (utilization-driven, same spec) ==")
    # Under-provision on purpose: keep only the first replica, then let the
    # ScalePolicy rent the rest back as the queue builds (cost backend).
    small = deployment.subset([0])
    static = simulate(small, trace, spec.models)
    policy = ScalePolicy.from_spec(
        spec, deployment, interval=max(static.makespan / 50, 1e-3),
        window=2, queue_high=2.0, cooldown=1)
    obs = repro.Observability()     # trace the autoscale run
    runtime = ServingRuntime(small, CostModelExecutor(small.replicas,
                                                      spec.models),
                             obs=obs)
    auto = runtime.run(trace, autoscale=policy)
    print(f"static 1-replica: goodput {static.goodput(slo):.2f} req/s, "
          f"makespan {static.makespan:.1f}s")
    print(f"autoscaled      : goodput {auto.goodput(slo):.2f} req/s, "
          f"makespan {auto.makespan:.1f}s "
          f"({int(auto.info.get('autoscale_adds', 0))} adds, "
          f"{int(auto.info.get('autoscale_drains', 0))} drains)")
    for d in runtime.scale_log:
        print(f"  t={d.time:8.2f}s {d.action:5s} {d.config_key} ({d.reason})")

    print("\n== observability (exported trace; load in ui.perfetto.dev) ==")
    trace_path = os.path.join(tempfile.gettempdir(),
                              "repro_autoscale_trace.json")
    runtime.export_trace(trace_path)
    print(f"wrote {trace_path} "
          f"({obs.tracer.num_records} trace records)")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "tools"))
    from trace_summarize import format_summary, load_trace, summarize
    print(format_summary(summarize(load_trace(trace_path))))


if __name__ == "__main__":
    main()
