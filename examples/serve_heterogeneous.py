"""End-to-end serving driver (the paper's kind of system is a *server*):

  1. schedule a heterogeneous plan for a trace + budget (MILP core),
  2. evaluate it against homogeneous baselines on the unified event-driven
     runtime (cost-model backend): streaming dispatch at arrival time,
     continuous batching, per-request TTFT/TPOT and goodput under an SLO,
  3. EXECUTE the plan with real JAX model replicas through the *same*
     runtime scheduler — the EngineExecutor generates real tokens batch-for-
     batch with the plan evaluation (reduced-config Llama3 on CPU; full
     configs are exercised by the multi-pod dry-run).

    PYTHONPATH=src python examples/serve_heterogeneous.py
"""
from repro.configs import get_config
from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, LLAMA3_8B,
                        make_trace, simulate, solve, solve_homogeneous)
from repro.runtime import SLO
from repro.serving import HeterogeneousServer


def main():
    budget = 12.0
    trace = make_trace("trace3", num_requests=120, arrival_rate=4.0, seed=0)
    avail = AVAILABILITY_SNAPSHOTS["avail2"]
    slo = SLO(ttft=20.0, tpot=0.5)

    print("== scheduling ==")
    plan = solve([LLAMA3_8B], trace, GPU_CATALOG, avail, budget)
    print(plan.summary())

    print("\n== plan quality vs homogeneous baselines (runtime-predicted) ==")
    ours = simulate(plan, trace, [LLAMA3_8B])
    print(f"ours      : {ours.throughput:.2f} req/s, p90 "
          f"{ours.percentile(90):.1f}s, ttft_p90 "
          f"{ours.ttft_percentile(90):.1f}s, goodput {ours.goodput(slo):.2f} "
          f"req/s ({100 * ours.slo_attainment(slo):.0f}% in SLO)")
    for gpu in ("H100", "A6000", "4090"):
        try:
            homo = solve_homogeneous([LLAMA3_8B], trace, GPU_CATALOG, gpu,
                                     budget)
            sim = simulate(homo, trace, [LLAMA3_8B])
            print(f"homo-{gpu:<6}: {sim.throughput:.2f} req/s, "
                  f"p90 {sim.percentile(90):.1f}s, "
                  f"goodput {sim.goodput(slo):.2f} req/s "
                  f"({100 * sim.slo_attainment(slo):.0f}% in SLO)")
        except (RuntimeError, ValueError) as e:
            print(f"homo-{gpu:<6}: infeasible ({e})")

    print("\n== executing the plan with real JAX replicas ==")
    cfg = get_config("llama3-8b").reduced()
    server = HeterogeneousServer(plan, [cfg], max_batch=8)
    stats = server.serve(trace, input_len=8, max_new=4)
    res = stats.result
    print(f"served {stats.completed} requests "
          f"({stats.generated_tokens} tokens) on {len(plan.replicas)} "
          f"replicas in {stats.wall_s:.1f}s -> {stats.tokens_per_s:.0f} tok/s")
    print(f"requests per replica: {stats.per_replica_requests}")
    print(f"executed ttft_p90 {res.ttft_percentile(90):.2f}s, "
          f"tpot_p90 {res.tpot_percentile(90):.3f}s "
          f"(same scheduler, measured step times)")

    print("\n== KV-cache accounting (paged block admission) ==")
    # Both backends admit by block accounting against the same modeled HBM
    # budget; the engine additionally decodes through real block pools.
    for i, mgr in enumerate(server.executor.kv_managers):
        if mgr is None:
            continue
        paged = server.executor._paged[i]
        backing = (f"paged pool: {paged.num_blocks} x "
                   f"{paged.block_size}-token blocks" if paged is not None
                   else "dense cohort caches")
        unit = f"{mgr.block_size} tokens" if mgr.block_size else "state"
        print(f"  [{i}] budget {mgr.num_blocks} blocks x {unit}, "
              f"peak used {mgr.peak_used} "
              f"({100 * mgr.peak_used / max(mgr.num_blocks, 1):.1f}%) — "
              f"{backing}")
    print(f"preemptions (recompute): {int(res.info.get('preemptions', 0))}")


if __name__ == "__main__":
    main()
