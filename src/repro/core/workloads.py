"""Workload types and trace generation.

The paper characterizes requests by (avg input tokens, avg output tokens) and
subsamples nine workload types from ShareGPT / WildGPT / Azure-Trace with input
lengths {2455, 824, 496} x output lengths {510, 253, 18} (§3).  A *trace* is a
mixture over the nine types (Table 4) plus arrival times.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

INPUT_LENGTHS = (2455, 824, 496)
OUTPUT_LENGTHS = (510, 253, 18)


@dataclasses.dataclass(frozen=True)
class WorkloadType:
    """A request class: average input/output token lengths."""

    input_len: int
    output_len: int

    @property
    def name(self) -> str:
        return f"in{self.input_len}_out{self.output_len}"

    @property
    def kind(self) -> str:
        """Fig-1 style categorization (long input > 512, long output > 128)."""
        i = "long" if self.input_len > 512 else "short"
        o = "long" if self.output_len > 128 else "short"
        return f"{i}_input_{o}_output"


# Workloads 1..9 "shown in Figure 4 from left to right": row-major over
# (input, output) grids used throughout §3.
WORKLOAD_TYPES: Tuple[WorkloadType, ...] = tuple(
    WorkloadType(i, o) for i in INPUT_LENGTHS for o in OUTPUT_LENGTHS
)

# Table 4: workload-type ratios (%) for the three traces.
TRACE_MIXES: Dict[str, Tuple[float, ...]] = {
    "trace1": (33, 7, 8, 7, 27, 6, 6, 3, 3),     # Swiss AI Center
    "trace2": (22, 5, 5, 21, 5, 5, 19, 6, 12),   # Azure-Trace
    "trace3": (4, 1, 4, 3, 20, 27, 1, 25, 15),   # WildGPT
}


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request in a trace."""

    req_id: int
    workload: int          # index into WORKLOAD_TYPES
    input_len: int
    output_len: int
    arrival: float         # seconds since trace start
    model: int = 0         # model index (multi-model serving)
    # Optional prompt token ids (shared-prefix traces / live sessions).
    # When set, prefix-aware admission hashes these for cross-request KV
    # reuse; None keeps the legacy purely-symbolic request.
    prompt: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class Trace:
    name: str
    requests: Tuple[Request, ...]

    def counts_by_type(self, num_types: int = len(WORKLOAD_TYPES),
                       model: int | None = None) -> np.ndarray:
        counts = np.zeros(num_types, dtype=np.int64)
        for r in self.requests:
            if model is None or r.model == model:
                counts[r.workload] += 1
        return counts

    @property
    def num_requests(self) -> int:
        return len(self.requests)


def make_trace(
    name: str,
    num_requests: int = 1000,
    *,
    mix: Sequence[float] | None = None,
    arrival_rate: float | None = None,
    length_jitter: float = 0.0,
    model_mix: Sequence[float] = (1.0,),
    seed: int = 0,
) -> Trace:
    """Generate a synthetic trace following a Table-4 mixture.

    Args:
      name: one of TRACE_MIXES keys (mixture looked up) or any label when
        ``mix`` is given explicitly.
      num_requests: total requests.
      mix: optional explicit 9-way mixture (need not be normalized).
      arrival_rate: Poisson arrival rate (req/s).  None = all arrive at t=0
        (the paper's makespan setting, §4.1).
      length_jitter: relative stddev on token lengths (0 = exact averages).
      model_mix: probability per model index (multi-model, §4.3 ext).
      seed: RNG seed (deterministic).
    """
    rng = np.random.default_rng(seed)
    probs = np.asarray(mix if mix is not None else TRACE_MIXES[name], dtype=np.float64)
    probs = probs / probs.sum()
    types = rng.choice(len(WORKLOAD_TYPES), size=num_requests, p=probs)
    models = rng.choice(len(model_mix), size=num_requests,
                        p=np.asarray(model_mix) / np.sum(model_mix))
    if arrival_rate is None:
        arrivals = np.zeros(num_requests)
    else:
        arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, size=num_requests))
    reqs = []
    for i in range(num_requests):
        w = WORKLOAD_TYPES[types[i]]
        if length_jitter > 0:
            ilen = max(1, int(rng.normal(w.input_len, length_jitter * w.input_len)))
            olen = max(1, int(rng.normal(w.output_len, length_jitter * w.output_len)))
        else:
            ilen, olen = w.input_len, w.output_len
        reqs.append(Request(i, int(types[i]), ilen, olen, float(arrivals[i]), int(models[i])))
    return Trace(name, tuple(reqs))


def nearest_workload(input_len: int, output_len: int) -> int:
    """Index of the WORKLOAD_TYPE closest to (input_len, output_len) in
    relative length space (used to classify ad-hoc prompt traces)."""
    def dist(w: WorkloadType) -> float:
        return (abs(np.log(max(1, input_len) / w.input_len))
                + abs(np.log(max(1, output_len) / w.output_len)))
    return min(range(len(WORKLOAD_TYPES)),
               key=lambda i: dist(WORKLOAD_TYPES[i]))


def make_shared_prefix_trace(
    name: str,
    num_requests: int = 64,
    *,
    input_len: int,
    output_len: int,
    prefix_pool_size: int = 4,
    prefix_len: int | Sequence[int] | None = None,
    hit_ratio: float = 0.9,
    arrival_rate: float | None = None,
    vocab: int = 50_000,
    workload: int | None = None,
    model: int = 0,
    seed: int = 0,
) -> Trace:
    """Generate a trace whose prompts share prefixes — the workload shape
    cross-request prefix caching exploits (multi-turn chat, few-shot
    templates, system prompts).

    A pool of ``prefix_pool_size`` random prefixes is drawn once; each
    request samples a pool prefix with probability ``hit_ratio`` (its
    leading tokens are then byte-identical to every other request using
    that pool entry) or a fresh unique prefix otherwise.  Suffix tokens
    are always unique per request, so prompts diverge after the prefix.

    Args:
      input_len / output_len: token lengths for every request (the prompt
        carries exactly ``input_len`` ids).
      prefix_pool_size: number of distinct shared prefixes.
      prefix_len: shared-prefix length — an int, a sequence to sample
        per pool entry (a length distribution), or None for
        ``input_len // 2``.  Clamped to ``input_len - 1`` so every prompt
        keeps at least one unique-suffix token.
      hit_ratio: probability a request draws from the shared pool.
      arrival_rate: Poisson rate (req/s); None = all arrive at t=0.
      vocab: token id range.
      workload: WORKLOAD_TYPES index; None picks the nearest type.
      model / seed: as in :func:`make_trace`.
    """
    if not 0.0 <= hit_ratio <= 1.0:
        raise ValueError(f"hit_ratio must be in [0, 1], got {hit_ratio}")
    rng = np.random.default_rng(seed)
    if prefix_len is None:
        lens = [max(1, input_len // 2)] * max(1, prefix_pool_size)
    elif isinstance(prefix_len, (int, np.integer)):
        lens = [int(prefix_len)] * max(1, prefix_pool_size)
    else:
        choices = [int(v) for v in prefix_len]
        lens = [int(rng.choice(choices)) for _ in range(max(1, prefix_pool_size))]
    lens = [min(max(1, L), max(1, input_len - 1)) for L in lens]
    pool = [tuple(int(t) for t in rng.integers(0, vocab, size=L))
            for L in lens]
    w = nearest_workload(input_len, output_len) if workload is None \
        else int(workload)
    if arrival_rate is None:
        arrivals = np.zeros(num_requests)
    else:
        arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate,
                                             size=num_requests))
    reqs: List[Request] = []
    for i in range(num_requests):
        if rng.random() < hit_ratio:
            prefix = pool[int(rng.integers(0, len(pool)))]
        else:
            L = lens[int(rng.integers(0, len(lens)))]
            prefix = tuple(int(t) for t in rng.integers(0, vocab, size=L))
        suffix = tuple(int(t) for t in rng.integers(
            0, vocab, size=input_len - len(prefix)))
        reqs.append(Request(i, w, input_len, output_len,
                            float(arrivals[i]), model,
                            prompt=prefix + suffix))
    return Trace(name, tuple(reqs))


def workload_demand(trace: Trace, num_models: int = 1) -> np.ndarray:
    """λ_{m,w}: request counts per (model, workload type)."""
    lam = np.zeros((num_models, len(WORKLOAD_TYPES)), dtype=np.float64)
    for r in trace.requests:
        lam[r.model, r.workload] += 1
    return lam
