"""Event-driven cluster simulator for evaluating serving plans.

Each replica runs continuous batching: admitted requests pay a serialized
prefill, then decode proceeds in lockstep steps whose duration comes from the
same cost model the scheduler uses; the simulator advances replica time to
the next completion event (O(#requests) events per replica, not #tokens).

Outputs the paper's metrics: makespan, overall throughput (req/s), and
percentile latencies (p10..p100).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import costmodel
from repro.core.costmodel import ModelProfile
from repro.core.plan import Config, ServingPlan
from repro.core.workloads import WORKLOAD_TYPES, Request, Trace


@dataclasses.dataclass
class SimResult:
    makespan: float
    throughput: float                    # completed requests / makespan
    latencies: np.ndarray                # per-request completion − arrival
    per_replica_busy: np.ndarray

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies, p))

    def percentiles(self, ps: Sequence[int] = (10, 30, 50, 70, 90, 100)) -> Dict[str, float]:
        return {f"p{p}": self.percentile(p) for p in ps}


@dataclasses.dataclass
class _Active:
    req: Request
    remaining: int           # decode tokens left


class _ReplicaSim:
    """Continuous-batching simulation of one replica."""

    def __init__(self, config: Config, model: ModelProfile):
        self.config = config
        self.model = model
        self.queue: List[Request] = []
        self.active: List[_Active] = []
        self.now = 0.0
        self.busy = 0.0
        self.completions: List[tuple] = []   # (req_id, finish_time)

    def _max_batch(self) -> int:
        caps = [costmodel.max_batch_size(self.config.stages, self.model,
                                         WORKLOAD_TYPES[r.workload])
                for r in (self.queue[:1] or [])]
        # Use the first queued request's workload as the cap proxy; mixed
        # batches use the min cap across active workloads.
        b = costmodel.MAX_BATCH
        for a in self.active:
            b = min(b, costmodel.max_batch_size(
                self.config.stages, self.model, WORKLOAD_TYPES[a.req.workload]))
        if caps:
            b = min(b, caps[0])
        return max(1, int(b))

    def _admit(self):
        """Admit queued requests (continuous batching: classes mix freely),
        paying each request's prefill serially on admission."""
        while self.queue and len(self.active) < self._max_batch():
            r = self.queue[0]
            if r.arrival > self.now and not self.active:
                self.now = r.arrival
            if r.arrival > self.now:
                break
            self.queue.pop(0)
            t_pre = max(costmodel._stage_prefill_time(st, self.model, r.input_len)
                        for st in self.config.stages)
            self.now += t_pre
            self.busy += t_pre
            self.active.append(_Active(r, max(1, r.output_len)))

    def step(self) -> bool:
        """Advance to the next completion. Returns False when idle+empty."""
        if not self.active:
            if not self.queue:
                return False
            self._admit()
            if not self.active:
                return False
        batch = len(self.active)
        avg_ctx = float(np.mean([a.req.input_len + (a.req.output_len - a.remaining)
                                 for a in self.active])) + 1.0
        t_step = max(costmodel._stage_decode_step_time(st, self.model, batch, avg_ctx)
                     for st in self.config.stages)
        k = min(a.remaining for a in self.active)
        # Don't overshoot the next arrival (so we can admit mid-flight).
        if self.queue:
            next_arrival = self.queue[0].arrival
            if next_arrival > self.now:
                k = max(1, min(k, int((next_arrival - self.now) / max(t_step, 1e-12)) + 1))
        self.now += k * t_step
        self.busy += k * t_step
        still: List[_Active] = []
        for a in self.active:
            a.remaining -= k
            if a.remaining <= 0:
                self.completions.append((a.req.req_id, self.now))
            else:
                still.append(a)
        self.active = still
        self._admit()
        return True


def simulate(plan: ServingPlan, trace: Trace,
             models: Sequence[ModelProfile], *, seed: int = 0) -> SimResult:
    """Dispatch the trace per the plan's assignment and simulate each replica.

    Dispatch is deterministic deficit-round-robin (the same policy as the
    runtime's AssignmentRouter): realized per-replica fractions track the
    plan's x_{c,w} to within one request, so simulated makespan reflects the
    plan rather than multinomial sampling noise.
    """
    demand_index = {(m, w): d for d, (m, w, _) in enumerate(plan.demands)}
    replicas = [_ReplicaSim(cfg, models[cfg.model_index]) for cfg in plan.replicas]
    credit = np.zeros_like(plan.assignment)

    for r in sorted(trace.requests, key=lambda q: q.arrival):
        d = demand_index.get((r.model, r.workload))
        if d is None:
            continue
        probs = np.clip(plan.assignment[:, d], 0, None)
        total = probs.sum()
        if total <= 0:
            # plan doesn't cover this demand (shouldn't happen) — round robin
            i = r.req_id % len(replicas)
        else:
            credit[:, d] += probs / total
            i = int(np.argmax(credit[:, d]))
            credit[i, d] -= 1.0
        replicas[i].queue.append(r)

    finishes: List[float] = []
    latencies: List[float] = []
    arrival_by_id = {r.req_id: r.arrival for r in trace.requests}
    for rep in replicas:
        while rep.step():
            pass
        for req_id, t in rep.completions:
            finishes.append(t)
            latencies.append(t - arrival_by_id[req_id])

    makespan = max(finishes) if finishes else 0.0
    n = len(finishes)
    return SimResult(
        makespan=makespan,
        throughput=n / makespan if makespan > 0 else 0.0,
        latencies=np.array(sorted(latencies)),
        per_replica_busy=np.array([rep.busy for rep in replicas]),
    )
