"""Event-driven cluster simulator for evaluating serving plans.

Thin wrapper over the unified serving runtime (``repro.runtime``): the
continuous-batching replica loop, streaming dispatch, and SLO accounting
all live there, shared verbatim with the real-token server — this module
just binds the :class:`~repro.runtime.executor.CostModelExecutor` backend
so step durations come from the same cost model the scheduler plans with.

Outputs the paper's metrics (makespan, overall throughput in req/s,
percentile latencies) plus per-request TTFT/TPOT and ``goodput(slo)``.
``SimResult`` is an alias of :class:`repro.runtime.RuntimeResult` kept for
backwards compatibility.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.costmodel import ModelProfile
from repro.core.plan import ServingPlan
from repro.core.workloads import Trace
from repro.runtime.lifecycle import RuntimeResult

SimResult = RuntimeResult


def simulate(plan: ServingPlan, trace: Trace,
             models: Sequence[ModelProfile], *, seed: int = 0,
             replan=None) -> RuntimeResult:
    """Simulate serving ``trace`` under ``plan``.

    Requests are dispatched at arrival time by the plan's deficit-round-robin
    ``AssignmentRouter`` (realized per-replica fractions track the plan's
    x_{c,w} to within one request), then each replica runs continuous
    batching with cost-model step times.  ``replan`` optionally passes
    :class:`repro.runtime.ReplanEvent` s for mid-trace availability changes.
    ``seed`` is kept for API compatibility (dispatch is deterministic).
    """
    del seed
    # Imported here (not at module top) to keep repro.core <-> repro.runtime
    # importable in either order.
    from repro.runtime.executor import CostModelExecutor
    from repro.runtime.orchestrator import ServingRuntime
    executor = CostModelExecutor(plan.replicas, models)
    return ServingRuntime(plan, executor).run(trace, replan=replan)
