"""The paper's scheduling MILP (§4.3) over scipy/HiGHS.

    arg min T
    s.t.  Σ_c x_{c,w} = 1                       ∀w   (assignment)
          Σ_w x_{c,w}·λ_w/(y_c·h_{c,w}) ≤ T     ∀c   (makespan)
          x_{c,w} ≤ y_c                         ∀c,w (activation coupling)
          Σ_c o_c·y_c ≤ B                            (budget)
          Σ_c d_n(c)·y_c ≤ a_n                  ∀n   (availability)
          y_c ∈ {0,1,2,...}

The makespan constraint is bilinear in (T, y_c).  We linearize it exactly:
multiply through by y_c, expand y_c = Σ_k k·u_{c,k} with binaries u_{c,k}
(Σ_k u_{c,k} ≤ 1), and introduce v_{c,k} ⩬ T·u_{c,k} via its upper McCormick
envelope (v ≤ T, v ≤ T_ub·u) — upper envelope suffices because the solver
*wants* v large (it relaxes the makespan constraint), so at optimum
v_{c,k} = min(T, T_ub·u_{c,k}) = T·u_{c,k} exactly:

          Σ_w x_{c,w}·λ_w/h_{c,w} ≤ Σ_k k·v_{c,k}   ∀c.

The multi-model extension (App E) is handled by generalizing workload columns
to *demands* d = (model m, workload w, volume λ): configs built for model m
have h_{c,d} = 0 for demands of other models, and budget/availability couple
all models — exactly Eqs. (8)-(12).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp

from repro.core.plan import Config, ServingPlan

MAX_COPIES = 64  # hard cap on y_c (availability usually binds first)


@dataclasses.dataclass
class SchedulingProblem:
    """Inputs to the scheduler, after config enumeration and costing."""

    configs: List[Config]
    h: np.ndarray                       # (C, D) req/s; 0 = config can't serve demand
    demands: List[Tuple[int, int, float]]  # (model, workload, λ) with λ > 0
    budget: float
    availability: Mapping[str, int]

    def __post_init__(self):
        assert self.h.shape == (len(self.configs), len(self.demands))

    @property
    def lam(self) -> np.ndarray:
        return np.array([d[2] for d in self.demands], dtype=float)

    def y_max(self, c: int) -> int:
        """Copies of config c that availability and budget allow."""
        cfg = self.configs[c]
        k = MAX_COPIES
        for name, n in cfg.device_counts().items():
            k = min(k, self.availability.get(name, 0) // n)
        if cfg.cost > 0:
            k = min(k, int(self.budget // cfg.cost))
        return max(k, 0)

    def makespan_upper_bound(self) -> float:
        """T_ub: serve each model's whole demand serially on its cheapest
        single usable config (App G's worst-case bound)."""
        total = 0.0
        models = sorted({m for (m, _, _) in self.demands})
        for m in models:
            d_idx = [i for i, (mm, _, _) in enumerate(self.demands) if mm == m]
            best: Optional[float] = None
            for c, cfg in enumerate(self.configs):
                if cfg.model_index != m or self.y_max(c) < 1:
                    continue
                if any(self.h[c, d] <= 0 for d in d_idx):
                    continue
                t = sum(self.lam[d] / self.h[c, d] for d in d_idx)
                best = t if best is None else min(best, t)
            if best is None:
                raise ValueError(f"no feasible single config for model {m}")
            total += best
        return 2.0 * total


def _plan_from_solution(problem: SchedulingProblem, y: np.ndarray, x: np.ndarray,
                        info: Dict[str, float]) -> ServingPlan:
    """Expand (y_c, x_{c,d}) into per-replica rows (copies split x evenly)."""
    replicas: List[Config] = []
    rows: List[np.ndarray] = []
    for c, cfg in enumerate(problem.configs):
        copies = int(round(y[c]))
        for _ in range(copies):
            replicas.append(cfg)
            rows.append(x[c] / copies)
    assignment = np.array(rows) if rows else np.zeros((0, len(problem.demands)))
    makespan = plan_makespan(problem, y, x)
    cost = float(sum(cfg.cost * int(round(y[c])) for c, cfg in enumerate(problem.configs)))
    return ServingPlan(replicas=replicas, assignment=assignment,
                       demands=list(problem.demands), makespan=makespan,
                       cost=cost, solver_info=info)


def plan_makespan(problem: SchedulingProblem, y: np.ndarray, x: np.ndarray) -> float:
    """max_c Σ_d x_{c,d}·λ_d / (y_c·h_{c,d})."""
    t = 0.0
    lam = problem.lam
    for c in range(len(problem.configs)):
        if round(y[c]) < 1:
            continue
        tc = 0.0
        for d in range(len(problem.demands)):
            if x[c, d] > 1e-9:
                tc += x[c, d] * lam[d] / (round(y[c]) * problem.h[c, d])
        t = max(t, tc)
    return t


def solve_milp(problem: SchedulingProblem, *, time_limit: float = 120.0,
               mip_rel_gap: float = 1e-3) -> ServingPlan:
    """Direct min-makespan MILP with the exact linearization above."""
    C, D = problem.h.shape
    lam = problem.lam
    T_ub = problem.makespan_upper_bound()
    kmax = [problem.y_max(c) for c in range(C)]
    usable = [c for c in range(C) if kmax[c] >= 1]

    # Variable layout: [T | x (C*D) | u (Σ kmax) | v (Σ kmax)]
    n_x = C * D
    u_off: Dict[int, int] = {}
    off = 1 + n_x
    for c in usable:
        u_off[c] = off
        off += kmax[c]
    n_u = off - (1 + n_x)
    v_off = {c: u_off[c] + n_u for c in usable}
    n_var = 1 + n_x + 2 * n_u

    def xi(c: int, d: int) -> int:
        return 1 + c * D + d

    lb = np.zeros(n_var)
    ub = np.full(n_var, np.inf)
    ub[0] = T_ub
    for c in range(C):
        for d in range(D):
            ub[xi(c, d)] = 1.0 if (c in u_off and problem.h[c, d] > 0) else 0.0
    for c in usable:
        ub[u_off[c]: u_off[c] + kmax[c]] = 1.0      # binaries
        ub[v_off[c]: v_off[c] + kmax[c]] = T_ub      # v = T·u
    integrality = np.zeros(n_var)
    for c in usable:
        integrality[u_off[c]: u_off[c] + kmax[c]] = 1

    rows, cols, vals, c_lb, c_ub = [], [], [], [], []
    r = 0

    def add(entries, lo, hi):
        nonlocal r
        for col, val in entries:
            rows.append(r); cols.append(col); vals.append(val)
        c_lb.append(lo); c_ub.append(hi)
        r += 1

    # (2) assignment: Σ_c x_{c,d} = 1
    for d in range(D):
        add([(xi(c, d), 1.0) for c in range(C)], 1.0, 1.0)
    # (3) makespan: Σ_d x λ/h − Σ_k k·v_{c,k} ≤ 0
    for c in usable:
        ent = [(xi(c, d), lam[d] / problem.h[c, d])
               for d in range(D) if problem.h[c, d] > 0]
        ent += [(v_off[c] + k, -(k + 1.0)) for k in range(kmax[c])]
        add(ent, -np.inf, 0.0)
    # McCormick: v − T ≤ 0 ; v − T_ub·u ≤ 0
    for c in usable:
        for k in range(kmax[c]):
            add([(v_off[c] + k, 1.0), (0, -1.0)], -np.inf, 0.0)
            add([(v_off[c] + k, 1.0), (u_off[c] + k, -T_ub)], -np.inf, 0.0)
    # SOS-ish: Σ_k u_{c,k} ≤ 1
    for c in usable:
        add([(u_off[c] + k, 1.0) for k in range(kmax[c])], 0.0, 1.0)
    # (4) activation: x_{c,d} − y_c ≤ 0
    for c in usable:
        for d in range(D):
            if problem.h[c, d] > 0:
                ent = [(xi(c, d), 1.0)]
                ent += [(u_off[c] + k, -(k + 1.0)) for k in range(kmax[c])]
                add(ent, -np.inf, 0.0)
    # (5) budget: Σ_c o_c Σ_k k·u ≤ B
    ent = []
    for c in usable:
        ent += [(u_off[c] + k, problem.configs[c].cost * (k + 1.0)) for k in range(kmax[c])]
    add(ent, 0.0, problem.budget)
    # (6) availability per device type
    names = sorted({n for c in usable for n in problem.configs[c].device_counts()})
    for name in names:
        ent = []
        for c in usable:
            dn = problem.configs[c].device_counts().get(name, 0)
            if dn:
                ent += [(u_off[c] + k, dn * (k + 1.0)) for k in range(kmax[c])]
        add(ent, 0.0, float(problem.availability.get(name, 0)))

    A = sp.csr_matrix((vals, (rows, cols)), shape=(r, n_var))
    obj = np.zeros(n_var)
    obj[0] = 1.0

    t0 = time.perf_counter()
    res = milp(c=obj, constraints=LinearConstraint(A, c_lb, c_ub),
               integrality=integrality, bounds=Bounds(lb, ub),
               options={"time_limit": time_limit, "mip_rel_gap": mip_rel_gap})
    elapsed = time.perf_counter() - t0
    if res.status not in (0, 1) or res.x is None:
        raise RuntimeError(f"MILP failed: status={res.status} {res.message}")

    sol = res.x
    y = np.zeros(C)
    for c in usable:
        u = sol[u_off[c]: u_off[c] + kmax[c]]
        y[c] = float(np.round(u).dot(np.arange(1, kmax[c] + 1)))
    x = np.zeros((C, D))
    for c in range(C):
        for d in range(D):
            x[c, d] = max(0.0, sol[xi(c, d)])
    info = {"solver": 0.0, "solve_time_s": elapsed, "objective_T": float(sol[0]),
            "mip_gap": float(getattr(res, "mip_gap", 0.0) or 0.0)}
    return _plan_from_solution(problem, y, x, info)


def solve_feasibility(problem: SchedulingProblem, t_hat: float, *,
                      time_limit: float = 30.0,
                      minimize_cost: bool = True
                      ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """App-F feasibility check: is there a plan with makespan ≤ T̂?

    For fixed T̂ the makespan constraint Σ_d x·λ/h ≤ T̂·y_c is *linear*, so
    this is a plain MILP with integer y — no linearization needed.  Returns
    (y, x) or None.
    """
    C, D = problem.h.shape
    lam = problem.lam
    kmax = [problem.y_max(c) for c in range(C)]

    # Layout: [x (C*D) | y (C)]
    n_var = C * D + C

    def xi(c: int, d: int) -> int:
        return c * D + d

    def yi(c: int) -> int:
        return C * D + c

    lb = np.zeros(n_var)
    ub = np.zeros(n_var)
    for c in range(C):
        ub[yi(c)] = kmax[c]
        for d in range(D):
            ub[xi(c, d)] = 1.0 if (kmax[c] >= 1 and problem.h[c, d] > 0) else 0.0
    integrality = np.zeros(n_var)
    integrality[C * D:] = 1

    rows, cols, vals, c_lb, c_ub = [], [], [], [], []
    r = 0

    def add(entries, lo, hi):
        nonlocal r
        for col, val in entries:
            rows.append(r); cols.append(col); vals.append(val)
        c_lb.append(lo); c_ub.append(hi)
        r += 1

    for d in range(D):
        add([(xi(c, d), 1.0) for c in range(C)], 1.0, 1.0)
    for c in range(C):
        if kmax[c] < 1:
            continue
        ent = [(xi(c, d), lam[d] / problem.h[c, d])
               for d in range(D) if problem.h[c, d] > 0]
        ent.append((yi(c), -t_hat))
        add(ent, -np.inf, 0.0)
        for d in range(D):
            if problem.h[c, d] > 0:
                add([(xi(c, d), 1.0), (yi(c), -1.0)], -np.inf, 0.0)
    add([(yi(c), problem.configs[c].cost) for c in range(C)], 0.0, problem.budget)
    names = sorted({n for cfg in problem.configs for n in cfg.device_counts()})
    for name in names:
        ent = [(yi(c), float(problem.configs[c].device_counts().get(name, 0)))
               for c in range(C)]
        add(ent, 0.0, float(problem.availability.get(name, 0)))

    A = sp.csr_matrix((vals, (rows, cols)), shape=(r, n_var))
    obj = np.zeros(n_var)
    if minimize_cost:
        for c in range(C):
            obj[yi(c)] = problem.configs[c].cost

    res = milp(c=obj, constraints=LinearConstraint(A, c_lb, c_ub),
               integrality=integrality, bounds=Bounds(lb, ub),
               options={"time_limit": time_limit})
    # status 1 = time/iteration limit: HiGHS may still carry a feasible
    # incumbent (res.x is not None), which is a perfectly good witness that
    # makespan <= t_hat — rejecting it made the binary search treat "slow
    # to prove optimal" as "infeasible" and silently degrade plans under
    # tight time limits (solve_milp already accepts (0, 1) the same way).
    if res.status not in (0, 1) or res.x is None:
        return None
    sol = res.x
    y = np.array([round(sol[yi(c)]) for c in range(C)], dtype=float)
    x = np.array([[max(0.0, sol[xi(c, d)]) for d in range(D)] for c in range(C)])
    return y, x
