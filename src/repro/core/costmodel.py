"""Analytical serving cost model.

The paper obtains the per-config/per-workload throughput table ``h_{c,w}`` via
one-time profiling on real GPUs (§4.3, item iv).  Without heterogeneous
hardware in this container we replace profiling with an analytical roofline
model with the *same interface* — a table ``h[c][w]`` in requests/second — and
additionally support loading an externally profiled table (``ProfiledThroughput``).

The model captures exactly the physics the paper's observations rest on:

* prefill is compute-bound  →  t_prefill ≈ FLOPs / (Σ peak_flops · MFU) + TP comm
* decode is memory-bound    →  t_step   ≈ bytes(weights_active + KV) / HBM_bw + TP comm
* batch size is capped by the KV-cache memory left after weights
* TP adds per-layer all-reduce cost over the intra-machine link
* PP throughput is bottlenecked by its slowest stage; activations cross the
  inter-machine network

so "workstation GPUs win memory-bound decode per dollar", "H100 wins
compute-bound prefill", and "consumer GPUs win small models" all emerge from
first principles (§3 Observations 1–3).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

from repro.core.catalog import DeviceType
from repro.core.workloads import WorkloadType

BYTES_PER_PARAM = 2  # bf16 serving

# Utilization knobs (single global calibration, not per-GPU fudge factors).
PREFILL_MFU = 0.55
DECODE_BW_UTIL = 0.75
# Effective concurrent batch in the paper's trace-driven serving regime
# (trace concurrency and latency SLOs keep effective decode batches well
# under vLLM's max_num_seqs).  The cap balances the paper's two capacity
# arguments: small enough that bandwidth-per-dollar decides (Observation
# 1 iii: consumer GPUs win small models), large enough that KV-memory
# capacity per dollar matters (Observation 1 ii: workstation GPUs' 1.8x
# memory/$ wins memory-bound 70B workloads).
MAX_BATCH = 64
MEMORY_UTIL = 0.9  # vLLM gpu_memory_utilization: usable fraction of HBM
RUNTIME_OVERHEAD_BYTES = 1 * 1024**3  # per-device activations/framework
# Unhidden per-boundary cost of a pipeline hop (NCCL-over-TCP handshake +
# framing on commodity Ethernet).  Charged per prefill and per decode step:
# single-batch PP (vLLM semantics) does not overlap the hop with compute.
PP_BOUNDARY_LATENCY_S = 3e-3
# Achievable fraction of the host link's nominal bandwidth for block-granular
# KV copies (pinned buffers, but many mid-sized transfers).
HOST_LINK_UTIL = 0.8


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Static facts about a model needed to cost serving it.

    ``params_active`` differs from ``params_total`` for MoE (top-k activated
    experts); ``n_attn_layers`` differs from ``n_layers`` for hybrids (Jamba);
    ``window`` bounds the KV context for sliding-window attention;
    ``state_bytes`` is the constant recurrent state (SSM/xLSTM) per sequence.
    """

    name: str
    n_layers: int
    d_model: int
    n_kv_heads: int
    head_dim: int
    params_total: float
    params_active: float
    n_attn_layers: int = -1           # -1 → == n_layers
    window: int = 0                   # 0 → full attention
    state_bytes_per_seq: float = 0.0  # SSM/recurrent state
    vocab: int = 32000

    @property
    def attn_layers(self) -> int:
        return self.n_layers if self.n_attn_layers < 0 else self.n_attn_layers

    @property
    def weight_bytes(self) -> float:
        return self.params_total * BYTES_PER_PARAM

    @property
    def active_weight_bytes(self) -> float:
        return self.params_active * BYTES_PER_PARAM

    @property
    def kv_bytes_per_token(self) -> float:
        """KV-cache bytes appended per generated/prefilled token (all layers)."""
        return 2 * self.attn_layers * self.n_kv_heads * self.head_dim * BYTES_PER_PARAM

    def kv_context(self, context_len: int) -> float:
        """Effective KV length actually attended to / held."""
        if self.window and self.window < context_len:
            return float(self.window)
        return float(context_len)

    def min_memory_bytes(self) -> float:
        """M_r in the paper's App-D memory check (weights + one request's KV)."""
        return self.weight_bytes * 1.2


# The paper's evaluation models.
LLAMA3_8B = ModelProfile(
    name="llama3-8b", n_layers=32, d_model=4096, n_kv_heads=8, head_dim=128,
    params_total=8.03e9, params_active=8.03e9, vocab=128256)
LLAMA3_70B = ModelProfile(
    name="llama3-70b", n_layers=80, d_model=8192, n_kv_heads=8, head_dim=128,
    params_total=70.6e9, params_active=70.6e9, vocab=128256)


@dataclasses.dataclass(frozen=True)
class Stage:
    """One pipeline stage: ``tp`` devices of one type within one machine."""

    device: DeviceType
    tp: int
    layer_frac: float  # fraction of layers on this stage (App-D non-uniform split)

    @property
    def price(self) -> float:
        return self.tp * self.device.price_per_hour

    @property
    def memory(self) -> float:
        return self.tp * self.device.memory_bytes


def _tp_allreduce_time(stage: Stage, act_bytes: float, n_layers: float) -> float:
    """Per-layer tensor-parallel all-reduce cost (2 all-reduces per layer)."""
    if stage.tp == 1:
        return 0.0
    ring_factor = 2.0 * (stage.tp - 1) / stage.tp
    return 2.0 * n_layers * act_bytes * ring_factor / stage.device.intra_bw


def _stage_prefill_time(stage: Stage, model: ModelProfile, s_in: int) -> float:
    frac = stage.layer_frac
    # Dense matmul FLOPs ≈ 2·P_active·S, plus quadratic attention term.
    attn_ctx = model.kv_context(s_in)
    flops = (2.0 * model.params_active * s_in
             + 4.0 * model.attn_layers * s_in * attn_ctx * model.n_kv_heads * model.head_dim) * frac
    compute = stage.tp * stage.device.dense_peak_flops * PREFILL_MFU
    t_compute = flops / compute
    # Weight read (matters for tiny prompts / huge models).
    t_mem = frac * model.active_weight_bytes / stage.tp / (stage.device.hbm_bandwidth * DECODE_BW_UTIL)
    act_bytes = s_in * model.d_model * BYTES_PER_PARAM
    t_comm = _tp_allreduce_time(stage, act_bytes, model.n_layers * frac)
    return max(t_compute, t_mem) + t_comm


def _stage_decode_step_time(stage: Stage, model: ModelProfile, batch: float,
                            context: float) -> float:
    frac = stage.layer_frac
    kv_read = batch * model.kv_context(context) * model.kv_bytes_per_token * frac
    state_read = batch * model.state_bytes_per_seq * frac
    bytes_read = frac * model.active_weight_bytes / stage.tp + (kv_read + state_read) / stage.tp
    t_mem = bytes_read / (stage.device.hbm_bandwidth * DECODE_BW_UTIL)
    flops = 2.0 * model.params_active * batch * frac
    t_compute = flops / (stage.tp * stage.device.dense_peak_flops * PREFILL_MFU)
    act_bytes = batch * model.d_model * BYTES_PER_PARAM
    t_comm = _tp_allreduce_time(stage, act_bytes, model.n_layers * frac)
    return max(t_mem, t_compute) + t_comm


def host_link_bandwidth(stages: Sequence[Stage]) -> float:
    """Aggregate host<->device KV-copy bandwidth of one replica (bytes/s).

    Each pipeline stage holds a disjoint layer shard of every KV block, and
    its ``tp`` devices copy their slices in parallel over independent host
    links; a whole-block transfer therefore completes when the *slowest*
    stage finishes its shard."""
    return min(st.tp * st.device.host_bw for st in stages)


def swap_time_s(stages: Sequence[Stage], n_bytes: float) -> float:
    """Modeled wall time to move ``n_bytes`` of KV cache across the host link."""
    bw = host_link_bandwidth(stages) * HOST_LINK_UTIL
    if bw <= 0 or n_bytes <= 0:
        return 0.0 if n_bytes <= 0 else float("inf")
    return n_bytes / bw


def phase_affinity(device: DeviceType) -> float:
    """Compute-vs-bandwidth affinity of one GPU type: achievable prefill
    FLOP/s per achievable decode byte/s.  Prefill is compute-bound and
    decode is memory-bound (§3), so a high ratio marks a GPU whose
    silicon is better spent on prefill and a low one a GPU whose HBM
    bandwidth (and capacity per dollar) favors decode — the partition
    axis the ``"disagg"`` planner splits the catalog along."""
    bw = device.hbm_bandwidth * DECODE_BW_UTIL
    if bw <= 0:
        return float("inf")
    return device.dense_peak_flops * PREFILL_MFU / bw


def interconnect_bandwidth(src_stages: Sequence[Stage],
                           dst_stages: Sequence[Stage]) -> float:
    """Cross-replica KV transfer bandwidth between two replicas (bytes/s).

    Within each replica, every pipeline stage holds a disjoint layer
    shard of each KV block and its ``tp`` devices move their slices in
    parallel, so a replica's aggregate rate is gated by its slowest
    stage; the end-to-end handoff is gated by the slower endpoint."""
    def replica_bw(stages: Sequence[Stage]) -> float:
        return min(st.tp * st.device.interconnect_bw for st in stages)
    return min(replica_bw(src_stages), replica_bw(dst_stages))


def handoff_time_s(src_stages: Sequence[Stage],
                   dst_stages: Sequence[Stage], n_bytes: float) -> float:
    """Modeled wall time to migrate ``n_bytes`` of paged KV from a
    prefill replica to a decode replica over the interconnect."""
    bw = interconnect_bandwidth(src_stages, dst_stages) * HOST_LINK_UTIL
    if n_bytes <= 0:
        return 0.0
    if bw <= 0:
        return float("inf")
    return n_bytes / bw


def preempt_costs(stages: Sequence[Stage], model: ModelProfile, *,
                  swap_bytes: float, prompt_tokens: int) -> Tuple[float, float]:
    """(modeled swap time, modeled recompute time) for one preemption victim.

    Swap pays the victim's KV bytes over the host link twice (copy-out at
    preemption, copy-in at readmission); recompute pays the prefill FLOPs to
    rebuild the prompt's KV from scratch.  Both are computed analytically —
    never from measured step times — so the cost and engine backends reach
    identical swap-vs-recompute decisions on the same trace."""
    swap_s = swap_time_s(stages, 2.0 * swap_bytes)
    recompute_s = max(_stage_prefill_time(st, model, max(1, int(prompt_tokens)))
                      for st in stages)
    return swap_s, recompute_s


def kv_free_bytes(stages: Sequence[Stage], model: ModelProfile) -> float:
    """HBM bytes left for KV cache on one replica: usable memory minus
    weights and per-device runtime overhead.  This is the budget both the
    planner's batch cap and the runtime's paged KV-cache manager
    (``repro.runtime.kvcache``) divide into token blocks."""
    total_mem = sum(st.memory for st in stages)
    n_devices = sum(st.tp for st in stages)
    return (MEMORY_UTIL * total_mem - model.weight_bytes
            - RUNTIME_OVERHEAD_BYTES * n_devices)


def max_batch_size(stages: Sequence[Stage], model: ModelProfile,
                   workload: WorkloadType) -> float:
    """KV-memory-capped concurrent batch size for this config."""
    free = kv_free_bytes(stages, model)
    if free <= 0:
        return 0.0
    ctx = model.kv_context(workload.input_len + workload.output_len)
    per_seq = ctx * model.kv_bytes_per_token + model.state_bytes_per_seq
    if per_seq <= 0:
        return float(MAX_BATCH)
    return float(min(MAX_BATCH, max(1.0, free / per_seq)))


PHASES = ("both", "prefill", "decode")


def config_throughput(stages: Sequence[Stage], model: ModelProfile,
                      workload: WorkloadType, *,
                      prefix_hit_rate: float = 0.0,
                      phase: str = "both") -> float:
    """h_{c,w}: steady-state requests/second of one replica.

    A request costs one prefill plus ``output_len`` amortized decode-step
    shares; with PP the bottleneck stage gates throughput and activations
    cross the inter-machine link between stages.

    ``prefix_hit_rate`` models cross-request prefix caching: the expected
    fraction of prompt tokens served from cached KV blocks, so only the
    remaining ``(1 - hit_rate)`` suffix is charged to prefill compute (and
    to the PP boundary activation traffic).  At least one token always
    prefills — the first logits require it.  Decode cost is unchanged:
    cached prefixes shorten *compute*, not context length.

    ``phase`` restricts the request cost to one phase of a disaggregated
    deployment: a ``"prefill"`` replica is charged only the prefill
    bottleneck (its requests hand their KV off before decoding), a
    ``"decode"`` replica only the amortized decode steps (its requests
    arrive with KV already built).  ``"both"`` is the colocated default.
    """
    if not 0.0 <= prefix_hit_rate <= 1.0:
        raise ValueError(f"prefix_hit_rate must be in [0, 1], "
                         f"got {prefix_hit_rate}")
    if phase not in PHASES:
        raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")
    batch = max_batch_size(stages, model, workload)
    if batch < 1.0:
        return 0.0
    avg_ctx = workload.input_len + workload.output_len / 2.0
    n_stages = len(stages)
    eff_input = max(1, int(round(workload.input_len
                                 * (1.0 - prefix_hit_rate))))

    # Throughput is gated by the slowest stage (pipeline steady state).
    prefill_bottleneck = max(_stage_prefill_time(st, model, eff_input) for st in stages)
    decode_bottleneck = max(_stage_decode_step_time(st, model, batch, avg_ctx) for st in stages)

    if n_stages > 1:
        inter_bw = min(st.device.inter_bw for st in stages)
        boundary = n_stages - 1
        prefill_bottleneck += boundary * (
            eff_input * model.d_model * BYTES_PER_PARAM / inter_bw
            + PP_BOUNDARY_LATENCY_S)
        decode_bottleneck += boundary * (
            batch * model.d_model * BYTES_PER_PARAM / inter_bw
            + PP_BOUNDARY_LATENCY_S)

    time_per_request = 0.0
    if phase != "decode":
        time_per_request += prefill_bottleneck
    if phase != "prefill":
        time_per_request += workload.output_len * decode_bottleneck / batch
    if time_per_request <= 0.0:
        return 0.0
    return 1.0 / time_per_request


class ProfiledThroughput:
    """Drop-in replacement for the analytical model: a profiled h-table.

    ``table[(config_key, workload_index)] -> req/s`` — the exact artifact the
    paper's one-time profiling step produces.
    """

    def __init__(self, table: Mapping[Tuple[str, int], float]):
        self._table = dict(table)

    def __call__(self, config_key: str, workload_index: int) -> float:
        return self._table[(config_key, workload_index)]
