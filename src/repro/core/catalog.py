"""Accelerator catalogs.

``GPU_CATALOG`` reproduces Table 1 of the paper exactly (six cloud GPU types
with FP16 peak FLOPs, HBM bandwidth, memory capacity, and hourly rental price).

``TPU_CATALOG`` is the hardware adaptation: the same scheduling problem posed
over heterogeneous *TPU slice types*. Prices are representative on-demand
prices; per-chip constants follow the target-hardware spec used throughout the
roofline analysis (v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 16 GB).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Tuple


@dataclasses.dataclass(frozen=True)
class DeviceType:
    """One rentable accelerator type.

    Attributes:
      name: catalog key.
      peak_flops: peak dense half-precision FLOP/s per device.
      hbm_bandwidth: HBM bytes/s per device.
      memory_bytes: HBM capacity in bytes per device.
      price_per_hour: rental price, $/h per device.
      devices_per_machine: max devices sharing the fast intra-machine
        interconnect (TP domain; App-D heuristic restricts TP to one machine).
      intra_bw: intra-machine interconnect bytes/s (NVLink / PCIe / ICI).
      inter_bw: inter-machine network bytes/s (Ethernet / DCN), used by PP.
      family: "datacenter" | "workstation" | "consumer" | "tpu".
    """

    name: str
    peak_flops: float
    hbm_bandwidth: float
    memory_bytes: float
    price_per_hour: float
    devices_per_machine: int
    intra_bw: float
    inter_bw: float
    family: str
    # Dense (non-sparsity) matmul peak.  Table 1 lists the H100 at 1979
    # TFLOPS, which is the 2:4-structured-sparsity figure; dense bf16 is
    # 989.5 TFLOPS.  The cost model computes with the dense peak.
    dense_peak_flops: float = 0.0
    # Host<->device copy bandwidth (bytes/s per device): what a KV block
    # swap to/from host memory rides on.  PCIe 4.0 x16 sustains ~25 GB/s
    # effective; PCIe 5.0 (H100) ~50 GB/s.  0.0 → defaulted in
    # ``__post_init__`` so older call sites need not name it.
    host_bw: float = 0.0
    # Cross-*replica* KV transfer bandwidth (bytes/s per device): what a
    # prefill→decode handoff of paged KV blocks rides on.  Replicas on a
    # heterogeneous marketplace generally sit on different machines, so
    # this defaults to the inter-machine network (``inter_bw``); set it
    # explicitly for pools with RDMA/NVLink between hosts.
    interconnect_bw: float = 0.0
    # Host RAM budget per device (bytes) the two-tier KV cache may spill
    # into.  0.0 → defaulted to 4x HBM in ``__post_init__`` (typical
    # cloud hosts pair each accelerator with several times its HBM in
    # DRAM); catalog entries may override with marketplace-typical values.
    host_ram_bytes: float = 0.0

    def __post_init__(self):
        if self.dense_peak_flops == 0.0:
            object.__setattr__(self, "dense_peak_flops", self.peak_flops)
        if self.host_bw == 0.0:
            object.__setattr__(self, "host_bw", 25 * 1e9)
        if self.interconnect_bw == 0.0:
            object.__setattr__(self, "interconnect_bw", self.inter_bw)
        if self.host_ram_bytes == 0.0:
            object.__setattr__(self, "host_ram_bytes",
                               4.0 * self.memory_bytes)

    @property
    def flops_per_dollar(self) -> float:
        return self.peak_flops / self.price_per_hour

    @property
    def bandwidth_per_dollar(self) -> float:
        return self.hbm_bandwidth / self.price_per_hour

    @property
    def memory_per_dollar(self) -> float:
        return self.memory_bytes / self.price_per_hour


_T = 1e12
_G = 1e9
_GB = 1024**3

# Table 1 of the paper.  Rows: A6000, A40, L40, A100, H100, 4090.
# Data-center GPUs: NVLink 300 GB/s; workstation/consumer: PCIe 60 GB/s.
# Inter-machine Ethernet: 5 Gb/s = 0.625 GB/s (paper §5.1).
_ETH = 5 / 8 * _G

GPU_CATALOG: Dict[str, DeviceType] = {
    "A6000": DeviceType("A6000", 91 * _T, 960 * _G, 48 * _GB, 0.83, 8, 60 * _G, _ETH, "workstation"),
    "A40":   DeviceType("A40", 150 * _T, 696 * _G, 48 * _GB, 0.55, 8, 60 * _G, _ETH, "workstation"),
    "L40":   DeviceType("L40", 181 * _T, 864 * _G, 48 * _GB, 0.83, 8, 60 * _G, _ETH, "workstation"),
    "A100":  DeviceType("A100", 312 * _T, 1555 * _G, 80 * _GB, 1.75, 8, 300 * _G, _ETH, "datacenter"),
    "H100":  DeviceType("H100", 1979 * _T, 3350 * _G, 80 * _GB, 2.99, 8, 300 * _G, _ETH, "datacenter",
                        dense_peak_flops=989.5 * _T, host_bw=50 * _G,
                        host_ram_bytes=256 * _GB),  # DGX-class: 2 TB / 8
    # RTX 4090s have no NVLink and no PCIe P2P: multi-GPU traffic stages
    # through host memory, ~12 GB/s effective (the paper's 60 GB/s PCIe
    # figure applies to the workstation cards, which do support P2P).
    # The same staging limit applies to host<->device KV swaps.  Consumer
    # hosts also carry less DRAM than the 4x-HBM datacenter default.
    "4090":  DeviceType("4090", 83 * _T, 1008 * _G, 24 * _GB, 0.53, 4, 12 * _G, _ETH, "consumer",
                        host_bw=12 * _G, host_ram_bytes=64 * _GB),
}

# Hardware adaptation: heterogeneous TPU slice types.  A "device" here is one
# slice (the paper's unit of rental is one GPU; ours is one slice), so
# devices_per_machine=1 and TP happens *inside* the slice — peak numbers are
# aggregated over the slice's chips and intra_bw is the ICI bisection.
_V5E_FLOPS = 197 * _T
_V5E_BW = 819 * _G
_V5E_MEM = 16 * _GB
_ICI = 50 * _G  # per link

def _tpu(name: str, chips: int, flops: float, bw: float, mem: float,
         price: float, ici_links: int) -> DeviceType:
    return DeviceType(
        name=name,
        peak_flops=chips * flops,
        hbm_bandwidth=chips * bw,
        memory_bytes=chips * mem,
        price_per_hour=price,
        devices_per_machine=1,
        intra_bw=ici_links * _ICI,
        inter_bw=25 / 8 * _G,  # DCN
        family="tpu",
    )

# Representative cloud pricing: larger slices carry bulk discounts and the
# older v4 generation trades at a deep discount per chip — the same
# supply-and-demand spread (Fig 2 of the paper) that makes heterogeneous
# composition worthwhile on GPU marketplaces.
TPU_CATALOG: Dict[str, DeviceType] = {
    "v5e-1": _tpu("v5e-1", 1, _V5E_FLOPS, _V5E_BW, _V5E_MEM, 1.20, 0),
    "v5e-4": _tpu("v5e-4", 4, _V5E_FLOPS, _V5E_BW, _V5E_MEM, 4.40, 4),
    "v5e-8": _tpu("v5e-8", 8, _V5E_FLOPS, _V5E_BW, _V5E_MEM, 8.00, 8),
    "v4-8":  _tpu("v4-8", 4, 275 * _T, 1228 * _G, 32 * _GB, 9.50, 6),
    "v5p-8": _tpu("v5p-8", 4, 459 * _T, 2765 * _G, 95 * _GB, 16.80, 6),
}


def get_catalog(kind: str = "gpu") -> Mapping[str, DeviceType]:
    if kind == "gpu":
        return GPU_CATALOG
    if kind == "tpu":
        return TPU_CATALOG
    raise ValueError(f"unknown catalog kind: {kind!r}")


# Real-time availability snapshots (paper Table 3, Vast.ai).
AVAILABILITY_SNAPSHOTS: Dict[str, Dict[str, int]] = {
    "avail1": {"4090": 16, "A40": 12, "A6000": 8, "L40": 12, "A100": 6, "H100": 8},
    "avail2": {"4090": 32, "A40": 8, "A6000": 16, "L40": 16, "A100": 7, "H100": 12},
    "avail3": {"4090": 32, "A40": 16, "A6000": 8, "L40": 8, "A100": 32, "H100": 8},
    "avail4": {"4090": 24, "A40": 24, "A6000": 24, "L40": 16, "A100": 4, "H100": 8},
}

TPU_AVAILABILITY_SNAPSHOTS: Dict[str, Dict[str, int]] = {
    "tpu-avail1": {"v5e-1": 16, "v5e-4": 8, "v5e-8": 4, "v4-8": 4, "v5p-8": 2},
    "tpu-avail2": {"v5e-1": 32, "v5e-4": 4, "v5e-8": 2, "v4-8": 8, "v5p-8": 1},
}
