"""Core contribution: cost-efficient LLM serving plan search over
heterogeneous accelerators (MILP + binary-search-on-T + simulator).

Public planning API: build a declarative :class:`DeploymentSpec` (models,
workload trace, catalog, availability snapshot, budget, SLOs, objective)
and hand it to :func:`plan` — strategies (``"milp"`` | ``"homogeneous"`` |
``"uniform"`` | ``"fixed"``) live in a registry and subsume the legacy
``solve_*`` entrypoints, which remain as deprecated wrappers.
:func:`replan` re-solves the same spec against a new availability
snapshot; ``ScalePolicy.from_spec`` closes the online loop.
"""
from repro.core.catalog import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG,
                                TPU_CATALOG, DeviceType, get_catalog)
from repro.core.costmodel import (LLAMA3_8B, LLAMA3_70B, ModelProfile, Stage,
                                  config_throughput, kv_free_bytes,
                                  max_batch_size)
from repro.core.plan import Config, ServingPlan
from repro.core.milp import SchedulingProblem, solve_feasibility, solve_milp
from repro.core.binsearch import knapsack_feasible, solve_binary_search
from repro.core.scheduler import (ScalePolicy, build_problem, solve,
                                  solve_homogeneous, solve_fixed_composition,
                                  uniform_composition)
from repro.core.simulator import SimResult, simulate
from repro.core.workloads import (TRACE_MIXES, WORKLOAD_TYPES, Request, Trace,
                                  WorkloadType, make_trace, workload_demand)
# Imported last: binds `repro.core.plan` (the function) over the submodule
# attribute of the same name — `from repro.core.plan import ...` still
# resolves the module through sys.modules.
from repro.core.spec import (DeploymentSpec, plan, planner_names,
                             register_planner, replan)

__all__ = [
    "AVAILABILITY_SNAPSHOTS", "GPU_CATALOG", "TPU_CATALOG", "DeviceType",
    "get_catalog", "LLAMA3_8B", "LLAMA3_70B", "ModelProfile", "Stage",
    "config_throughput", "kv_free_bytes", "max_batch_size", "Config", "ServingPlan",
    "SchedulingProblem", "solve_feasibility", "solve_milp",
    "knapsack_feasible", "solve_binary_search", "build_problem",
    "DeploymentSpec", "plan", "planner_names", "register_planner", "replan",
    "ScalePolicy", "solve", "solve_homogeneous", "solve_fixed_composition",
    "uniform_composition", "SimResult", "simulate", "TRACE_MIXES",
    "WORKLOAD_TYPES", "Request", "Trace", "WorkloadType", "make_trace",
    "workload_demand",
]
