"""Core contribution: cost-efficient LLM serving plan search over
heterogeneous accelerators (MILP + binary-search-on-T + simulator)."""
from repro.core.catalog import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG,
                                TPU_CATALOG, DeviceType, get_catalog)
from repro.core.costmodel import (LLAMA3_8B, LLAMA3_70B, ModelProfile, Stage,
                                  config_throughput, kv_free_bytes,
                                  max_batch_size)
from repro.core.plan import Config, ServingPlan
from repro.core.milp import SchedulingProblem, solve_feasibility, solve_milp
from repro.core.binsearch import knapsack_feasible, solve_binary_search
from repro.core.scheduler import (build_problem, replan, solve,
                                  solve_homogeneous, solve_fixed_composition,
                                  uniform_composition)
from repro.core.simulator import SimResult, simulate
from repro.core.workloads import (TRACE_MIXES, WORKLOAD_TYPES, Request, Trace,
                                  WorkloadType, make_trace, workload_demand)

__all__ = [
    "AVAILABILITY_SNAPSHOTS", "GPU_CATALOG", "TPU_CATALOG", "DeviceType",
    "get_catalog", "LLAMA3_8B", "LLAMA3_70B", "ModelProfile", "Stage",
    "config_throughput", "kv_free_bytes", "max_batch_size", "Config", "ServingPlan",
    "SchedulingProblem", "solve_feasibility", "solve_milp",
    "knapsack_feasible", "solve_binary_search", "build_problem", "replan",
    "solve", "solve_homogeneous", "solve_fixed_composition",
    "uniform_composition", "SimResult", "simulate", "TRACE_MIXES",
    "WORKLOAD_TYPES", "Request", "Trace", "WorkloadType", "make_trace",
    "workload_demand",
]
