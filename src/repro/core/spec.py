"""Declarative deployment specification + pluggable planner registry.

The paper's pipeline — workload + budget + real-time GPU availability →
MILP plan → serving — is expressed as one declarative value,
:class:`DeploymentSpec`, consumed by one entrypoint, :func:`plan`:

    spec = DeploymentSpec(models=[LLAMA3_70B], workload=trace,
                          catalog=GPU_CATALOG,
                          availability=AVAILABILITY_SNAPSHOTS["avail1"],
                          budget=30.0)
    p = plan(spec)                          # the paper's MILP planner
    p = plan(spec, strategy="homogeneous", gpu_type="H100")   # baseline
    p = plan(spec, strategy="uniform")      # ablation (ii)
    p = plan(spec, strategy="fixed", composition={"A100": 4})

Strategies live in a registry (:func:`register_planner`), so baselines,
ablations, and future solvers plug in behind the same spec; offline
planning, online replanning (:func:`replan`), and autoscaling
(``ScalePolicy.from_spec``) all consume the same ``DeploymentSpec``.
The built-in strategies are registered by ``repro.core.scheduler`` and
subsume the legacy ``solve_*`` functions (kept there as deprecated
wrappers).

The spec's two objectives mirror the paper and its dual:

* ``objective="makespan"`` — minimize trace completion time T under the
  price budget (the paper's §4 formulation);
* ``objective="cost"`` — minimize $/h subject to finishing within
  ``slo_makespan`` seconds (the operator's dual; one feasibility MILP).
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile
from repro.core.plan import ServingPlan
from repro.core.workloads import Trace

OBJECTIVES = ("makespan", "cost")


@dataclasses.dataclass(frozen=True)
class DeploymentSpec:
    """What to deploy, against which pool, under which constraints.

    One immutable value carrying everything a planner strategy needs:
    the models to serve, the demand (a workload trace), the device
    catalog with prices, the real-time availability snapshot, the price
    budget, and the objective.  ``slo`` optionally carries a per-request
    service-level objective (e.g. :class:`repro.runtime.SLO`) that the
    serving session scores goodput against; ``slo_makespan`` is the
    completion-time bound the ``"cost"`` objective plans under.
    """

    models: Tuple[ModelProfile, ...]
    workload: Trace
    catalog: Mapping[str, DeviceType]
    availability: Mapping[str, int]
    budget: float
    objective: str = "makespan"
    slo: Optional[object] = None          # per-request SLO (runtime-scored)
    slo_makespan: Optional[float] = None  # seconds; required for "cost"
    # workload-class index -> expected cross-request prefix hit rate in
    # [0, 1] (e.g. measured from a prior run's info["prefix_hit_rate"]);
    # the "milp" planner folds it into each config's modeled throughput,
    # so cache-heavy workloads plan onto fewer/cheaper GPUs.
    prefix_hit_rates: Optional[Mapping[int, float]] = None
    # Host-RAM budget the serving session sizes each replica's two-tier
    # KV host pool from: bytes per replica, or "auto" (sum the catalog's
    # per-device ``host_ram_bytes`` over the replica's stages).  None
    # keeps host-tier sizing to the executor's explicit ``host_blocks``.
    host_ram_bytes: Optional[object] = None

    def __post_init__(self):
        object.__setattr__(self, "models", tuple(self.models))
        # Snapshot the mappings: a frozen spec must not change because the
        # caller keeps mutating the dict it was built from (e.g. a live
        # availability watcher updating its snapshot in place).
        object.__setattr__(self, "catalog", dict(self.catalog))
        # A negative or fractional device count would flow silently into
        # the MILP's per-type capacity constraints; fail at construction.
        # Integer-valued numerics (numpy ints from computed snapshots)
        # normalize to plain ints.
        avail: Dict[str, int] = {}
        for name, n in dict(self.availability).items():
            ok = not isinstance(n, bool)
            if ok:
                try:
                    ni = int(n)
                    ok = ni == n
                except (TypeError, ValueError):
                    ok = False
            if not ok or ni < 0:
                raise ValueError(
                    f"availability[{name!r}] must be a non-negative int, "
                    f"got {n!r}")
            avail[name] = ni
        object.__setattr__(self, "availability", avail)
        if self.budget <= 0:
            raise ValueError(f"budget must be > 0, got {self.budget}")
        if self.objective not in OBJECTIVES:
            raise ValueError(f"objective must be one of {OBJECTIVES}, "
                             f"got {self.objective!r}")
        if self.objective == "cost" and self.slo_makespan is None:
            raise ValueError('objective="cost" requires slo_makespan')
        if self.prefix_hit_rates is not None:
            rates = {int(k): float(v)
                     for k, v in dict(self.prefix_hit_rates).items()}
            for k, v in rates.items():
                if not 0.0 <= v <= 1.0:
                    raise ValueError(
                        f"prefix_hit_rates[{k}] must be in [0, 1], got {v}")
            object.__setattr__(self, "prefix_hit_rates", rates)
        if self.host_ram_bytes is not None and self.host_ram_bytes != "auto":
            try:
                ram = float(self.host_ram_bytes)
            except (TypeError, ValueError):
                ram = -1.0
            if ram < 0:
                raise ValueError(
                    f'host_ram_bytes must be None, "auto", or bytes >= 0, '
                    f"got {self.host_ram_bytes!r}")
            object.__setattr__(self, "host_ram_bytes", ram)

    # ------------------------------------------------------------- variants

    def with_availability(self, availability: Mapping[str, int]
                          ) -> "DeploymentSpec":
        """The same deployment against a new pool snapshot (Fig 2: cloud
        availability fluctuates; this is the replanning input).  GPU
        types absent from the catalog are rejected — a typo'd snapshot
        key would otherwise just vanish inside the planner."""
        unknown = sorted(set(availability) - set(self.catalog))
        if unknown:
            raise ValueError(
                f"with_availability: unknown GPU type(s) {unknown}; "
                f"catalog has {sorted(self.catalog)}")
        return dataclasses.replace(self, availability=dict(availability))

    def with_budget(self, budget: float) -> "DeploymentSpec":
        return dataclasses.replace(self, budget=float(budget))

    def with_workload(self, workload: Trace) -> "DeploymentSpec":
        return dataclasses.replace(self, workload=workload)

    def with_objective(self, objective: str, *,
                       slo_makespan: Optional[float] = None
                       ) -> "DeploymentSpec":
        return dataclasses.replace(
            self, objective=objective,
            slo_makespan=(self.slo_makespan if slo_makespan is None
                          else float(slo_makespan)))

    def with_prefix_hit_rates(self, rates: Optional[Mapping[int, float]]
                              ) -> "DeploymentSpec":
        """The same deployment with new expected per-workload prefix hit
        rates (e.g. fed back from a served run's measured hit rate)."""
        return dataclasses.replace(
            self, prefix_hit_rates=None if rates is None else dict(rates))

    def with_host_ram(self, host_ram_bytes) -> "DeploymentSpec":
        """The same deployment with a new host-RAM budget for the two-tier
        KV cache (bytes per replica, ``"auto"`` for catalog-derived, or
        None to disable RAM-derived sizing)."""
        return dataclasses.replace(self, host_ram_bytes=host_ram_bytes)


# ------------------------------------------------------------ the registry

_PLANNERS: Dict[str, Callable[..., ServingPlan]] = {}


def register_planner(name: str) -> Callable:
    """Register a planning strategy: ``fn(spec, **options) -> ServingPlan``."""
    def deco(fn: Callable[..., ServingPlan]) -> Callable[..., ServingPlan]:
        _PLANNERS[name] = fn
        return fn
    return deco


def planner_names() -> Tuple[str, ...]:
    _load_builtin_planners()
    return tuple(sorted(_PLANNERS))


def _load_builtin_planners() -> None:
    # The built-in strategies are registered as a side effect of importing
    # the scheduler (which owns their implementations); lazy so the spec
    # module stays import-light and cycle-free.
    from repro.core import scheduler  # noqa: F401


def plan(spec: DeploymentSpec, strategy: str = "milp",
         **options) -> ServingPlan:
    """Plan a deployment: dispatch ``spec`` to a registered strategy.

    Built-in strategies (see ``repro.core.scheduler``):

    * ``"milp"`` — the paper's planner: binary-search-on-T over the MILP
      (``method="milp"`` solves the exact MILP once instead); honors
      ``spec.objective`` (``"cost"`` plans min-$ under ``slo_makespan``);
    * ``"homogeneous"`` — single-GPU-type baseline
      (``gpu_type="H100"``, availability unconstrained up to the budget);
    * ``"uniform"`` — ablation (ii): one fixed TP-only config shape
      (``tp=4``) for every replica;
    * ``"fixed"`` — optimize deployment+assignment inside a *given*
      composition (``composition={type: count}``; defaults to the
      budget-even split of ``uniform_composition``).

    Extra ``options`` are forwarded to the strategy (solver method,
    tolerances, time limits, strategy-specific knobs).
    """
    _load_builtin_planners()
    try:
        fn = _PLANNERS[strategy]
    except KeyError:
        raise ValueError(
            f"unknown planning strategy {strategy!r}; "
            f"registered: {planner_names()}") from None
    return fn(spec, **options)


def replan(old_plan: ServingPlan, spec, *legacy,
           availability: Optional[Mapping[str, int]] = None,
           strategy: str = "milp", **options) -> ServingPlan:
    """Availability changed mid-serving: re-solve the same spec against
    the new pool.  Replicas whose config keys survive keep their identity
    (the runtime keeps them warm when it applies the new plan as a
    :class:`~repro.runtime.orchestrator.ReplanEvent`); the rest are
    re-rented.  ``solver_info["replicas_kept"]`` records the multiset
    overlap, matching the runtime's own survivor accounting.

    Also accepts the legacy positional signature
    ``replan(plan, models, trace, catalog, new_availability, budget)``
    (deprecated) so pre-spec callers of ``repro.core.replan`` keep
    working through the transition.
    """
    if not isinstance(spec, DeploymentSpec):
        if len(legacy) != 4:
            raise TypeError(
                "replan() wants (old_plan, DeploymentSpec, *, "
                "availability=...) — or the deprecated (old_plan, models, "
                "trace, catalog, new_availability, budget)")
        import warnings
        warnings.warn(
            "replan(plan, models, trace, catalog, new_availability, budget)"
            " is deprecated; use replan(plan, spec, availability=...)",
            DeprecationWarning, stacklevel=2)
        models, (trace, catalog, new_avail, budget) = spec, legacy
        spec = DeploymentSpec(models=tuple(models), workload=trace,
                              catalog=catalog, availability=new_avail,
                              budget=budget)
    elif legacy:
        raise TypeError("replan() takes no positional arguments beyond "
                        "(old_plan, spec)")
    if availability is not None:
        spec = spec.with_availability(availability)
    new_plan = plan(spec, strategy=strategy, **options)
    overlap = (Counter(o.key for o in old_plan.replicas)
               & Counter(c.key for c in new_plan.replicas))
    new_plan.solver_info["replicas_kept"] = float(sum(overlap.values()))
    return new_plan
