"""Serving-plan datatypes shared by the scheduler, simulator, and runtime."""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage


@dataclasses.dataclass(frozen=True)
class Config:
    """One feasible deployment configuration c (a single model replica).

    Mirrors §4.3: v_c (GPU counts per type), s_c (parallelism strategy: TP
    degree per pipeline stage), o_c (price), and h_{c,w} (throughput row,
    filled by the cost model).
    """

    stages: Tuple[Stage, ...]
    model_index: int
    model: ModelProfile
    # Which serving phase this replica runs: "both" (colocated, the
    # default), or one side of a disaggregated deployment — "prefill"
    # replicas run admission + prefill then hand KV off; "decode"
    # replicas receive handoffs and run decode only.
    role: str = "both"

    def __post_init__(self):
        if self.role not in ("both", "prefill", "decode"):
            raise ValueError(
                f'role must be "both", "prefill", or "decode", '
                f"got {self.role!r}")

    @property
    def key(self) -> str:
        s = "+".join(f"{st.device.name}x{st.tp}" for st in self.stages)
        base = f"{self.model.name}:{s}"
        return base if self.role == "both" else f"{base}|{self.role}"

    @property
    def strategy(self) -> Tuple[int, ...]:
        """s_c: TP degree of each pipeline stage."""
        return tuple(st.tp for st in self.stages)

    @property
    def cost(self) -> float:
        """o_c in $/h."""
        return sum(st.price for st in self.stages)

    def device_counts(self) -> Dict[str, int]:
        """v_c: devices used per type."""
        counts: Dict[str, int] = {}
        for st in self.stages:
            counts[st.device.name] = counts.get(st.device.name, 0) + st.tp
        return counts

    @property
    def num_devices(self) -> int:
        return sum(st.tp for st in self.stages)


@dataclasses.dataclass
class ServingPlan:
    """The scheduler's output: composition + configurations + assignment.

    ``replicas[i]`` is a chosen Config (each copy listed separately, i.e. a
    config with y_c = 3 appears three times); ``assignment[i, d]`` is the
    fraction of demand d routed to replica i (columns sum to 1 over replicas).
    ``demands`` are (model_index, workload_index, λ) triples.
    """

    replicas: Sequence[Config]
    assignment: np.ndarray
    demands: Sequence[Tuple[int, int, float]]
    makespan: float
    cost: float
    solver_info: Dict[str, float] = dataclasses.field(default_factory=dict)

    def subset(self, indices: Sequence[int]) -> "ServingPlan":
        """A plan restricted to ``replicas[indices]`` (same demands; the
        dropped rows' assignment mass is *not* re-spread — the runtime's
        router renormalizes per demand column).  Used to under-provision
        deliberately, e.g. as an autoscaling starting point."""
        idx = list(indices)
        replicas = [self.replicas[i] for i in idx]
        return ServingPlan(replicas=replicas,
                           assignment=self.assignment[idx],
                           demands=self.demands,
                           makespan=self.makespan,
                           cost=sum(c.cost for c in replicas),
                           solver_info=dict(self.solver_info, subset=1.0))

    def composition(self) -> Dict[str, int]:
        total: Dict[str, int] = {}
        for c in self.replicas:
            for name, n in c.device_counts().items():
                total[name] = total.get(name, 0) + n
        return total

    def summary(self) -> str:
        lines = [f"ServingPlan: {len(self.replicas)} replicas, "
                 f"cost {self.cost:.2f} $/h, makespan {self.makespan:.2f} s"]
        lines.append(f"  composition: {self.composition()}")
        for i, c in enumerate(self.replicas):
            frac = ", ".join(
                f"w{d}:{self.assignment[i, d]:.2f}"
                for d in range(self.assignment.shape[1]) if self.assignment[i, d] > 1e-6)
            lines.append(f"  [{i}] {c.key} (${c.cost:.2f}/h) <- {frac}")
        return "\n".join(lines)
