"""Top-level scheduler: trace + budget + availability → ServingPlan.

Also provides the paper's baselines:

* homogeneous(type): rent only one GPU type (availability unconstrained, as
  the paper assumes for homogeneous baselines), deployment configs and
  workload assignment still optimized by our algorithm — exactly the paper's
  "fine-tune ... using our scheduling algorithm" setup;
* uniform-composition (ablation i / HexGen-uniform): spend the budget evenly
  across available types, then optimize deployment+assignment within that
  fixed composition;
* round-robin assignment (ablation iii): workload fractions forced
  proportional to replica throughput (workload-unaware);
* uniform-deployment (ablation ii): a single TP-only config shape for all
  replicas.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import configspace
from repro.core.binsearch import solve_binary_search
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, config_throughput
from repro.core.milp import SchedulingProblem, solve_milp, _plan_from_solution
from repro.core.plan import Config, ServingPlan
from repro.core.workloads import WORKLOAD_TYPES, Trace, WorkloadType, workload_demand


def build_problem(
    models: Sequence[ModelProfile],
    trace: Trace,
    catalog: Mapping[str, DeviceType],
    availability: Mapping[str, int],
    budget: float,
    *,
    workloads: Sequence[WorkloadType] = WORKLOAD_TYPES,
    throughput_fn: Optional[Callable] = None,
    include_mixed: bool = True,
    max_stages: int = configspace.MAX_STAGES,
    prune: bool = True,
) -> SchedulingProblem:
    """Enumerate configs for every model and assemble the demand matrix."""
    lam = workload_demand(trace, num_models=len(models))
    demands: List[Tuple[int, int, float]] = []
    for m in range(len(models)):
        for w in range(len(workloads)):
            if lam[m, w] > 0:
                demands.append((m, w, float(lam[m, w])))

    all_configs: List[Config] = []
    h_rows: List[np.ndarray] = []
    for m, model in enumerate(models):
        cfgs = configspace.enumerate_configs(
            model, catalog, availability, model_index=m,
            include_mixed=include_mixed, max_stages=max_stages)
        hw = configspace.throughput_table(cfgs, workloads, throughput_fn)
        if prune and len(cfgs):
            cfgs, hw = configspace.prune_dominated(cfgs, hw)
        for i, cfg in enumerate(cfgs):
            all_configs.append(cfg)
            row = np.zeros(len(demands))
            for j, (md, wd, _) in enumerate(demands):
                row[j] = hw[i, wd] if md == m else 0.0
            h_rows.append(row)
    h = np.array(h_rows) if h_rows else np.zeros((0, len(demands)))
    return SchedulingProblem(configs=all_configs, h=h, demands=demands,
                             budget=budget, availability=availability)


def solve(
    models: Sequence[ModelProfile],
    trace: Trace,
    catalog: Mapping[str, DeviceType],
    availability: Mapping[str, int],
    budget: float,
    *,
    method: str = "binary_search",
    workloads: Sequence[WorkloadType] = WORKLOAD_TYPES,
    throughput_fn: Optional[Callable] = None,
    include_mixed: bool = True,
    tol: float = 1.0,
    time_limit: float = 120.0,
) -> ServingPlan:
    problem = build_problem(models, trace, catalog, availability, budget,
                            workloads=workloads, throughput_fn=throughput_fn,
                            include_mixed=include_mixed)
    if method == "milp":
        return solve_milp(problem, time_limit=time_limit)
    if method == "binary_search":
        return solve_binary_search(problem, tol=tol,
                                   time_limit_per_check=time_limit / 4)
    raise ValueError(f"unknown method {method!r}")


def solve_min_cost(
    models: Sequence[ModelProfile],
    trace: Trace,
    catalog: Mapping[str, DeviceType],
    availability: Mapping[str, int],
    budget: float,
    slo_makespan: float,
    *,
    workloads: Sequence[WorkloadType] = WORKLOAD_TYPES,
    throughput_fn: Optional[Callable] = None,
    time_limit: float = 60.0,
) -> ServingPlan:
    """Beyond-paper dual formulation: given a makespan SLO, rent the
    *cheapest* feasible composition (the paper minimizes T under a budget;
    operators often want min-$ under a deadline).  One feasibility MILP at
    T̂ = SLO with a cost objective."""
    from repro.core.milp import solve_feasibility, _plan_from_solution
    problem = build_problem(models, trace, catalog, availability, budget,
                            workloads=workloads, throughput_fn=throughput_fn)
    witness = solve_feasibility(problem, slo_makespan, time_limit=time_limit,
                                minimize_cost=True)
    if witness is None:
        raise RuntimeError(
            f"no plan meets makespan SLO {slo_makespan}s within budget")
    y, x = witness
    return _plan_from_solution(problem, y, x,
                               {"solver": 2.0, "slo_s": slo_makespan})


def replan(
    plan: ServingPlan,
    models: Sequence[ModelProfile],
    trace: Trace,
    catalog: Mapping[str, DeviceType],
    new_availability: Mapping[str, int],
    budget: float,
    **kw,
) -> ServingPlan:
    """Availability changed mid-serving (Fig 2: cloud pools fluctuate):
    re-solve against the new pool.  Replicas whose devices survive keep
    their identity (the runtime can keep them warm); the rest are re-rented.
    """
    new_plan = solve(models, trace, catalog, new_availability, budget, **kw)
    # Multiset matching by config key: a surviving key keeps at most as many
    # replicas as the old plan actually had (the runtime matches the same way
    # when it migrates queued requests off drained replicas).
    overlap = (Counter(o.key for o in plan.replicas)
               & Counter(c.key for c in new_plan.replicas))
    new_plan.solver_info["replicas_kept"] = float(sum(overlap.values()))
    return new_plan


# ---------------------------------------------------------------- baselines

def homogeneous_availability(catalog: Mapping[str, DeviceType], gpu_type: str,
                             budget: float) -> Dict[str, int]:
    """Paper baseline: unlimited single-type pool (budget is the binding cap)."""
    dev = catalog[gpu_type]
    return {gpu_type: int(budget // dev.price_per_hour)}


def solve_homogeneous(models, trace, catalog, gpu_type: str, budget: float,
                      **kw) -> ServingPlan:
    avail = homogeneous_availability(catalog, gpu_type, budget)
    sub = {gpu_type: catalog[gpu_type]}
    return solve(models, trace, sub, avail, budget, **kw)


def uniform_composition(catalog: Mapping[str, DeviceType],
                        availability: Mapping[str, int],
                        budget: float) -> Dict[str, int]:
    """Ablation (i): spread the budget evenly across available GPU types."""
    types = [t for t in availability if availability[t] > 0 and t in catalog]
    per_type = budget / max(len(types), 1)
    comp = {}
    for t in types:
        comp[t] = min(availability[t], int(per_type // catalog[t].price_per_hour))
    return comp


def solve_fixed_composition(models, trace, catalog, composition: Mapping[str, int],
                            budget: float, **kw) -> ServingPlan:
    """Optimize deployment+assignment inside a *given* composition (HexGen
    setting: scheduling over a predefined heterogeneous cluster)."""
    return solve(models, trace, catalog, composition, budget, **kw)


def apply_round_robin_assignment(plan: ServingPlan, h_fn: Callable) -> ServingPlan:
    """Ablation (iii): replace the optimized x with throughput-proportional
    (workload-unaware) dispatch across the plan's replicas."""
    R = len(plan.replicas)
    D = len(plan.demands)
    x = np.zeros((R, D))
    for d, (m, w, lam) in enumerate(plan.demands):
        rates = np.array([
            h_fn(cfg, w) if cfg.model_index == m else 0.0 for cfg in plan.replicas])
        total = rates.sum()
        if total > 0:
            x[:, d] = rates / total
    makespan = 0.0
    for i, cfg in enumerate(plan.replicas):
        t = sum(x[i, d] * plan.demands[d][2] / h_fn(cfg, plan.demands[d][1])
                for d in range(D) if x[i, d] > 0)
        makespan = max(makespan, t)
    return ServingPlan(replicas=plan.replicas, assignment=x, demands=plan.demands,
                       makespan=makespan, cost=plan.cost,
                       solver_info=dict(plan.solver_info, round_robin=1.0))


def solve_uniform_deployment(models, trace, catalog, availability, budget,
                             tp: int = 4, **kw) -> ServingPlan:
    """Ablation (ii): all replicas use one fixed TP-only config shape."""
    return solve(models, trace, catalog, availability, budget,
                 include_mixed=False, **kw,
                 throughput_fn=None if tp is None else _only_tp(tp))


def _only_tp(tp: int) -> Callable:
    def fn(cfg: Config, w: WorkloadType) -> float:
        if len(cfg.stages) != 1 or cfg.stages[0].tp != tp:
            return 0.0
        return config_throughput(cfg.stages, cfg.model, w)
    return fn
