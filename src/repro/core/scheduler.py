"""Planner strategies behind the declarative spec API (plus the paper's
baselines and the online autoscale policy).

The public entrypoint is ``repro.core.plan(spec, strategy=...)``
(:mod:`repro.core.spec`): this module owns the strategy *implementations*
and registers them —

* ``"milp"``: the paper's planner (binary-search-on-T over the MILP, or
  the exact MILP with ``method="milp"``); ``spec.objective="cost"`` plans
  the dual (min-$ under a makespan SLO);
* ``"homogeneous"``: rent only one GPU type (availability unconstrained,
  as the paper assumes for homogeneous baselines), deployment configs and
  workload assignment still optimized by our algorithm — exactly the
  paper's "fine-tune ... using our scheduling algorithm" setup;
* ``"uniform"``: ablation (ii), a single TP-only config shape for all
  replicas;
* ``"fixed"``: optimize deployment+assignment inside a *given*
  composition (HexGen setting; the default composition is the budget-even
  ``uniform_composition`` split, ablation i).

Round-robin assignment (ablation iii) stays a plan *post-processor*
(:func:`apply_round_robin_assignment`).  The legacy ``solve_*`` functions
are kept as thin deprecated wrappers over the same implementations.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import Counter
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import configspace
from repro.core.binsearch import solve_binary_search
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, config_throughput, phase_affinity
from repro.core.milp import SchedulingProblem, solve_milp, _plan_from_solution
from repro.core.plan import Config, ServingPlan
from repro.core.spec import DeploymentSpec, register_planner
from repro.core.spec import replan as spec_replan
from repro.core.workloads import WORKLOAD_TYPES, Trace, WorkloadType, workload_demand


def _warn_legacy(old: str, new: str) -> None:
    warnings.warn(
        f"repro.core.scheduler.{old} is deprecated; use {new} "
        f"(see the deprecation table in README.md)",
        DeprecationWarning, stacklevel=3)


def build_problem(
    models: Sequence[ModelProfile],
    trace: Trace,
    catalog: Mapping[str, DeviceType],
    availability: Mapping[str, int],
    budget: float,
    *,
    workloads: Sequence[WorkloadType] = WORKLOAD_TYPES,
    throughput_fn: Optional[Callable] = None,
    include_mixed: bool = True,
    max_stages: int = configspace.MAX_STAGES,
    prune: bool = True,
) -> SchedulingProblem:
    """Enumerate configs for every model and assemble the demand matrix."""
    lam = workload_demand(trace, num_models=len(models))
    demands: List[Tuple[int, int, float]] = []
    for m in range(len(models)):
        for w in range(len(workloads)):
            if lam[m, w] > 0:
                demands.append((m, w, float(lam[m, w])))

    all_configs: List[Config] = []
    h_rows: List[np.ndarray] = []
    for m, model in enumerate(models):
        cfgs = configspace.enumerate_configs(
            model, catalog, availability, model_index=m,
            include_mixed=include_mixed, max_stages=max_stages)
        hw = configspace.throughput_table(cfgs, workloads, throughput_fn)
        if prune and len(cfgs):
            cfgs, hw = configspace.prune_dominated(cfgs, hw)
        for i, cfg in enumerate(cfgs):
            all_configs.append(cfg)
            row = np.zeros(len(demands))
            for j, (md, wd, _) in enumerate(demands):
                row[j] = hw[i, wd] if md == m else 0.0
            h_rows.append(row)
    h = np.array(h_rows) if h_rows else np.zeros((0, len(demands)))
    return SchedulingProblem(configs=all_configs, h=h, demands=demands,
                             budget=budget, availability=availability)


def _solve(
    models: Sequence[ModelProfile],
    trace: Trace,
    catalog: Mapping[str, DeviceType],
    availability: Mapping[str, int],
    budget: float,
    *,
    method: str = "binary_search",
    workloads: Sequence[WorkloadType] = WORKLOAD_TYPES,
    throughput_fn: Optional[Callable] = None,
    include_mixed: bool = True,
    tol: float = 1.0,
    time_limit: float = 120.0,
) -> ServingPlan:
    problem = build_problem(models, trace, catalog, availability, budget,
                            workloads=workloads, throughput_fn=throughput_fn,
                            include_mixed=include_mixed)
    if method == "milp":
        return solve_milp(problem, time_limit=time_limit)
    if method == "binary_search":
        return solve_binary_search(problem, tol=tol,
                                   time_limit_per_check=time_limit / 4)
    raise ValueError(f"unknown method {method!r}")


def _solve_min_cost(
    models: Sequence[ModelProfile],
    trace: Trace,
    catalog: Mapping[str, DeviceType],
    availability: Mapping[str, int],
    budget: float,
    slo_makespan: float,
    *,
    workloads: Sequence[WorkloadType] = WORKLOAD_TYPES,
    throughput_fn: Optional[Callable] = None,
    time_limit: float = 60.0,
) -> ServingPlan:
    """Beyond-paper dual formulation: given a makespan SLO, rent the
    *cheapest* feasible composition (the paper minimizes T under a budget;
    operators often want min-$ under a deadline).  One feasibility MILP at
    T̂ = SLO with a cost objective."""
    from repro.core.milp import solve_feasibility, _plan_from_solution
    problem = build_problem(models, trace, catalog, availability, budget,
                            workloads=workloads, throughput_fn=throughput_fn)
    witness = solve_feasibility(problem, slo_makespan, time_limit=time_limit,
                                minimize_cost=True)
    if witness is None:
        raise RuntimeError(
            f"no plan meets makespan SLO {slo_makespan}s within budget")
    y, x = witness
    return _plan_from_solution(problem, y, x,
                               {"solver": 2.0, "slo_s": slo_makespan})


# ----------------------------------------------------- registered strategies

def _hit_rate_throughput_fn(rates: Mapping[int, float]
                            ) -> Callable[[Config, WorkloadType], float]:
    """A ``throughput_fn`` that folds the spec's expected per-workload
    prefix hit rates into the analytical model: workload classes with a
    declared hit rate skip that fraction of prefill compute (see
    ``costmodel.config_throughput``)."""
    def fn(cfg: Config, w: WorkloadType) -> float:
        try:
            rate = rates.get(WORKLOAD_TYPES.index(w), 0.0)
        except ValueError:          # a custom workload class: no declared rate
            rate = 0.0
        return config_throughput(cfg.stages, cfg.model, w,
                                 prefix_hit_rate=rate)
    return fn


@register_planner("milp")
def _plan_milp(spec: DeploymentSpec, **options) -> ServingPlan:
    """The paper's planner over the spec.  ``spec.objective="makespan"``
    minimizes T under the budget (binary search over the MILP feasibility
    check by default; ``method="milp"`` solves the exact MILP once);
    ``"cost"`` minimizes $/h under ``spec.slo_makespan``.  When the spec
    declares ``prefix_hit_rates``, the modeled throughput table credits
    each workload's expected prefix-cache savings (an explicit
    ``throughput_fn`` option still wins)."""
    if spec.prefix_hit_rates and "throughput_fn" not in options:
        options = dict(options,
                       throughput_fn=_hit_rate_throughput_fn(
                           spec.prefix_hit_rates))
    if spec.objective == "cost":
        unsupported = sorted(k for k in ("method", "include_mixed", "tol")
                             if k in options)
        if unsupported:
            raise ValueError(
                f'objective="cost" plans via one feasibility MILP; '
                f"options {unsupported} do not apply")
        return _solve_min_cost(spec.models, spec.workload, spec.catalog,
                               spec.availability, spec.budget,
                               spec.slo_makespan, **options)
    return _solve(spec.models, spec.workload, spec.catalog,
                  spec.availability, spec.budget, **options)


@register_planner("homogeneous")
def _plan_homogeneous(spec: DeploymentSpec, *, gpu_type: str,
                      **options) -> ServingPlan:
    """Paper baseline: one GPU type only, pool unconstrained (the budget
    is the binding cap), configs + assignment still optimized."""
    avail = homogeneous_availability(spec.catalog, gpu_type, spec.budget)
    sub = {gpu_type: spec.catalog[gpu_type]}
    return _solve(spec.models, spec.workload, sub, avail, spec.budget,
                  **options)


@register_planner("uniform")
def _plan_uniform(spec: DeploymentSpec, *, tp: int = 4,
                  **options) -> ServingPlan:
    """Ablation (ii): all replicas use one fixed TP-only config shape."""
    return _solve(spec.models, spec.workload, spec.catalog,
                  spec.availability, spec.budget, include_mixed=False,
                  throughput_fn=None if tp is None else _only_tp(tp),
                  **options)


@register_planner("fixed")
def _plan_fixed(spec: DeploymentSpec, *,
                composition: Optional[Mapping[str, int]] = None,
                **options) -> ServingPlan:
    """Optimize deployment+assignment inside a *given* composition (HexGen
    setting: scheduling over a predefined heterogeneous cluster).  The
    default composition is the budget-even split across available types
    (ablation i / HexGen-uniform)."""
    if composition is None:
        composition = uniform_composition(spec.catalog, spec.availability,
                                          spec.budget)
    return _solve(spec.models, spec.workload, spec.catalog, composition,
                  spec.budget, **options)


def _phase_throughput_fn(phase: str, rates: Optional[Mapping[int, float]]
                         ) -> Callable[[Config, WorkloadType], float]:
    """Per-phase ``throughput_fn``: the analytical model restricted to one
    serving phase (see ``costmodel.config_throughput``), with the spec's
    expected prefix hit rates folded into prefill-side compute."""
    rates = rates or {}

    def fn(cfg: Config, w: WorkloadType) -> float:
        try:
            rate = rates.get(WORKLOAD_TYPES.index(w), 0.0)
        except ValueError:
            rate = 0.0
        return config_throughput(cfg.stages, cfg.model, w,
                                 prefix_hit_rate=rate, phase=phase)
    return fn


def partition_by_affinity(catalog: Mapping[str, DeviceType],
                          availability: Mapping[str, int]
                          ) -> Tuple[List[str], List[str]]:
    """Split the available GPU types into (prefill-leaning, decode-leaning)
    pools by ``costmodel.phase_affinity``: sort by achievable prefill
    FLOP/s per decode byte/s and cut at the midpoint, so the compute-rich
    half runs prefill and the bandwidth-rich half runs decode.  Both pools
    are non-empty whenever at least two types are available."""
    types = sorted(t for t, n in availability.items()
                   if n > 0 and t in catalog)
    if len(types) < 2:
        return types, list(types)
    ranked = sorted(types, key=lambda t: (-phase_affinity(catalog[t]), t))
    cut = max(1, len(ranked) // 2)
    return ranked[:cut], ranked[cut:]


@register_planner("disagg")
def _plan_disagg(spec: DeploymentSpec, *,
                 prefill_types: Optional[Sequence[str]] = None,
                 decode_types: Optional[Sequence[str]] = None,
                 budget_splits: Sequence[float] = (0.2, 0.35, 0.5, 0.65, 0.8),
                 **options) -> ServingPlan:
    """Prefill/decode disaggregation over heterogeneous GPU types.

    Partitions the available catalog by ``costmodel.phase_affinity``
    (compute-rich types → prefill pool, bandwidth-rich types → decode
    pool; override with ``prefill_types``/``decode_types``), then solves
    the existing MILP once per phase with phase-restricted throughput
    tables, scanning ``budget_splits`` fractions of the shared budget
    given to the prefill side.  The merged plan carries role-tagged
    replicas (``Config.role``): arrivals are assigned to prefill replicas
    only (decode replicas get zero assignment mass — they receive work
    by KV handoff, not routing), and the modeled makespan is the slower
    phase's, since the phases pipeline against each other at runtime.

    Falls back to the colocated ``"milp"`` strategy when fewer than two
    GPU types are available or no budget split yields a feasible plan for
    both phases (``solver_info["disagg_fallback"] = 1.0``).
    """
    if spec.objective != "makespan":
        raise ValueError('strategy="disagg" currently plans the "makespan" '
                         'objective only')

    def fallback() -> ServingPlan:
        p = _plan_milp(spec, **options)
        p.solver_info["disagg_fallback"] = 1.0
        return p

    if prefill_types is None or decode_types is None:
        auto_p, auto_d = partition_by_affinity(spec.catalog,
                                               spec.availability)
        if prefill_types is None:
            prefill_types = auto_p
        if decode_types is None:
            decode_types = auto_d
    prefill_types = [t for t in prefill_types if t in spec.catalog]
    decode_types = [t for t in decode_types if t in spec.catalog]
    if (not prefill_types or not decode_types
            or set(prefill_types) == set(decode_types)):
        return fallback()

    def solve_phase(phase: str, pool: Sequence[str], budget: float
                    ) -> Optional[ServingPlan]:
        sub_catalog = {t: spec.catalog[t] for t in pool}
        sub_avail = {t: spec.availability.get(t, 0) for t in pool}
        if budget <= 0 or not any(sub_avail.values()):
            return None
        try:
            p = _solve(spec.models, spec.workload, sub_catalog, sub_avail,
                       budget,
                       throughput_fn=_phase_throughput_fn(
                           phase, spec.prefix_hit_rates),
                       **options)
        except (RuntimeError, ValueError):
            # Infeasible split (e.g. the phase budget cannot afford a
            # single replica of any type in the pool): try the next one.
            return None
        if not len(p.replicas) or not np.isfinite(p.makespan):
            return None
        return p

    best: Optional[Tuple[float, float, float, ServingPlan, ServingPlan]] = None
    for f in budget_splits:
        pplan = solve_phase("prefill", prefill_types, f * spec.budget)
        dplan = solve_phase("decode", decode_types, (1 - f) * spec.budget)
        if pplan is None or dplan is None:
            continue
        makespan = max(pplan.makespan, dplan.makespan)
        cost = pplan.cost + dplan.cost
        if best is None or (makespan, cost) < (best[0], best[1]):
            best = (makespan, cost, f, pplan, dplan)
    if best is None:
        return fallback()

    makespan, cost, split, pplan, dplan = best
    replicas = ([dataclasses.replace(c, role="prefill")
                 for c in pplan.replicas]
                + [dataclasses.replace(c, role="decode")
                   for c in dplan.replicas])
    # Arrival assignment covers prefill replicas only; decode replicas'
    # rows stay zero (the runtime's handoff picker, not the router,
    # chooses their work).  Both phase solves saw the same trace, so
    # their demand lists are identical.
    assignment = np.vstack([
        pplan.assignment,
        np.zeros((len(dplan.replicas), len(pplan.demands)))])
    info: Dict[str, float] = {
        "disagg": 1.0,
        "budget_split": float(split),
        "prefill_replicas": float(len(pplan.replicas)),
        "decode_replicas": float(len(dplan.replicas)),
        "prefill_makespan": float(pplan.makespan),
        "decode_makespan": float(dplan.makespan),
    }
    for t in sorted(set(prefill_types) | set(decode_types)):
        info[f"affinity_{t}"] = float(phase_affinity(spec.catalog[t]))
    return ServingPlan(replicas=replicas, assignment=assignment,
                       demands=pplan.demands, makespan=makespan,
                       cost=cost, solver_info=info)


# ------------------------------------------------- legacy entrypoints (deprecated)

def solve(models, trace, catalog, availability, budget, **kw) -> ServingPlan:
    """Deprecated: build a :class:`~repro.core.spec.DeploymentSpec` and
    call ``repro.core.plan(spec)`` instead."""
    _warn_legacy("solve", 'repro.core.plan(spec, strategy="milp")')
    return _solve(models, trace, catalog, availability, budget, **kw)


def solve_min_cost(models, trace, catalog, availability, budget,
                   slo_makespan, **kw) -> ServingPlan:
    """Deprecated: use ``repro.core.plan(spec)`` with
    ``spec.objective="cost"`` / ``spec.slo_makespan``."""
    _warn_legacy("solve_min_cost",
                 'repro.core.plan(spec with objective="cost")')
    return _solve_min_cost(models, trace, catalog, availability, budget,
                           slo_makespan, **kw)


def replan(
    plan: ServingPlan,
    models: Sequence[ModelProfile],
    trace: Trace,
    catalog: Mapping[str, DeviceType],
    new_availability: Mapping[str, int],
    budget: float,
    **kw,
) -> ServingPlan:
    """Deprecated: use ``repro.core.replan(old_plan, spec,
    availability=new_snapshot)`` — the spec-level twin with identical
    survivor accounting."""
    _warn_legacy("replan", "repro.core.replan(old_plan, spec, ...)")
    spec = DeploymentSpec(models=tuple(models), workload=trace,
                          catalog=catalog, availability=new_availability,
                          budget=budget)
    return spec_replan(plan, spec, **kw)


# ---------------------------------------------------------------- baselines

def homogeneous_availability(catalog: Mapping[str, DeviceType], gpu_type: str,
                             budget: float) -> Dict[str, int]:
    """Paper baseline: unlimited single-type pool (budget is the binding cap)."""
    dev = catalog[gpu_type]
    return {gpu_type: int(budget // dev.price_per_hour)}


def solve_homogeneous(models, trace, catalog, gpu_type: str, budget: float,
                      **kw) -> ServingPlan:
    """Deprecated: ``repro.core.plan(spec, strategy="homogeneous",
    gpu_type=...)``."""
    _warn_legacy("solve_homogeneous",
                 'repro.core.plan(spec, strategy="homogeneous")')
    avail = homogeneous_availability(catalog, gpu_type, budget)
    sub = {gpu_type: catalog[gpu_type]}
    return _solve(models, trace, sub, avail, budget, **kw)


def uniform_composition(catalog: Mapping[str, DeviceType],
                        availability: Mapping[str, int],
                        budget: float) -> Dict[str, int]:
    """Ablation (i): spread the budget evenly across available GPU types."""
    types = [t for t in availability if availability[t] > 0 and t in catalog]
    per_type = budget / max(len(types), 1)
    comp = {}
    for t in types:
        comp[t] = min(availability[t], int(per_type // catalog[t].price_per_hour))
    return comp


def solve_fixed_composition(models, trace, catalog, composition: Mapping[str, int],
                            budget: float, **kw) -> ServingPlan:
    """Deprecated: ``repro.core.plan(spec, strategy="fixed",
    composition=...)``."""
    _warn_legacy("solve_fixed_composition",
                 'repro.core.plan(spec, strategy="fixed")')
    return _solve(models, trace, catalog, composition, budget, **kw)


def apply_round_robin_assignment(plan: ServingPlan, h_fn: Callable) -> ServingPlan:
    """Ablation (iii): replace the optimized x with throughput-proportional
    (workload-unaware) dispatch across the plan's replicas."""
    R = len(plan.replicas)
    D = len(plan.demands)
    x = np.zeros((R, D))
    for d, (m, w, lam) in enumerate(plan.demands):
        rates = np.array([
            h_fn(cfg, w) if cfg.model_index == m else 0.0 for cfg in plan.replicas])
        total = rates.sum()
        if total > 0:
            x[:, d] = rates / total
    makespan = 0.0
    for i, cfg in enumerate(plan.replicas):
        t = sum(x[i, d] * plan.demands[d][2] / h_fn(cfg, plan.demands[d][1])
                for d in range(D) if x[i, d] > 0)
        makespan = max(makespan, t)
    return ServingPlan(replicas=plan.replicas, assignment=x, demands=plan.demands,
                       makespan=makespan, cost=plan.cost,
                       solver_info=dict(plan.solver_info, round_robin=1.0))


def solve_uniform_deployment(models, trace, catalog, availability, budget,
                             tp: int = 4, **kw) -> ServingPlan:
    """Deprecated: ``repro.core.plan(spec, strategy="uniform", tp=...)``."""
    _warn_legacy("solve_uniform_deployment",
                 'repro.core.plan(spec, strategy="uniform")')
    return _solve(models, trace, catalog, availability, budget,
                  include_mixed=False, **kw,
                  throughput_fn=None if tp is None else _only_tp(tp))


def _only_tp(tp: int) -> Callable:
    def fn(cfg: Config, w: WorkloadType) -> float:
        if len(cfg.stages) != 1 or cfg.stages[0].tp != tp:
            return 0.0
        return config_throughput(cfg.stages, cfg.model, w)
    return fn


# ------------------------------------------------------------- autoscaling

@dataclasses.dataclass(frozen=True)
class ReplicaSnapshot:
    """One replica's load observation at an autoscale tick (produced by
    ``ServingRuntime._snapshot``; consumed by :class:`ScalePolicy`)."""

    index: int
    config: Config
    queue_len: int          # requests queued, not yet admitted
    active: int             # requests decoding
    kv_used_frac: float     # used / total KV blocks (0 when unmanaged)
    draining: bool
    dead: bool = False      # torn down by a fault (reclaim/crash): not
                            # load, and not capacity either
    step_time_s: float = 0.0   # backend's decode-step estimate (engine:
                               # EMA of measured durations; 0 if unknown)


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """One online scaling action: the plan to replan to, plus provenance."""

    time: float
    action: str             # "add" | "drain"
    config_key: str
    reason: str
    plan: ServingPlan


def scaled_plan(base: ServingPlan, replicas: Sequence[Config], *,
                throughput_fn: Optional[Callable] = None) -> ServingPlan:
    """A plan over an online-rescaled replica set: same demands as
    ``base``, assignment re-derived throughput-proportionally (the MILP is
    not re-solved online — the autoscaler reacts in milliseconds; the
    solver refines at the next offline replan).  ``throughput_fn`` follows
    the ``solve()`` contract: called as ``fn(config, WorkloadType)``."""
    def h(cfg: Config, w: int) -> float:
        if throughput_fn is not None:
            return throughput_fn(cfg, WORKLOAD_TYPES[w])
        return config_throughput(cfg.stages, cfg.model, WORKLOAD_TYPES[w])

    R, D = len(replicas), len(base.demands)
    x = np.zeros((R, D))
    for d, (m, w, _) in enumerate(base.demands):
        rates = np.array([h(cfg, w) if cfg.model_index == m else 0.0
                          for cfg in replicas])
        total = rates.sum()
        if total > 0:
            x[:, d] = rates / total
    makespan = 0.0
    for i, cfg in enumerate(replicas):
        t = sum(x[i, d] * base.demands[d][2] / h(cfg, base.demands[d][1])
                for d in range(D) if x[i, d] > 0)
        makespan = max(makespan, t)
    return ServingPlan(replicas=list(replicas), assignment=x,
                       demands=base.demands, makespan=makespan,
                       cost=sum(c.cost for c in replicas),
                       solver_info=dict(base.solver_info or {},
                                        autoscaled=1.0))


class ScalePolicy:
    """Utilization-driven online autoscaler.

    Watches per-replica **queue depth** and **KV watermark** over a sliding
    window of ``window`` ticks (one tick every ``interval`` seconds of
    serving time) and emits at most one action per decision:

    * **add** — when the windowed mean queue depth per live replica
      reaches ``queue_high`` or the mean KV utilization reaches
      ``kv_high``, rent the best-value affordable config from
      ``candidates`` (total live cost stays within ``budget``);
    * **drain** — when load falls below ``queue_low`` *and* ``kv_low``
      and some live replica is idle, release the most expensive idle
      replica (never below ``min_replicas``, never stranding a model
      that still has demand).

    After any action the window is cleared and the next ``cooldown`` ticks
    are skipped (counting down while the window refills, so the reaction
    delay before the next possible decision is ``max(cooldown, window)``
    ticks).  The runtime
    applies decisions as rebalancing replans
    (:class:`~repro.runtime.orchestrator.ReplanEvent`), closing the loop
    between the MILP planner's static plan and observed load.
    """

    def __init__(self, candidates: Sequence[Config], budget: float, *,
                 interval: float = 0.5, window: int = 3,
                 queue_high: float = 3.0, queue_low: float = 0.25,
                 kv_high: float = 0.85, kv_low: float = 0.25,
                 cooldown: int = 2, min_replicas: int = 1,
                 throughput_fn: Optional[Callable] = None,
                 hit_rate_feedback: bool = False):
        if interval <= 0:
            raise ValueError(f"interval must be > 0, got {interval}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.candidates = list(candidates)
        self.budget = float(budget)
        self.interval = float(interval)
        self.window = int(window)
        self.queue_high = queue_high
        self.queue_low = queue_low
        self.kv_high = kv_high
        self.kv_low = kv_low
        self.cooldown = int(cooldown)
        self.min_replicas = int(min_replicas)
        self.throughput_fn = throughput_fn
        # When True, the runtime refreshes ``throughput_fn`` each tick
        # from the *measured* prefix hit rates of its KV managers
        # (``_hit_rate_throughput_fn``), so candidate valuation credits
        # the cache savings actually observed.
        self.hit_rate_feedback = bool(hit_rate_feedback)
        self.reset()

    @classmethod
    def from_spec(cls, spec: DeploymentSpec, plan: ServingPlan, *,
                  candidates: Optional[Sequence[Config]] = None,
                  **kw) -> "ScalePolicy":
        """Autoscaler over the same :class:`~repro.core.spec.DeploymentSpec`
        the plan came from: the budget cap is ``spec.budget`` and the
        candidate pool defaults to the plan's own replica configs (the
        shapes the planner already proved cost-efficient for this
        workload).  All tuning knobs pass through ``**kw``."""
        pool = list(plan.replicas) if candidates is None else list(candidates)
        return cls(candidates=pool, budget=spec.budget, **kw)

    def reset(self) -> None:
        """Clear observation history (called by the runtime at run start)."""
        self._history: List[Tuple[float, float]] = []
        self._cool = 0
        # Optional repro.obs.Observability (attached by the runtime):
        # records the windowed load signals behind every decision.
        self.obs = None

    def _arm_cooldown(self) -> None:
        self._history.clear()
        self._cool = self.cooldown

    def _value(self, cfg: Config, plan: ServingPlan) -> float:
        """Throughput-per-dollar of a candidate on the plan's demand mix.
        ``throughput_fn`` follows the ``solve()`` contract
        (``fn(config, WorkloadType)``)."""
        def h(c: Config, w: int) -> float:
            if self.throughput_fn is not None:
                return self.throughput_fn(c, WORKLOAD_TYPES[w])
            return config_throughput(c.stages, c.model, WORKLOAD_TYPES[w])
        gain = sum(lam * h(cfg, w) for (m, w, lam) in plan.demands
                   if m == cfg.model_index)
        return gain / max(cfg.cost, 1e-9)

    def update(self, now: float, snapshots: Sequence[ReplicaSnapshot],
               plan: ServingPlan) -> Optional[ScaleDecision]:
        """Observe one tick; returns a decision or None."""
        live = [s for s in snapshots if not s.draining and not s.dead]
        if not live:
            return None
        self._history.append((
            float(np.mean([s.queue_len for s in live])),
            float(np.mean([s.kv_used_frac for s in live]))))
        del self._history[:-self.window]
        if self.obs is not None:
            q, kv = self._history[-1]
            self.obs.on_scale_observe(now, q, kv)
        if self._cool > 0:           # counts down even while the cleared
            self._cool -= 1          # window refills: reaction delay is
            return None              # max(cooldown, window) ticks
        if len(self._history) < self.window:
            return None
        queue_depth = float(np.mean([q for q, _ in self._history]))
        kv_util = float(np.mean([k for _, k in self._history]))
        reason = f"queue={queue_depth:.2f},kv={kv_util:.2f}"
        cfgs = [s.config for s in live]
        cost_now = sum(c.cost for c in cfgs)
        if queue_depth >= self.queue_high or kv_util >= self.kv_high:
            afford = [c for c in self.candidates
                      if cost_now + c.cost <= self.budget + 1e-9
                      and self._value(c, plan) > 0]   # must serve demand
            if not afford:
                return None
            best = max(afford, key=lambda c: self._value(c, plan))
            self._arm_cooldown()
            return ScaleDecision(
                time=now, action="add", config_key=best.key, reason=reason,
                plan=scaled_plan(plan, cfgs + [best],
                                 throughput_fn=self.throughput_fn))
        if (len(live) > self.min_replicas and queue_depth <= self.queue_low
                and kv_util <= self.kv_low):
            needed = {m for (m, _, lam) in plan.demands if lam > 0}
            idle = [s for s in live if s.queue_len == 0 and s.active == 0]
            for victim in sorted(idle, key=lambda s: -s.config.cost):
                rest = list(cfgs)
                rest.remove(victim.config)
                if needed <= {c.model_index for c in rest}:
                    self._arm_cooldown()
                    return ScaleDecision(
                        time=now, action="drain",
                        config_key=victim.config.key, reason=reason,
                        plan=scaled_plan(plan, rest,
                                         throughput_fn=self.throughput_fn))
        return None
