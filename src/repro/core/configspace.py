"""Feasible deployment-configuration enumeration (§4.3 precomputation + App D).

A configuration is a pipeline of stages; each stage is ``tp`` devices of one
type inside one machine (App-D heuristic i: TP only within a machine).  We
enumerate:

* homogeneous configs: one device type, tp ∈ {1,2,4,8}, pp ∈ {1..MAX_STAGES};
* mixed-type PP configs: 2..MAX_STAGES stages drawn from up to two device
  types (HexGen-style asymmetric pipelines), non-uniform layer split
  proportional to stage memory (App-D heuristic ii);

and filter by the App-D constraints:

* memory check: Σ_n d_n(c)·m_n ≥ M_r;
* availability: d_n(c) ≤ a_n for every type;
* connectivity: all stage device types must be mutually connected
  (``connected`` predicate; defaults to everything-connected, matching a
  single cloud region);

followed by dominance pruning (App G i): drop c if some c' costs no more and
has ≥ throughput on every workload.
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.core import costmodel
from repro.core.catalog import DeviceType
from repro.core.costmodel import ModelProfile, Stage, config_throughput
from repro.core.plan import Config
from repro.core.workloads import WORKLOAD_TYPES, WorkloadType

TP_DEGREES = (1, 2, 4, 8)
MAX_STAGES = 4
MAX_MIXED_TYPES = 2


def _make_config(stage_specs: Sequence[tuple], model: ModelProfile,
                 model_index: int) -> Config:
    """Build a Config with memory-proportional non-uniform layer split."""
    mems = np.array([dev.memory_bytes * tp for dev, tp in stage_specs], dtype=float)
    fracs = mems / mems.sum()
    stages = tuple(Stage(dev, tp, float(f)) for (dev, tp), f in zip(stage_specs, fracs))
    return Config(stages=stages, model_index=model_index, model=model)


def _memory_ok(config: Config) -> bool:
    total = sum(st.memory for st in config.stages)
    return total >= config.model.min_memory_bytes()


def _availability_ok(config: Config, availability: Mapping[str, int]) -> bool:
    for name, n in config.device_counts().items():
        if n > availability.get(name, 0):
            return False
    return True


def enumerate_configs(
    model: ModelProfile,
    catalog: Mapping[str, DeviceType],
    availability: Mapping[str, int],
    *,
    model_index: int = 0,
    max_stages: int = MAX_STAGES,
    tp_degrees: Sequence[int] = TP_DEGREES,
    connected: Optional[Callable[[str, str], bool]] = None,
    include_mixed: bool = True,
) -> List[Config]:
    """Enumerate all feasible configs for one model."""
    connected = connected or (lambda a, b: True)
    types = [t for t in catalog.values() if availability.get(t.name, 0) > 0]
    configs: List[Config] = []

    # Per-type stage menu (respect machine size).
    stage_menu: Dict[str, List[tuple]] = {}
    for dev in types:
        stage_menu[dev.name] = [(dev, tp) for tp in tp_degrees
                                if tp <= dev.devices_per_machine]

    # Homogeneous configs: same (type, tp) repeated pp times.
    for dev in types:
        for (d, tp) in stage_menu[dev.name]:
            for pp in range(1, max_stages + 1):
                if tp * pp > availability.get(dev.name, 0):
                    continue
                configs.append(_make_config([(d, tp)] * pp, model, model_index))

    # Mixed-type pipelines (asymmetric stages over ≤ MAX_MIXED_TYPES types).
    if include_mixed and len(types) > 1:
        all_stage_options = [s for dev in types for s in stage_menu[dev.name]]
        for n_stages in range(2, max_stages + 1):
            for combo in itertools.combinations_with_replacement(all_stage_options, n_stages):
                names = {dev.name for dev, _ in combo}
                if len(names) < 2 or len(names) > MAX_MIXED_TYPES:
                    continue  # homogeneous handled above; cap type diversity
                if not all(connected(a, b) for a in names for b in names):
                    continue
                configs.append(_make_config(list(combo), model, model_index))

    configs = [c for c in configs if _memory_ok(c) and _availability_ok(c, availability)]
    return configs


def throughput_table(configs: Sequence[Config],
                     workloads: Sequence[WorkloadType] = WORKLOAD_TYPES,
                     throughput_fn: Optional[Callable] = None) -> np.ndarray:
    """h_{c,w} matrix (req/s).  ``throughput_fn(config, workload)`` overrides
    the analytical model (e.g. with a profiled table)."""
    fn = throughput_fn or (lambda c, w: config_throughput(c.stages, c.model, w))
    h = np.zeros((len(configs), len(workloads)))
    for i, c in enumerate(configs):
        for j, w in enumerate(workloads):
            h[i, j] = fn(c, w)
    return h


def prune_dominated(configs: List[Config], h: np.ndarray,
                    tol: float = 1e-9) -> tuple[List[Config], np.ndarray]:
    """App-G pruning: drop configs dominated on (cost, every-workload h).

    A config is dominated if another has cost ≤ and throughput ≥ everywhere
    (strictly better somewhere).  Also drops configs with all-zero throughput.
    """
    keep: List[int] = []
    costs = np.array([c.cost for c in configs])
    order = np.argsort(costs)  # cheap first: dominators found early
    for idx in order:
        if h[idx].max() <= tol:
            continue
        dominated = False
        for k in keep:
            if costs[k] <= costs[idx] + tol and np.all(h[k] >= h[idx] - tol):
                dominated = True
                break
        if not dominated:
            keep.append(idx)
    # Exact second pass: cost ties admitted above can still dominate each
    # other (greedy only checks against earlier-kept entries).
    final: List[int] = []
    for i in keep:
        dominated = any(
            j != i and costs[j] <= costs[i] + tol
            and np.all(h[j] >= h[i] - tol)
            and (costs[j] < costs[i] - tol or np.any(h[j] > h[i] + tol))
            for j in keep)
        if not dominated:
            final.append(i)
    final.sort()
    return [configs[i] for i in final], h[final]
