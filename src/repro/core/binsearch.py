"""App-F binary-search-on-T with knapsack-approximation pre-check.

Rather than minimizing T directly (which needs the bilinear linearization in
``milp.solve_milp``), bisect on a candidate makespan T̂: for fixed T̂ the
makespan constraint is linear, so each step is a cheap feasibility MILP.  A
greedy knapsack-style check can certify feasibility without invoking the
solver at all (greedy success ⇒ feasible; greedy failure falls through to the
exact check).
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

from repro.core.milp import SchedulingProblem, solve_feasibility, _plan_from_solution
from repro.core.plan import ServingPlan


def knapsack_feasible(problem: SchedulingProblem, t_hat: float
                      ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Greedy sufficiency check: repeatedly rent the replica with the best
    (remaining-demand served within T̂) per dollar, respecting budget and
    availability.  Returns a witness (y, x) on success, None otherwise."""
    C, D = problem.h.shape
    lam = problem.lam.copy()
    remaining = lam.copy()            # requests still unassigned
    avail = dict(problem.availability)
    budget = problem.budget
    y = np.zeros(C)
    served = np.zeros((C, D))         # requests (not fractions) per replica-set

    def can_rent(c: int) -> bool:
        cfg = problem.configs[c]
        if cfg.cost > budget + 1e-9:
            return False
        return all(avail.get(n, 0) >= k for n, k in cfg.device_counts().items())

    for _ in range(1024):
        if remaining.sum() <= 1e-9:
            break
        best_c, best_gain, best_take = -1, 0.0, None
        for c in range(C):
            if not can_rent(c):
                continue
            cfg = problem.configs[c]
            # Fill one copy of c greedily with the demands it serves fastest.
            cap = t_hat
            take = np.zeros(D)
            order = np.argsort(-problem.h[c])
            got = 0.0
            for d in order:
                if problem.h[c, d] <= 0 or remaining[d] <= 0:
                    continue
                rate = problem.h[c, d]
                n = min(remaining[d], cap * rate)
                take[d] = n
                got += n
                cap -= n / rate
                if cap <= 1e-12:
                    break
            gain = got / max(cfg.cost, 1e-9)
            if gain > best_gain:
                best_c, best_gain, best_take = c, gain, take
        if best_c < 0 or best_gain <= 0:
            return None
        cfg = problem.configs[best_c]
        y[best_c] += 1
        served[best_c] += best_take
        remaining -= best_take
        budget -= cfg.cost
        for n, k in cfg.device_counts().items():
            avail[n] = avail.get(n, 0) - k
    if remaining.sum() > 1e-9:
        return None
    x = np.zeros((C, D))
    for d in range(D):
        if lam[d] > 0:
            x[:, d] = served[:, d] / lam[d]
    return y, x


def solve_binary_search(problem: SchedulingProblem, *, tol: float = 1.0,
                        time_limit_per_check: float = 30.0,
                        use_knapsack: bool = True,
                        max_iters: int = 64) -> ServingPlan:
    """Algorithm 1: bisect [T_lb, T_ub]; keep the best feasible witness."""
    t0 = time.perf_counter()
    t_hi = problem.makespan_upper_bound()
    t_lo = 0.0
    best: Optional[Tuple[np.ndarray, np.ndarray]] = None
    iters = 0
    knapsack_hits = 0
    while t_hi - t_lo > tol and iters < max_iters:
        t_hat = 0.5 * (t_lo + t_hi)
        witness = None
        if use_knapsack:
            witness = knapsack_feasible(problem, t_hat)
            if witness is not None:
                knapsack_hits += 1
        if witness is None:
            witness = solve_feasibility(problem, t_hat,
                                        time_limit=time_limit_per_check)
        if witness is not None:
            best = witness
            t_hi = t_hat
        else:
            t_lo = t_hat
        iters += 1
    if best is None:
        # The initial upper bound itself must be feasible.
        best = solve_feasibility(problem, t_hi, time_limit=time_limit_per_check)
        if best is None:
            raise RuntimeError("binary search found no feasible plan")
    elapsed = time.perf_counter() - t0
    y, x = best
    info = {"solver": 1.0, "solve_time_s": elapsed, "iterations": float(iters),
            "knapsack_hits": float(knapsack_hits), "objective_T": float(t_hi)}
    return _plan_from_solution(problem, y, x, info)
