"""Llama3-8B [arXiv:2407.21783] — the paper's small evaluation model."""
from repro.models.config import ATTN, MLP, ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="llama3-8b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    period=(LayerDesc(ATTN, MLP),),
    rope_theta=500_000.0,
    mlp_act="silu",
    norm="rmsnorm",
    long_context_mode="sliding_window",
    source="arXiv:2407.21783",
)
