"""ChatGLM3-6B [arXiv:2406.12793] — dense, 2d (half-dim) RoPE, GQA kv=2,
QKV bias."""
from repro.models.config import ATTN, MLP, ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="chatglm3-6b",
    arch_type="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    period=(LayerDesc(ATTN, MLP),),
    rope_fraction=0.5,
    qkv_bias=True,
    mlp_act="silu",
    norm="rmsnorm",
    long_context_mode="sliding_window",
    source="arXiv:2406.12793",
)
