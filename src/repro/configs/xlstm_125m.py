"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM blocks (no separate FFN;
d_ff=0).  Ratio 5:1 mLSTM:sLSTM per period of 6 (xLSTM[7:1]-style mix fitted
to 12 layers).  Constant-size recurrent state -> native long-context decode."""
from repro.models.config import MLSTM, NONE, SLSTM, ArchConfig, LayerDesc

_PERIOD = tuple(LayerDesc(MLSTM, NONE) for _ in range(5)) + (LayerDesc(SLSTM, NONE),)

CONFIG = ArchConfig(
    name="xlstm-125m",
    arch_type="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    period=_PERIOD,
    norm="layernorm",
    source="arXiv:2405.04517",
)
