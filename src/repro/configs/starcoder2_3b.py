"""StarCoder2-3B [arXiv:2402.19173] — dense, GQA kv=2, RoPE, LayerNorm,
plain (ungated) GELU MLP."""
from repro.models.config import ATTN, MLP, ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="starcoder2-3b",
    arch_type="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    period=(LayerDesc(ATTN, MLP),),
    rope_theta=100_000.0,
    qkv_bias=True,
    mlp_act="gelu",
    mlp_gated=False,
    norm="layernorm",
    long_context_mode="sliding_window",
    source="arXiv:2402.19173",
)
