"""Gemma-2 27B [arXiv:2408.00118] — dense, local(SWA 4096)/global
alternating attention, attention + final-logit softcapping, GQA kv=16,
scaled & tied embeddings, GeGLU."""
from repro.models.config import ATTN, ATTN_LOCAL, MLP, ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="gemma2-27b",
    arch_type="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    period=(LayerDesc(ATTN_LOCAL, MLP), LayerDesc(ATTN, MLP)),
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    scale_embed=True,
    tie_embeddings=True,
    mlp_act="gelu",
    norm="rmsnorm",
    long_context_mode="sliding_window",  # global layers windowed at 500k
    source="arXiv:2408.00118",
)
