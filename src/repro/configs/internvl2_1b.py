"""InternVL2-1B [arXiv:2404.16821] — Qwen2-0.5B language backbone (24L,
d=896, 14H GQA kv=2, head_dim 64) consuming InternViT patch embeddings.
The vision tower + projector is a STUB: ``input_specs`` provides 256
precomputed patch embeddings; the language model is fully implemented."""
from repro.models.config import ATTN, MLP, ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="internvl2-1b",
    arch_type="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    period=(LayerDesc(ATTN, MLP),),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    mlp_act="silu",
    norm="rmsnorm",
    frontend="vision_stub",
    num_patches=256,
    long_context_mode="sliding_window",
    source="arXiv:2404.16821",
)
