"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family] — 94 layers, MoE 128
experts top-8 (per-expert d_ff=1536), GQA kv=4, QK-norm, RoPE theta=1e6."""
from repro.models.config import ATTN, MOE, ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    period=(LayerDesc(ATTN, MOE),),
    rope_theta=1_000_000.0,
    qk_norm=True,
    n_experts=128,
    n_experts_active=8,
    moe_d_ff=1536,
    mlp_act="silu",
    norm="rmsnorm",
    long_context_mode="sliding_window",
    source="hf:Qwen/Qwen3-30B-A3B",
)
