"""Mixtral-8x22B [arXiv:2401.04088] — MoE 8 experts top-2, sliding-window
attention, GQA kv=8."""
from repro.models.config import ATTN_LOCAL, MOE, ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    period=(LayerDesc(ATTN_LOCAL, MOE),),
    sliding_window=4096,
    rope_theta=1_000_000.0,
    n_experts=8,
    n_experts_active=2,
    moe_d_ff=16384,
    mlp_act="silu",
    norm="rmsnorm",
    source="arXiv:2401.04088",
)
