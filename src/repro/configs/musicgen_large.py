"""MusicGen-large [arXiv:2306.05284] — decoder-only transformer over EnCodec
audio tokens (vocab 2048).  The EnCodec/conditioning frontend is a STUB:
``input_specs`` provides a 64-token precomputed conditioning-prefix embedding;
the decoder itself is fully implemented (LayerNorm, GELU, ungated MLP)."""
from repro.models.config import ATTN, MLP, ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    period=(LayerDesc(ATTN, MLP),),
    mlp_act="gelu",
    mlp_gated=False,
    norm="layernorm",
    frontend="audio_stub",
    num_patches=64,
    long_context_mode="sliding_window",
    source="arXiv:2306.05284",
)
