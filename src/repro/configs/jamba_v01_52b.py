"""Jamba-v0.1 (52B) [arXiv:2403.19887] — hybrid Mamba+attention 1:7
interleave (attention at layer i%8==4), MoE 16 experts top-2 every other
layer, GQA kv=8, no positional encoding."""
from repro.models.config import ATTN, MAMBA, MLP, MOE, ArchConfig, LayerDesc

_PERIOD = tuple(
    LayerDesc(ATTN if i == 4 else MAMBA, MOE if i % 2 == 1 else MLP)
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    period=_PERIOD,
    use_rope=False,
    n_experts=16,
    n_experts_active=2,
    moe_d_ff=14336,
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    mlp_act="silu",
    norm="rmsnorm",
    source="arXiv:2403.19887",
)
