"""Architecture registry: the 10 assigned public-pool architectures (each
cites its source) plus the paper's own evaluation models (Llama3-8B/70B).

``get_config(name)`` / ``list_archs()`` are the ``--arch <id>`` entry points.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

_MODULES = {
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "musicgen-large": "repro.configs.musicgen_large",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "llama3-8b": "repro.configs.llama3_8b",
    "llama3-70b": "repro.configs.llama3_70b",
}

ASSIGNED_ARCHS = tuple(list(_MODULES)[:10])


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def list_archs() -> List[str]:
    return list(_MODULES)


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get_config(name) for name in _MODULES}
