"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — dense, Qwen1.5 architecture:
GQA kv=32 (== MHA at 32 heads), RoPE theta=1e6, QKV bias, SwiGLU, RMSNorm."""
from repro.models.config import ATTN, MLP, ArchConfig, LayerDesc

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    period=(LayerDesc(ATTN, MLP),),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    mlp_act="silu",
    norm="rmsnorm",
    long_context_mode="sliding_window",
    source="hf:Qwen/CodeQwen1.5-7B",
)
