"""Version-compat shims for the Pallas TPU API.

The TPU compiler-params class was renamed across JAX releases:
``pltpu.TPUCompilerParams`` (<= 0.4.x) became ``pltpu.CompilerParams``
(newer releases).  Both kernels route through :func:`tpu_compiler_params`
so they lower on either pin.
"""
from __future__ import annotations

from typing import Sequence

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(dimension_semantics: Sequence[str], **kwargs):
    """Build the TPU compiler-params object for ``pl.pallas_call``."""
    return _COMPILER_PARAMS_CLS(
        dimension_semantics=tuple(dimension_semantics), **kwargs)
