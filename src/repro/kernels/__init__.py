"""Pallas TPU kernels for serving's compute hot spots (validated in
interpret mode on CPU against pure-jnp oracles in each ref.py)."""
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.decode_attention.ops import decode_attention_op
