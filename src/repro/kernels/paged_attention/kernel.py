"""Pallas TPU paged flash-decode: one query token per sequence against a
block-table KV cache.

Same memory-bound organization as the contiguous ``decode_attention``
kernel — grid = (batch, kv_heads, kv_blocks) with the online-softmax state
((G, D) acc, (G,) m/l) in VMEM scratch and all G = H/KV query heads of one
kv head processed as an MXU-shaped (G, BLOCK) tile — but K/V live in a
shared pool of fixed-size token blocks and each sequence reaches its
history *through a block table*:

* ``k_pool``/``v_pool`` are ``(num_blocks, block_size, KV, D)``: the
  physical pool every sequence's blocks are scattered across.
* ``block_tables`` is ``(B, blocks_per_seq)`` int32: logical block ``i`` of
  sequence ``b`` lives in physical block ``block_tables[b, i]``.
* The table (and per-sequence ``lengths``) ride scalar prefetch
  (``PrefetchScalarGridSpec``) so the *index map* — not the kernel body —
  resolves the indirection: the pipeline DMAs exactly the right pool block
  into VMEM per grid step, which is what makes paged gather free on TPU.

Blocks past a sequence's length are skipped (``pl.when``), so the cost of
a step is proportional to the tokens actually held, not to the table
width.  Out-of-range table entries may point anywhere (allocators pass 0);
the in-block position mask keeps them out of the softmax.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _paged_decode_kernel(tables_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, block_size: int,
                         scale: float, softcap: float):
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[bi]
    k_start = ki * block_size

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)             # (BS, D)
        v = v_ref[0, :, 0].astype(jnp.float32)             # (BS, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (G, BS)
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(kpos < length, logits, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *, softcap: float = 0.0,
                           interpret: bool = True) -> jax.Array:
    """q: (B, H, D) one token per sequence; k/v_pool: (NB, BS, KV, D)
    physical block pools; block_tables: (B, MB) int32; lengths: (B,) valid
    tokens per sequence.  Returns (B, H, D)."""
    b, h, d = q.shape
    bs, kv = k_pool.shape[1], k_pool.shape[2]
    mb = block_tables.shape[1]
    assert h % kv == 0
    g = h // kv
    scale = 1.0 / math.sqrt(d)

    q_g = q.reshape(b, kv, g, d)

    def q_map(bi, hi, ki, tables, lens):
        del ki, tables, lens
        return (bi, hi, 0, 0)

    def kv_map(bi, hi, ki, tables, lens):
        del lens
        return (tables[bi, ki], 0, hi, 0)

    kernel = functools.partial(_paged_decode_kernel, block_size=bs,
                               scale=scale, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,     # block_tables, lengths
        grid=(b, kv, mb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), q_map),
            pl.BlockSpec((1, bs, 1, d), kv_map),
            pl.BlockSpec((1, bs, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), q_g,
      k_pool, v_pool)
    return out.reshape(b, h, d)
