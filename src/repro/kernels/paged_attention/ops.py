"""Jit'd public wrapper for the paged flash-decode kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_decode_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("softcap",))
def paged_decode_attention_op(q, k_pool, v_pool, block_tables, lengths, *,
                              softcap: float = 0.0):
    """q: (B,H,D); pools: (NB,BS,KV,D); block_tables: (B,MB); lengths: (B,)
    -> (B,H,D)."""
    return paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                                  softcap=softcap, interpret=not _on_tpu())
