from repro.kernels.paged_attention.kernel import paged_decode_attention
from repro.kernels.paged_attention.ops import paged_decode_attention_op
from repro.kernels.paged_attention.ref import (gather_kv,
                                               paged_decode_attention_ref)

__all__ = ["gather_kv", "paged_decode_attention",
           "paged_decode_attention_op", "paged_decode_attention_ref"]
