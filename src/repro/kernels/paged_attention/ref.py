"""Pure-jnp oracle for the paged flash-decode kernel.

Gathers each sequence's blocks into a contiguous cache and defers to the
contiguous flash-decode reference — stating the paged kernel's contract
directly: paged attention IS dense decode attention after the block-table
gather.
"""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.ref import decode_attention_ref


def gather_kv(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(NB, BS, KV, D) pool + (B, MB) tables -> contiguous (B, MB*BS, KV, D)."""
    b, mb = block_tables.shape
    bs, kv, d = pool.shape[1:]
    return pool[block_tables].reshape(b, mb * bs, kv, d)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array, *, softcap: float = 0.0
                               ) -> jax.Array:
    """q: (B,H,D); k/v_pool: (NB,BS,KV,D); block_tables: (B,MB) int32;
    lengths: (B,) -> (B,H,D)."""
    return decode_attention_ref(q, gather_kv(k_pool, block_tables),
                                gather_kv(v_pool, block_tables),
                                lengths, softcap=softcap)
