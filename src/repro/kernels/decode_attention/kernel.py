"""Pallas TPU flash-decode: one query token per sequence against a KV cache.

Decode attention is memory-bound — the whole KV cache streams through VMEM
once per step — so the kernel is organized around that stream:

* grid = (batch, kv_heads, num_kv_blocks), kv innermost with the online
  softmax state ((G, D) acc, (G,) m/l) in VMEM scratch.
* All G = H/KV query heads of one kv head are processed together: the logits
  tile is (G, BLOCK_K) and the weighted-value accumulation is (G, D) — this
  turns GQA's head grouping into an MXU-shaped matmul instead of G separate
  vector dots (the TPU-native answer to CUDA's per-warp q-head splits).
* ``lengths`` masks slots beyond each sequence's current cache fill (ring
  buffers pass their window size once full).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, block_k: int, scale: float, softcap: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[pl.program_id(0)]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)                # (BK, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (G, BK)
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(kpos < length, logits, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, softcap: float = 0.0,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = True) -> jax.Array:
    """q: (B, H, D) one token per sequence; k/v_cache: (B, T, KV, D);
    lengths: (B,) valid cache entries.  Returns (B, H, D)."""
    b, h, d = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    assert h % kv == 0
    g = h // kv
    block_k = min(block_k, t)
    nk = pl.cdiv(t, block_k)
    scale = 1.0 / math.sqrt(d)

    q_g = q.reshape(b, kv, g, d)
    kc = k_cache.transpose(0, 2, 1, 3)   # (B, KV, T, D)
    vc = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(_decode_kernel, block_k=block_k, scale=scale,
                               softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths, whole array
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, ki: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, ki: (b_, h_, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, ki: (b_, h_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, ki: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q_g, kc, vc)
    return out.reshape(b, h, d)
