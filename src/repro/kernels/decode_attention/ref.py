"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         lengths: jax.Array, *, softcap: float = 0.0
                         ) -> jax.Array:
    """q: (B,H,D); k/v_cache: (B,T,KV,D); lengths: (B,) -> (B,H,D)."""
    b, h, d = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, d).astype(jnp.float32)
    kc = k_cache.astype(jnp.float32)
    vc = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, kc) / math.sqrt(d)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    valid = jnp.arange(t)[None, :] < lengths[:, None]          # (B,T)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, vc)
    return out.reshape(b, h, d).astype(q.dtype)
