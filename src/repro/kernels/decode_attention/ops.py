"""Jit'd public wrapper for the flash-decode kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("softcap", "block_k"))
def decode_attention_op(q, k_cache, v_cache, lengths, *, softcap: float = 0.0,
                        block_k: int = 512):
    """q: (B,H,D); caches: (B,T,KV,D); lengths: (B,) -> (B,H,D)."""
    return decode_attention(q, k_cache, v_cache, lengths, softcap=softcap,
                            block_k=block_k, interpret=not _on_tpu())
