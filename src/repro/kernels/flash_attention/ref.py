"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """q: (B,H,S,D); k/v: (B,KV,S,D).  Plain materialized-softmax attention."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
