"""Jit'd public wrapper for the flash-attention kernel.

On TPU backends the Pallas kernel compiles natively; elsewhere it runs in
interpret mode (Python emulation of the kernel body) so correctness is
validated on CPU.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       softcap: float = 0.0, block_q: int = 256,
                       block_k: int = 256):
    """q: (B,H,S,D); k/v: (B,KV,S,D) -> (B,H,S,D)."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, block_q=block_q, block_k=block_k,
                           interpret=not _on_tpu())
