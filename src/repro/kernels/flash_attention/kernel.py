"""Pallas TPU flash attention (prefill path).

Blockwise online-softmax attention with explicit VMEM tiling:

* grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the kv dimension is
  innermost ("arbitrary" semantics) so the running (m, l, acc) state lives in
  VMEM scratch across kv steps — the classic TPU flash schedule.
* BlockSpecs stream (BLOCK_Q, head_dim) query tiles and (BLOCK_K, head_dim)
  key/value tiles into VMEM; head_dim stays whole (128 = one MXU tile for
  most archs; 64-dim heads pad inside the MXU).
* GQA is handled in the index_map: query head h reads kv head h // group.
* Supports causal masking, sliding windows, and Gemma-2 style logit softcap.

Numerics: logits and the softmax state are fp32; inputs/outputs bf16.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, seq_len: int, causal: bool,
                  window: int, softcap: float, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Skip fully-masked kv blocks (past the causal frontier / below the
    # sliding window's reach for this q block).
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window > 0:
        run &= k_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)               # (BK, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (BQ, BK)
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, KV, S, D) with H % KV == 0 -> (B, H, S, D)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0, (h, kv)
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq = pl.cdiv(s, block_q)
    nk = pl.cdiv(s, block_k)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=s,
        causal=causal, window=window, softcap=softcap, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            ("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
