"""repro: cost-efficient LLM serving over heterogeneous accelerators
(ICML'25 reproduction) — scheduler core, JAX model zoo, serving runtime,
Pallas kernels, multi-pod launch.

Public lifecycle: build a declarative ``repro.DeploymentSpec`` (models,
workload, catalog, availability, budget, SLOs), plan it with
``repro.plan(spec, strategy=...)``, and serve it online with
``repro.serve(spec_or_plan, ...)`` — a live ``Session`` whose
``submit()`` returns streaming request handles.
"""
__version__ = "0.1.0"


def serve(spec_or_plan, **kwargs):
    """Open an online serving session (see ``repro.serving.session.serve``)."""
    from repro.serving.session import serve as _serve
    return _serve(spec_or_plan, **kwargs)


def plan(spec, strategy: str = "milp", **options):
    """Plan a deployment spec (see ``repro.core.spec.plan``)."""
    from repro.core.spec import plan as _plan
    return _plan(spec, strategy=strategy, **options)


_LAZY = {
    "DeploymentSpec": ("repro.core.spec", "DeploymentSpec"),
    "replan": ("repro.core.spec", "replan"),
    "Session": ("repro.serving.session", "Session"),
    "RequestHandle": ("repro.serving.session", "RequestHandle"),
    "Observability": ("repro.obs", "Observability"),
    "TickClock": ("repro.obs", "TickClock"),
}


def __getattr__(name):
    # Lazy so `import repro` stays light (no jax import at top level).
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib
    return getattr(importlib.import_module(module), attr)
