"""repro: cost-efficient LLM serving over heterogeneous accelerators
(ICML'25 reproduction) — scheduler core, JAX model zoo, serving runtime,
Pallas kernels, multi-pod launch."""
__version__ = "0.1.0"
