"""Mamba-1 selective SSM block (for Jamba), TPU-adapted.

Hardware adaptation: the CUDA reference fuses the sequential scan into a
single kernel holding state in SRAM.  On TPU we instead exploit that the
selective recurrence h_t = Ā_t·h_{t-1} + B̄_t x_t is *linear*, so it maps to
``jax.lax.associative_scan`` (parallel, O(log S) depth, shardable).  To keep
the (B, S, d_inner, d_state) discretized tensors out of HBM we scan over
fixed-size chunks: within a chunk, associative scan; across chunks, a small
(B, d_inner, d_state) carry — the same blocking structure the official
Mamba-2 "chunked" algorithm uses.

``mamba_prefill`` processes a full sequence and returns the final state for
decode; ``mamba_step`` advances one token against the recurrent state.

Decode-state contract (horizon-fused decode): the ``{"conv", "h"}`` state
returned by both functions is a fixed-shape, fixed-dtype pytree —
``conv`` (B, K-1, d_inner) bf16, ``h`` (B, d_inner, d_state) fp32 — so it
rides a ``jax.lax.scan`` carry unchanged and ``transformer.decode_steps``
can fuse k Mamba steps into one jit.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

CHUNK = 256


def _ssm_scan_chunked(a: jax.Array, bx: jax.Array, h0: jax.Array,
                      chunk: int = CHUNK) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + bx_t along axis 1.

    a, bx: (B, S, D, N) fp32; h0: (B, D, N).  Returns (h all t, final h).
    """
    b, s, d, n = a.shape
    if s % chunk:
        pad = chunk - s % chunk
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = a.shape[1] // chunk
    a_c = a.reshape(b, nc, chunk, d, n).transpose(1, 0, 2, 3, 4)
    bx_c = bx.reshape(b, nc, chunk, d, n).transpose(1, 0, 2, 3, 4)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a2 * a1, a2 * b1 + b2

    def chunk_body(h, inputs):
        ac, bxc = inputs                       # (B, chunk, D, N)
        aa, bb = jax.lax.associative_scan(combine, (ac, bxc), axis=1)
        h_all = aa * h[:, None] + bb           # inject carry
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(chunk_body, h0, (a_c, bx_c))
    h_all = h_chunks.transpose(1, 0, 2, 3, 4).reshape(b, -1, d, n)[:, :s]
    return h_all, h_last


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array,
                 state: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (B,S,D); w: (K,D); state: (B,K-1,D)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)            # (B, S+K-1, D)
    out = sum(xx[:, i:i + x.shape[1]] * w[i] for i in range(k)) + bias
    new_state = xx[:, -(k - 1):] if k > 1 else jnp.zeros_like(state)
    return out, new_state


def _ssm_inner(cfg: ArchConfig, p: dict, xc: jax.Array, h0: jax.Array):
    """Shared selective-SSM math after the conv.  xc: (B,S,D_inner)."""
    ds = cfg.ssm_state_dim
    proj = jnp.einsum("bsd,dk->bsk", xc, p["x_proj"]).astype(jnp.float32)
    dt_rank = p["dt_proj"].shape[0]
    dt_raw, b_ssm, c_ssm = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_raw, p["dt_proj"])
                         + p["dt_bias"])                       # (B,S,D)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))               # (D,N)
    a_bar = jnp.exp(dt[..., None] * a)                         # (B,S,D,N)
    bx = (dt[..., None] * b_ssm[:, :, None, :]
          * xc.astype(jnp.float32)[..., None])                 # (B,S,D,N)
    h_all, h_last = _ssm_scan_chunked(a_bar, bx, h0)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, c_ssm)
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    return y.astype(xc.dtype), h_last


def mamba_prefill(cfg: ArchConfig, p: dict, x: jax.Array
                  ) -> Tuple[jax.Array, dict]:
    """x: (B,S,d_model) -> (y, state).  state = {conv, h}."""
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xc, z = jnp.split(xz, 2, axis=-1)                          # (B,S,Di)
    xc, conv_state = _causal_conv(xc, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    h0 = jnp.zeros((b, cfg.d_inner, cfg.ssm_state_dim), jnp.float32)
    y, h_last = _ssm_inner(cfg, p, xc, h0)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": conv_state, "h": h_last}


def mamba_step(cfg: ArchConfig, p: dict, x: jax.Array, state: dict
               ) -> Tuple[jax.Array, dict]:
    """One decode step.  x: (B,1,d_model)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xc, p["conv_w"], p["conv_b"], state["conv"])
    xc = jax.nn.silu(xc)
    y, h_last = _ssm_inner(cfg, p, xc, state["h"])
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": conv_state, "h": h_last}


def mamba_ref(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """Oracle: plain sequential jax.lax.scan over time (no chunking)."""
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(xc, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    ds = cfg.ssm_state_dim
    proj = jnp.einsum("bsd,dk->bsk", xc, p["x_proj"]).astype(jnp.float32)
    dt_rank = p["dt_proj"].shape[0]
    dt_raw, b_ssm, c_ssm = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_raw, p["dt_proj"])
                         + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    a_bar = jnp.exp(dt[..., None] * a)
    bx = dt[..., None] * b_ssm[:, :, None, :] * xc.astype(jnp.float32)[..., None]

    def step(h, inp):
        ab, bxt, ct = inp
        h = ab * h + bxt
        return h, jnp.einsum("bdn,bn->bd", h, ct)

    h0 = jnp.zeros((b, cfg.d_inner, ds), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (a_bar.transpose(1, 0, 2, 3),
                                    bx.transpose(1, 0, 2, 3),
                                    c_ssm.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + p["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])
