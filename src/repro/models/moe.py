"""Mixture-of-Experts layer.

Top-k routing with capacity-based, sort-packed dispatch: tokens are argsorted
by expert id and scattered into a fixed (E, C, d) buffer (no (tokens, E, C)
one-hot dispatch tensor, which would dwarf the activations at 128 experts).
FLOPs therefore scale with *activated* experts — exactly what the roofline's
MODEL_FLOPS = 6·N_active·D accounting expects.

Two entry points:
  * ``moe_block``            — single-shard math (also the EP local compute).
  * ``moe_block_ep``         — expert-parallel over a named mesh axis: tokens
                               all-to-all to their experts' shards and back
                               (used by shard_map'd model paths).
  * ``moe_block_dense_ref``  — O(E) dense oracle for tests.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

CAPACITY_FACTOR = 1.25


def router_topk(cfg: ArchConfig, router_w: jax.Array, x: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Softmax router with renormalized top-k weights.

    Returns (weights (B,S,k) fp32, expert ids (B,S,k) int32).
    """
    logits = jnp.einsum("bsd,de->bse", x, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.n_experts_active)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    return top_w, top_i.astype(jnp.int32)


def virtualize_routing(cfg: ArchConfig, top_w, top_i):
    """Map routing over E real experts to E*s virtual ff-slices: each
    chosen expert contributes s copies (same weight) whose partial outputs
    sum back to the full expert output in the weighted combine."""
    s = cfg.moe_expert_shards
    if s == 1:
        return top_w, top_i, cfg.n_experts, cfg.n_experts_active
    import jax.numpy as _jnp
    ids = (top_i[..., None] * s + _jnp.arange(s, dtype=top_i.dtype))
    ids = ids.reshape(*top_i.shape[:-1], -1)
    w = _jnp.repeat(top_w, s, axis=-1)
    return w, ids, cfg.n_experts * s, cfg.n_experts_active * s


def expert_capacity(cfg: ArchConfig, n_tokens: int,
                    capacity_factor: float = CAPACITY_FACTOR) -> int:
    c = int(n_tokens * cfg.n_experts_active * capacity_factor / cfg.n_experts)
    # An expert can receive at most one copy of each token.
    return min(max(c, cfg.n_experts_active), n_tokens)


def _pack_dispatch(e_flat: jax.Array, n_experts: int, capacity: int):
    """Sort-based packing: slot (expert, position) for every token copy.

    Returns (sort_idx, expert_of_sorted, pos_in_expert, keep_mask) where
    ``pos_in_expert`` < capacity for kept copies.
    """
    n = e_flat.shape[0]
    sort_idx = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[sort_idx]
    # start offset of each expert's segment in the sorted order
    starts = jnp.searchsorted(e_sorted, jnp.arange(n_experts), side="left")
    pos = jnp.arange(n) - starts[e_sorted]
    keep = pos < capacity
    # Writes use the raw pos: overflow lands out of bounds and is dropped by
    # scatter mode="drop" (never collides with a valid slot).  Reads clip.
    return sort_idx, e_sorted, pos, keep


def _expert_ffn(cfg: ArchConfig, p: dict, xin: jax.Array) -> jax.Array:
    """xin: (E, C, d) -> (E, C, d). Gated MLP per expert."""
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xin, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_block(cfg: ArchConfig, p: dict, x: jax.Array,
              capacity_factor: float | None = None) -> jax.Array:
    """Single-shard MoE: route, pack, run experts, combine."""
    if capacity_factor is None:
        capacity_factor = CAPACITY_FACTOR
    b, s, d = x.shape
    top_w, top_i = router_topk(cfg, p["router"], x)
    top_w, top_i, e, k = virtualize_routing(cfg, top_w, top_i)

    n = b * s * k
    xk = jnp.repeat(x.reshape(b * s, d), k, axis=0)            # (N, d)
    e_flat = top_i.reshape(-1)                                 # (N,)
    w_flat = top_w.reshape(-1)                                 # (N,)
    cap = expert_capacity(cfg, b * s, capacity_factor)

    sort_idx, e_sorted, pos, keep = _pack_dispatch(e_flat, e, cap)
    x_sorted = xk[sort_idx] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e, cap, d), dtype=x.dtype)
    buf = buf.at[e_sorted, pos].set(x_sorted, mode="drop")

    out_buf = _expert_ffn(cfg, p, buf)                          # (E, C, d)

    y_sorted = out_buf[e_sorted, jnp.clip(pos, 0, cap - 1)] * keep[:, None].astype(x.dtype)
    y_flat = jnp.zeros((n, d), dtype=x.dtype).at[sort_idx].set(y_sorted)
    y = (y_flat.reshape(b * s, k, d)
         * w_flat.reshape(b * s, k, 1).astype(x.dtype)).sum(axis=1)
    return y.reshape(b, s, d)


def moe_block_ep(cfg: ArchConfig, p_local: dict, x_local: jax.Array,
                 axis_name: str,
                 capacity_factor: float | None = None) -> jax.Array:
    """Expert-parallel MoE inside ``shard_map``: experts sharded over
    ``axis_name``; tokens travel by all-to-all.

    ``p_local`` holds this shard's experts: leaves (E_loc, ...), plus the
    full router.  ``x_local``: this shard's tokens (b_loc, S, d).
    """
    if capacity_factor is None:
        capacity_factor = CAPACITY_FACTOR
    # jax.lax.axis_size is missing on older releases; psum(1) is the
    # version-stable way to read the mapped axis size.
    axis_size = getattr(jax.lax, "axis_size", None)
    n_shards = (int(axis_size(axis_name)) if axis_size is not None
                else int(jax.lax.psum(1, axis_name)))
    b, s, d = x_local.shape
    top_w, top_i = router_topk(cfg, p_local["router"], x_local)
    top_w, top_i, e, k = virtualize_routing(cfg, top_w, top_i)
    e_loc = e // n_shards

    n = b * s * k
    xk = jnp.repeat(x_local.reshape(b * s, d), k, axis=0)
    e_flat = top_i.reshape(-1)
    w_flat = top_w.reshape(-1)
    # Per-source-shard capacity for each *global* expert.
    cap = max(expert_capacity(cfg, b * s, capacity_factor) // 1, 1)
    cap_src = max(cap, 1)

    sort_idx, e_sorted, pos, keep = _pack_dispatch(e_flat, e, cap_src)
    x_sorted = xk[sort_idx] * keep[:, None].astype(x_local.dtype)
    send = jnp.zeros((e, cap_src, d), dtype=x_local.dtype)
    send = send.at[e_sorted, pos].set(x_sorted, mode="drop")

    # (E, C, d) -> all-to-all over the expert axis (tiled: split axis 0 into
    # n pieces, concatenate received pieces along axis 1): every shard ends
    # up with its E_loc experts' slices from all sources, source-major.
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=1,
                              tiled=True)              # (E_loc, C*n, d)

    out_loc = _expert_ffn(cfg, {k_: p_local[k_] for k_ in
                                ("w_gate", "w_up", "w_down")}, recv)

    # exact inverse pair: split the source-major slots, concat experts back.
    back = jax.lax.all_to_all(out_loc, axis_name, split_axis=1, concat_axis=0,
                              tiled=True)              # (E, C, d)

    y_sorted = back[e_sorted, jnp.clip(pos, 0, cap_src - 1)] * keep[:, None].astype(x_local.dtype)
    y_flat = jnp.zeros((n, d), dtype=x_local.dtype).at[sort_idx].set(y_sorted)
    y = (y_flat.reshape(b * s, k, d)
         * w_flat.reshape(b * s, k, 1).astype(x_local.dtype)).sum(axis=1)
    return y.reshape(b, s, d)


def moe_block_dense_ref(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """O(E) oracle: run every expert on every token, weight by router."""
    top_w, top_i = router_topk(cfg, p["router"], x)
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("bsd,edf->bsef", x, p["w_gate"])) * jnp.einsum(
        "bsd,edf->bsef", x, p["w_up"])
    y_all = jnp.einsum("bsef,efd->bsed", h, p["w_down"])       # (B,S,E,d)
    w_full = jnp.zeros(x.shape[:2] + (cfg.n_experts,), jnp.float32)
    b_idx = jnp.arange(x.shape[0])[:, None, None]
    s_idx = jnp.arange(x.shape[1])[None, :, None]
    w_full = w_full.at[b_idx, s_idx, top_i].add(top_w)
    return jnp.einsum("bsed,bse->bsd", y_all, w_full.astype(x.dtype))


def aux_load_balance_loss(cfg: ArchConfig, router_w: jax.Array,
                          x: jax.Array) -> jax.Array:
    """Switch-style load-balance auxiliary loss (f · P)."""
    logits = jnp.einsum("bsd,de->bse", x, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, top_i = jax.lax.top_k(probs, cfg.n_experts_active)
    onehot = jax.nn.one_hot(top_i, cfg.n_experts).sum(axis=2)  # (B,S,E)
    f = onehot.mean(axis=(0, 1))
    p_mean = probs.mean(axis=(0, 1))
    return cfg.n_experts * jnp.sum(f * p_mean)
