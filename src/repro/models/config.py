"""Architecture configuration.

One ``ArchConfig`` describes any model in the zoo (dense / MoE / hybrid /
SSM / audio / VLM).  Layers are organized into a repeating *period* — a short
list of ``LayerDesc`` — so heterogeneous stacks (Jamba's 1:7 Mamba:attention
interleave, Gemma-2's local/global alternation, xLSTM's mLSTM/sLSTM mix) scan
over periods with per-position parameter stacks, keeping the lowered HLO
small and compile times flat in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Mixer kinds.
ATTN = "attn"            # full causal attention
ATTN_LOCAL = "attn_local"  # sliding-window attention
MAMBA = "mamba"
MLSTM = "mlstm"
SLSTM = "slstm"

# FFN kinds.
MLP = "mlp"
MOE = "moe"
NONE = "none"


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    """One layer position inside the repeating period."""

    mixer: str = ATTN
    ffn: str = MLP


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    period: Tuple[LayerDesc, ...] = (LayerDesc(),)

    # Attention options.
    use_rope: bool = True          # jamba: no positional encoding
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0     # chatglm3: rotary on half the head dims
    attn_softcap: float = 0.0      # gemma2
    logit_softcap: float = 0.0     # gemma2 (final logits)
    sliding_window: int = 0        # window for ATTN_LOCAL mixers
    qkv_bias: bool = False         # qwen1.5 family
    qk_norm: bool = False          # qwen3 family

    # FFN options.
    mlp_act: str = "silu"          # silu | gelu
    mlp_gated: bool = True         # False: plain 2-matrix MLP (starcoder2)
    norm: str = "rmsnorm"          # rmsnorm | layernorm

    # MoE options.
    n_experts: int = 0
    n_experts_active: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim (if != d_ff)
    # Store each expert as `s` ff-slices ("virtual experts", E*s total) so
    # small expert counts still shard over a 16-way mesh axis; the slices'
    # partial outputs recombine in the weighted token-return sum.
    moe_expert_shards: int = 1

    # SSM (Mamba) options.
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2

    scale_embed: bool = False      # gemma2: embeddings scaled by sqrt(d)

    # Modality frontend: audio/vlm backbones consume precomputed embeddings.
    frontend: str = "none"         # none | audio_stub | vision_stub
    num_patches: int = 0           # vlm: visual tokens prepended to text

    # Serving options.
    long_context_mode: str = "full"   # "sliding_window": serve-time SWA for
    long_context_window: int = 8192   # long_500k on full-attention archs
    tie_embeddings: bool = False

    # Citation for the public-pool assignment.
    source: str = ""

    def __post_init__(self):
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"period length {len(self.period)}")
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def uses_attention(self) -> bool:
        return any(d.mixer in (ATTN, ATTN_LOCAL) for d in self.period)

    @property
    def attn_layers_per_period(self) -> int:
        return sum(d.mixer in (ATTN, ATTN_LOCAL) for d in self.period)

    @property
    def is_subquadratic(self) -> bool:
        """True if no mixer does *unbounded* full attention (SSM/SWA only)."""
        return all(d.mixer != ATTN for d in self.period)

    def param_count(self) -> int:
        """Exact parameter count from the layer layout (used by the cost
        model, the memory checks, and the roofline MODEL_FLOPS term)."""
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        per_pos = {}
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += d  # final norm
        for desc in self.period:
            n = total * 0  # per-layer params
            if desc.mixer in (ATTN, ATTN_LOCAL):
                n += d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
                if self.qkv_bias:
                    n += h * dh + 2 * kv * dh
                if self.qk_norm:
                    n += 2 * dh
            elif desc.mixer == MAMBA:
                di, ds, k = self.d_inner, self.ssm_state_dim, self.ssm_conv_width
                n += d * 2 * di          # in_proj (x, z)
                n += di * k + di         # conv + bias
                n += di * (2 * ds + 1)   # B, C, dt projections (x_proj)
                n += di + di * ds        # dt_bias(+proj), A_log
                n += di                  # D
                n += di * d              # out_proj
            elif desc.mixer == MLSTM:
                di = 2 * d
                n += d * 2 * di          # up proj (x, z)
                n += 3 * di * di // max(self.n_heads, 1) * 0 + 3 * di * di  # q,k,v
                n += 2 * di              # i,f gate projections (per-dim)
                n += di * d              # down proj
            elif desc.mixer == SLSTM:
                n += 4 * d * d * 2       # i,f,z,o projections + recurrent
                n += 4 * d
                n += d * (d * 4 // 3) * 2  # gated FFN ~4/3
            n += d  # mixer norm
            if desc.ffn == MLP:
                n += (3 if self.mlp_gated else 2) * d * self.d_ff + d
            elif desc.ffn == MOE:
                ff = self.moe_d_ff or self.d_ff
                n += self.n_experts * 3 * d * ff + d * self.n_experts + d
            per_pos[desc] = n
            total += n * self.n_periods
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.n_experts == 0:
            return self.param_count()
        ff = self.moe_d_ff or self.d_ff
        moe_layers = self.n_periods * sum(d.ffn == MOE for d in self.period)
        inactive = (self.n_experts - self.n_experts_active) * 3 * self.d_model * ff
        return int(self.param_count() - moe_layers * inactive)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: ≤2 periods, d_model ≤ 512, ≤4 experts."""
        d = min(self.d_model, 256)
        dh = 64
        heads = max(2, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        n_layers = len(self.period)  # one period (jamba: 8 reduced layers)
        if n_layers < 2:
            n_layers = 2
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=dh,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            n_experts_active=min(self.n_experts_active, 2) if self.n_experts else 0,
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            long_context_window=256,
            num_patches=min(self.num_patches, 16) if self.num_patches else 0,
        )
