"""Shared neural-net layers: norms, RoPE, GQA attention (all paper-pool
variants), and gated MLPs.  Pure-functional JAX over parameter pytrees.

Compute is bf16 with fp32 softmax/normalization statistics.  Attention here
is the XLA path used by training, the dry-run, and CPU validation; the Pallas
flash kernels in ``repro.kernels`` implement the same math for TPU and are
validated against these references.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

NEG_INF = -1e30


# ----------------------------------------------------------------- norms

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ------------------------------------------------------------------ RoPE

def rope_freqs(head_dim: int, theta: float, fraction: float) -> jax.Array:
    rot = int(head_dim * fraction) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S).  ``fraction < 1`` rotates only
    the leading dims (ChatGLM-style partial / 2d RoPE)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta, fraction)
    rot = freqs.shape[0] * 2
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y, x_pass], axis=-1).astype(x.dtype)


# ------------------------------------------------------------- attention

class AttnParams(NamedTuple):
    wq: jax.Array  # (d_model, n_heads, head_dim)
    wk: jax.Array  # (d_model, n_kv, head_dim)
    wv: jax.Array  # (d_model, n_kv, head_dim)
    wo: jax.Array  # (n_heads, head_dim, d_model)
    bq: Optional[jax.Array] = None
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None
    q_norm: Optional[jax.Array] = None
    k_norm: Optional[jax.Array] = None


def _soft_cap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


def attention_scores(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, softcap: float = 0.0) -> jax.Array:
    """q: (B,S,H,Dh); k,v: (B,T,KV,Dh); mask: (B,S,T) or (S,T) bool.

    GQA: query heads grouped over KV heads via reshape.
    """
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    q = q.reshape(b, s, kv, g, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    logits *= dh ** -0.5
    logits = _soft_cap(logits, softcap)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, dh)


# Above this sequence length, full-sequence attention switches to a scan
# over query chunks so the (S, T) score matrix never materializes whole —
# the XLA analogue of the Pallas flash kernel's blocking (the kernel itself
# is the TPU fast path; this bounds memory for lowering/training/CPU).
CHUNKED_ATTN_THRESHOLD = 2048
ATTN_Q_CHUNK = 1024


def _attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                       offset_mask_fn, softcap: float) -> jax.Array:
    """Scan over query chunks; each chunk does full-row softmax.

    q: (B,S,H,Dh); k,v: (B,T,KV,Dh).  ``offset_mask_fn(q_start, s_chunk)``
    returns the (s_chunk, T) bool mask for that chunk.
    """
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    c = min(ATTN_Q_CHUNK, s)
    pad = (-s) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = q.shape[1] // c
    qc = q.reshape(b, nc, c, kv, g, dh).transpose(1, 0, 2, 3, 4, 5)

    def body(_, inp):
        qi, idx = inp                                  # (B,c,KV,G,Dh), scalar
        logits = jnp.einsum("bskgd,btkd->bkgst", qi, k).astype(jnp.float32)
        logits *= dh ** -0.5
        logits = _soft_cap(logits, softcap)
        mask = offset_mask_fn(idx * c, c)              # (c, T)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
        return None, out

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nc * c, h, dh)
    return out[:, :s]


def causal_mask(s: int, t: int, offset: int = 0, window: int = 0) -> jax.Array:
    """(s, t) bool mask; query i sits at absolute position offset+i, keys at
    0..t-1.  ``window > 0`` additionally bounds the lookback (SWA)."""
    qpos = offset + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def attention_block(cfg: ArchConfig, p: dict, x: jax.Array,
                    positions: jax.Array, *, window: int = 0,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    mask: Optional[jax.Array] = None) -> jax.Array:
    """Full attention sublayer (projections + RoPE + scores + output).

    ``kv_override``: decode path passes the (gathered) cache instead of the
    keys/values computed from x.
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    if kv_override is not None:
        k, v = kv_override
    if mask is None and s >= CHUNKED_ATTN_THRESHOLD:
        out = _attention_chunked(
            q, k, v,
            lambda off, sc: causal_mask(sc, k.shape[1], offset=off,
                                        window=window),
            cfg.attn_softcap)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if mask is None:
        mask = causal_mask(s, k.shape[1], window=window)
    out = attention_scores(q, k, v, mask, cfg.attn_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def project_qkv(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array):
    """Decode-path helper: q/k/v for the new token(s), RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def attention_output(p: dict, out: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ------------------------------------------------------------------- MLP

def mlp_block(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """Gated MLP (SwiGLU / GeGLU) or plain 2-matrix MLP."""
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    if cfg.mlp_gated:
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum(
            "bsd,df->bsf", x, p["w_up"])
    else:
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
