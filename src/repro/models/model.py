"""Model facade: wires ArchConfig + params into train / prefill / decode
callables, including the modality-stub input handling for audio/VLM archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ArchConfig

PyTree = Any
AUX_LOSS_WEIGHT = 0.01


def make_batch_spec(cfg: ArchConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    """Shapes of one training batch (tokens + labels [+ prefix embeds])."""
    n_prefix = cfg.num_patches if cfg.frontend != "none" else 0
    spec = {
        "tokens": ((batch, seq_len - n_prefix), jnp.int32),
        "labels": ((batch, seq_len - n_prefix), jnp.int32),
    }
    if n_prefix:
        spec["prefix_embeds"] = ((batch, n_prefix, cfg.d_model), jnp.bfloat16)
    return spec


def synthetic_batch(cfg: ArchConfig, batch: int, seq_len: int,
                    key: jax.Array) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    spec = make_batch_spec(cfg, batch, seq_len)
    out = {
        "tokens": jax.random.randint(k1, spec["tokens"][0], 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, spec["labels"][0], 0, cfg.vocab_size),
    }
    if "prefix_embeds" in spec:
        out["prefix_embeds"] = (jax.random.normal(k3, spec["prefix_embeds"][0])
                                * 0.02).astype(jnp.bfloat16)
    return out


def loss_fn(cfg: ArchConfig, params: PyTree, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    prefix = batch.get("prefix_embeds")
    logits, aux = T.forward(cfg, params, batch["tokens"], prefix_embeds=prefix)
    n_prefix = 0 if prefix is None else prefix.shape[1]
    logits = logits[:, n_prefix:]                      # text positions only
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(nll)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def init_params(cfg: ArchConfig, key: jax.Array) -> PyTree:
    return T.init_params(cfg, key)


def prefill(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None, *, t_max: int,
            long_mode: bool = False):
    return T.prefill(cfg, params, tokens, prefix_embeds, t_max=t_max,
                     long_mode=long_mode)


def decode_step(cfg: ArchConfig, params: PyTree, caches: PyTree,
                token: jax.Array, pos: jax.Array, long_mode: bool = False):
    return T.decode_step(cfg, params, caches, token, pos, long_mode=long_mode)


def decode_steps(cfg: ArchConfig, params: PyTree, caches: PyTree,
                 token: jax.Array, pos: jax.Array, *, k: int,
                 long_mode: bool = False):
    """``k`` greedy steps fused into one jit (scan carry over caches);
    returns (tokens (B, k), caches).  Fused ≡ stepwise token-for-token."""
    return T.decode_steps(cfg, params, caches, token, pos, k=k,
                          long_mode=long_mode)


def init_cache(cfg: ArchConfig, batch: int, t_max: int,
               long_mode: bool = False) -> PyTree:
    return T.init_cache(cfg, batch, t_max, long_mode)


def paged_supported(cfg: ArchConfig) -> bool:
    return T.paged_supported(cfg)


def prefill_suffix(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
                   pools, prefix_tables: jax.Array, t_prefix: jax.Array,
                   last: jax.Array):
    """Suffix-only prefill against cached paged prefix blocks (the warm
    path of cross-request prefix caching); returns (last-real-position
    logits, suffix caches)."""
    return T.prefill_suffix(cfg, params, tokens, pools, prefix_tables,
                            t_prefix, last)


def paged_decode_step(cfg: ArchConfig, params: PyTree, pools,
                      block_tables: jax.Array, lengths: jax.Array,
                      token: jax.Array):
    return T.paged_decode_step(cfg, params, pools, block_tables, lengths,
                               token)


def paged_decode_steps(cfg: ArchConfig, params: PyTree, pools,
                       block_tables: jax.Array, lengths: jax.Array,
                       token: jax.Array, *, k: int):
    """``k`` fused lockstep steps over a paged replica (no slot may cross a
    block boundary within the chunk); returns (tokens (S, k), pools)."""
    return T.paged_decode_steps(cfg, params, pools, block_tables, lengths,
                                token, k=k)


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def param_count(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
