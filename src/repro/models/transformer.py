"""Composable decoder stack.

The model is a scan over *periods* (repeating groups of layers, see
``ArchConfig.period``); every layer position in the period has its own
parameter/cache subtree whose leaves carry a leading ``n_periods`` dim.  This
keeps the lowered HLO size O(period) instead of O(depth) — a 94-layer MoE
compiles as fast as a 2-layer one.

Three entry points (all pure functions over the params pytree):
  * ``forward``      — full-sequence logits (training / scoring).
  * ``prefill``      — full sequence + returns decode caches.
  * ``decode_step``  — one token against the caches.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import runtime_flags as RF
from repro.models import xlstm as X
from repro.models.config import (ATTN, ATTN_LOCAL, MAMBA, MLP, MLSTM, MOE as
                                 FFN_MOE, NONE, SLSTM, ArchConfig, LayerDesc)

PyTree = Any

# Dry-run calibration: when True, the period scan is unrolled into a Python
# loop so XLA's cost_analysis counts every layer (scan/while bodies are
# otherwise counted once, not x trip-count).  Compile time grows ~n_periods.
UNROLL_PERIODS = False


def _maybe_scan(body, carry, xs):
    if not UNROLL_PERIODS:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda leaf: leaf[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    else:
        ys = None
    return carry, ys


def _constrain_acts(x: jax.Array) -> jax.Array:
    """Megatron-SP activation constraint (ACT_SEQ_SHARD): at layer
    boundaries the (B, S, D) stream shards S over the TP axis, so GSPMD
    lowers each TP all-reduce into reduce-scatter + all-gather (half the
    wire bytes) and the residual stream lives sharded."""
    f = RF.FLAGS
    if not f.act_seq_shard or f.mesh is None or x.ndim != 3:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = P(f.dp_axes, f.tp_axis, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(f.mesh, spec))


def _kv_quantize(k: jax.Array):
    """int8-quantize (B,S,KV,Dh) with per-(slot,head) absmax scales."""
    scale = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6) / 127.0
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale[..., 0].astype(jnp.bfloat16)


def _kv_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.bfloat16) * scale[..., None]).astype(jnp.bfloat16)


def _pallas_full_attention(cfg: ArchConfig, q, k, v, window: int):
    """(B,S,H,Dh) x (B,S,KV,Dh) -> (B,S,H,Dh) via the flash kernel."""
    from repro.kernels.flash_attention.ops import flash_attention_op
    out = flash_attention_op(q.transpose(0, 2, 1, 3),
                             k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3),
                             causal=True, window=window,
                             softcap=cfg.attn_softcap)
    return out.transpose(0, 2, 1, 3)


def _pallas_decode_attention(cfg: ArchConfig, q, ck, cv, pos):
    """(B,1,H,Dh) x (B,T,KV,Dh) cache -> (B,1,H,Dh) via flash-decode."""
    from repro.kernels.decode_attention.ops import decode_attention_op
    t = ck.shape[1]
    lengths = jnp.broadcast_to(jnp.minimum(pos + 1, t), (q.shape[0],))
    out = decode_attention_op(q[:, 0], ck, cv, lengths,
                              softcap=cfg.attn_softcap)
    return out[:, None]


def _moe_apply(cfg: ArchConfig, p: dict, h: jax.Array) -> jax.Array:
    """MoE dispatch: baseline global sort-pack, or (MOE_EP_SHARD_MAP)
    shard_map expert parallelism with explicit all-to-all."""
    f = RF.FLAGS
    ep_axis = "data"
    n_virtual = cfg.n_experts * cfg.moe_expert_shards
    if (f.moe_ep_shard_map and f.mesh is not None
            and ep_axis in getattr(f.mesh, "shape", {})
            and n_virtual % f.mesh.shape[ep_axis] == 0
            and h.shape[0] % f.mesh.shape[ep_axis] == 0):
        from jax.sharding import PartitionSpec as P
        p_specs = {
            "router": P(),
            "w_gate": P(ep_axis, None, None),
            "w_up": P(ep_axis, None, None),
            "w_down": P(ep_axis, None, None),
        }
        fn = lambda pl, xl: MOE.moe_block_ep(cfg, pl, xl, ep_axis)
        # jax.shard_map (with check_vma/axis_names) only exists on newer
        # releases; older pins ship jax.experimental.shard_map, whose
        # replication check is spelled check_rep and rejects the new
        # kwargs, so each API gets exactly its own argument set.
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is not None:
            return shard_map(fn, mesh=f.mesh,
                             in_specs=(p_specs, P(ep_axis, None, None)),
                             out_specs=P(ep_axis, None, None),
                             check_vma=False,
                             axis_names=frozenset({ep_axis}))(p, h)
        from jax.experimental.shard_map import shard_map
        return shard_map(fn, mesh=f.mesh,
                         in_specs=(p_specs, P(ep_axis, None, None)),
                         out_specs=P(ep_axis, None, None),
                         check_rep=False)(p, h)
    return MOE.moe_block(cfg, p, h)


# ------------------------------------------------------------------- init

def _norm_init(cfg: ArchConfig, d: int, np_: int) -> dict:
    p = {"scale": jnp.zeros((np_, d), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((np_, d), jnp.float32)
    return p


def _dense(key, shape, scale_axis=0) -> jax.Array:
    fan_in = shape[scale_axis]
    return (jax.random.normal(key, shape, jnp.float32)
            * (fan_in ** -0.5)).astype(jnp.bfloat16)


def _init_mixer(cfg: ArchConfig, desc: LayerDesc, key, np_: int) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 16)
    if desc.mixer in (ATTN, ATTN_LOCAL):
        p = {
            "wq": _dense(ks[0], (np_, d, h, dh), 1),
            "wk": _dense(ks[1], (np_, d, kv, dh), 1),
            "wv": _dense(ks[2], (np_, d, kv, dh), 1),
            "wo": _dense(ks[3], (np_, h, dh, d), 2) / (2 * cfg.n_layers) ** 0.5,
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((np_, h, dh), jnp.bfloat16)
            p["bk"] = jnp.zeros((np_, kv, dh), jnp.bfloat16)
            p["bv"] = jnp.zeros((np_, kv, dh), jnp.bfloat16)
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros((np_, dh), jnp.float32)
            p["k_norm"] = jnp.zeros((np_, dh), jnp.float32)
        return p
    if desc.mixer == MAMBA:
        di, ds, k = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_conv_width
        dt_rank = max(d // 16, 1)
        a_init = jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (np_, di, 1)))
        return {
            "in_proj": _dense(ks[0], (np_, d, 2 * di), 1),
            "conv_w": (jax.random.normal(ks[1], (np_, k, di)) * 0.1
                       ).astype(jnp.bfloat16),
            "conv_b": jnp.zeros((np_, di), jnp.bfloat16),
            "x_proj": _dense(ks[2], (np_, di, dt_rank + 2 * ds), 1),
            "dt_proj": _dense(ks[3], (np_, dt_rank, di), 1).astype(jnp.float32),
            "dt_bias": jnp.full((np_, di), -4.6, jnp.float32),  # softplus ≈ 0.01
            "a_log": a_init,
            "d_skip": jnp.ones((np_, di), jnp.float32),
            "out_proj": _dense(ks[4], (np_, di, d), 1),
        }
    if desc.mixer == MLSTM:
        di = 2 * d
        nh = cfg.n_heads
        return {
            "up_proj": _dense(ks[0], (np_, d, 2 * di), 1),
            "wq": _dense(ks[1], (np_, di, nh, di // nh), 1),
            "wk": _dense(ks[2], (np_, di, nh, di // nh), 1),
            "wv": _dense(ks[3], (np_, di, nh, di // nh), 1),
            "wi": _dense(ks[4], (np_, di, nh), 1).astype(jnp.float32),
            "bi": jnp.zeros((np_, nh), jnp.float32),
            "wf": _dense(ks[5], (np_, di, nh), 1).astype(jnp.float32),
            "bf": jnp.full((np_, nh), 3.0, jnp.float32),  # open forget gates
            "hnorm": jnp.zeros((np_, di), jnp.bfloat16),
            "down_proj": _dense(ks[6], (np_, di, d), 1) / (2 * cfg.n_layers) ** 0.5,
        }
    if desc.mixer == SLSTM:
        nh = cfg.n_heads
        dh = d // nh
        ff = max(4 * d // 3, 8)
        return {
            "w": _dense(ks[0], (np_, d, 4, nh, dh), 1).astype(jnp.float32),
            "r": (jax.random.normal(ks[1], (np_, 4, nh, dh, dh))
                  * (dh ** -0.5) * 0.3).astype(jnp.float32),
            "b": jnp.concatenate([
                jnp.zeros((np_, 1, nh, dh)), jnp.full((np_, 1, nh, dh), 3.0),
                jnp.zeros((np_, 2, nh, dh))], axis=1).astype(jnp.float32),
            "hnorm": jnp.zeros((np_, d), jnp.bfloat16),
            "ffn_gate": _dense(ks[2], (np_, d, ff), 1),
            "ffn_up": _dense(ks[3], (np_, d, ff), 1),
            "ffn_down": _dense(ks[4], (np_, ff, d), 1) / (2 * cfg.n_layers) ** 0.5,
        }
    raise ValueError(desc.mixer)


def _init_ffn(cfg: ArchConfig, desc: LayerDesc, key, np_: int) -> Optional[dict]:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if desc.ffn == MLP:
        p = {
            "w_up": _dense(ks[1], (np_, d, cfg.d_ff), 1),
            "w_down": _dense(ks[2], (np_, cfg.d_ff, d), 1) / (2 * cfg.n_layers) ** 0.5,
        }
        if cfg.mlp_gated:
            p["w_gate"] = _dense(ks[0], (np_, d, cfg.d_ff), 1)
        return p
    if desc.ffn == FFN_MOE:
        ff = cfg.moe_d_ff or cfg.d_ff
        e = cfg.n_experts
        s = cfg.moe_expert_shards
        ev, ffv = e * s, ff // s
        return {
            "router": _dense(ks[3], (np_, d, e), 1).astype(jnp.float32),
            # virtual layout: expert e's ff-slice j lives at index e*s+j
            "w_gate": _dense(ks[0], (np_, ev, d, ffv), 2),
            "w_up": _dense(ks[1], (np_, ev, d, ffv), 2),
            "w_down": _dense(ks[2], (np_, ev, ffv, d), 2) / (2 * cfg.n_layers) ** 0.5,
        }
    return None


def init_params(cfg: ArchConfig, key: jax.Array) -> PyTree:
    np_ = cfg.n_periods
    keys = jax.random.split(key, len(cfg.period) + 3)
    positions = []
    for i, desc in enumerate(cfg.period):
        kk = jax.random.split(keys[i], 3)
        sub = {"pre_norm": _norm_init(cfg, cfg.d_model, np_),
               "mixer": _init_mixer(cfg, desc, kk[0], np_)}
        if desc.ffn != NONE:
            sub["ffn_norm"] = _norm_init(cfg, cfg.d_model, np_)
            sub["ffn"] = _init_ffn(cfg, desc, kk[1], np_)
        positions.append(sub)
    params = {
        "embed": (jax.random.normal(keys[-3], (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(jnp.bfloat16),
        "layers": positions,
        "final_norm": {k: v[0] for k, v in _norm_init(cfg, cfg.d_model, 1).items()},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(keys[-2], (cfg.d_model, cfg.vocab_size), 0)
    return params


# ------------------------------------------------------------- cache init

def init_cache(cfg: ArchConfig, batch: int, t_max: int,
               long_mode: bool = False) -> PyTree:
    """Decode caches for every layer position (leaves lead with n_periods)."""
    np_ = cfg.n_periods
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    caches = []
    for desc in cfg.period:
        if desc.mixer in (ATTN, ATTN_LOCAL):
            t = _cache_len(cfg, desc, t_max, long_mode)
            if RF.FLAGS.kv_cache_int8:
                caches.append({
                    "k": jnp.zeros((np_, batch, t, kv, dh), jnp.int8),
                    "v": jnp.zeros((np_, batch, t, kv, dh), jnp.int8),
                    "k_scale": jnp.zeros((np_, batch, t, kv), jnp.bfloat16),
                    "v_scale": jnp.zeros((np_, batch, t, kv), jnp.bfloat16),
                })
            else:
                caches.append({
                    "k": jnp.zeros((np_, batch, t, kv, dh), jnp.bfloat16),
                    "v": jnp.zeros((np_, batch, t, kv, dh), jnp.bfloat16),
                })
        elif desc.mixer == MAMBA:
            caches.append({
                "conv": jnp.zeros((np_, batch, cfg.ssm_conv_width - 1,
                                   cfg.d_inner), jnp.bfloat16),
                "h": jnp.zeros((np_, batch, cfg.d_inner, cfg.ssm_state_dim),
                               jnp.float32),
            })
        elif desc.mixer == MLSTM:
            di = 2 * cfg.d_model
            nh = cfg.n_heads
            caches.append({
                "c": jnp.zeros((np_, batch, nh, di // nh, di // nh), jnp.float32),
                "n": jnp.zeros((np_, batch, nh, di // nh), jnp.float32),
                "m": jnp.full((np_, batch, nh), -1e30, jnp.float32),
            })
        elif desc.mixer == SLSTM:
            nh = cfg.n_heads
            dh_s = cfg.d_model // nh
            caches.append({
                "c": jnp.zeros((np_, batch, nh, dh_s), jnp.float32),
                "n": jnp.ones((np_, batch, nh, dh_s), jnp.float32),
                "h": jnp.zeros((np_, batch, nh, dh_s), jnp.float32),
                "m": jnp.zeros((np_, batch, nh, dh_s), jnp.float32),
            })
        else:
            raise ValueError(desc.mixer)
    return caches


def _cache_len(cfg: ArchConfig, desc: LayerDesc, t_max: int,
               long_mode: bool) -> int:
    if desc.mixer == ATTN_LOCAL and cfg.sliding_window:
        return min(t_max, cfg.sliding_window)
    if desc.mixer == ATTN and long_mode and cfg.long_context_mode == "sliding_window":
        return min(t_max, cfg.long_context_window)
    return t_max


def _effective_window(cfg: ArchConfig, desc: LayerDesc, long_mode: bool) -> int:
    if desc.mixer == ATTN_LOCAL:
        return cfg.sliding_window
    if desc.mixer == ATTN and long_mode and cfg.long_context_mode == "sliding_window":
        return cfg.long_context_window
    return 0


# ----------------------------------------------------------- forward pass

def _embed_inputs(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
                  prefix_embeds: Optional[jax.Array]) -> jax.Array:
    x = params["embed"][tokens].astype(jnp.bfloat16)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def _logits(cfg: ArchConfig, params: PyTree, x: jax.Array) -> jax.Array:
    x = L.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def _full_layer(cfg: ArchConfig, desc: LayerDesc, p: dict, x: jax.Array,
                positions: jax.Array, long_mode: bool,
                aux: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One layer, full-sequence (no cache)."""
    h = L.apply_norm(cfg, p["pre_norm"], x)
    if desc.mixer in (ATTN, ATTN_LOCAL):
        w = _effective_window(cfg, desc, long_mode)
        y = L.attention_block(cfg, p["mixer"], h, positions, window=w)
    elif desc.mixer == MAMBA:
        y, _ = M.mamba_prefill(cfg, p["mixer"], h)
    elif desc.mixer == MLSTM:
        y, _ = X.mlstm_block(cfg, p["mixer"], h)
    elif desc.mixer == SLSTM:
        y, _ = X.slstm_block(cfg, p["mixer"], h)
    else:
        raise ValueError(desc.mixer)
    x = x + y
    if desc.ffn != NONE:
        h = L.apply_norm(cfg, p["ffn_norm"], x)
        if desc.ffn == MLP:
            y = L.mlp_block(cfg, p["ffn"], h)
        else:
            y = _moe_apply(cfg, p["ffn"], h)
            aux = aux + MOE.aux_load_balance_loss(cfg, p["ffn"]["router"], h)
        x = x + y
    return _constrain_acts(x), aux


def forward(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            long_mode: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence logits.  Returns (logits, moe_aux_loss)."""
    x = _embed_inputs(cfg, params, tokens, prefix_embeds)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(carry, period_params):
        x, aux = carry
        for i, desc in enumerate(cfg.period):
            x, aux = _full_layer(cfg, desc, period_params[i], x, positions,
                                 long_mode, aux)
        return (x, aux), None

    body = jax.checkpoint(body)
    (x, aux), _ = _maybe_scan(body, (x, jnp.zeros((), jnp.float32)),
                              params["layers"])
    return _logits(cfg, params, x), aux


# ------------------------------------------------------------- prefill

def _prefill_layer(cfg, desc, p, x, positions, long_mode, t_max):
    """One layer full-sequence, also building its decode cache."""
    h = L.apply_norm(cfg, p["pre_norm"], x)
    if desc.mixer in (ATTN, ATTN_LOCAL):
        w = _effective_window(cfg, desc, long_mode)
        q, k, v = L.project_qkv(cfg, p["mixer"], h, positions)
        s = x.shape[1]
        if RF.FLAGS.use_pallas_attention:
            out = _pallas_full_attention(cfg, q, k, v, w)
        elif s >= L.CHUNKED_ATTN_THRESHOLD:
            out = L._attention_chunked(
                q, k, v,
                lambda off, sc: L.causal_mask(sc, s, offset=off, window=w),
                cfg.attn_softcap)
        else:
            mask = L.causal_mask(s, s, window=w)
            out = L.attention_scores(q, k, v, mask, cfg.attn_softcap)
        y = L.attention_output(p["mixer"], out)
        t = _cache_len(cfg, desc, t_max, long_mode)
        if t >= s:
            k_keep, v_keep = k, v
        else:
            # ring layout: slot j holds the latest position ≡ j (mod t)
            slots = jnp.arange(t)
            last = s - 1 - ((s - 1 - slots) % t)
            k_keep, v_keep = k[:, last], v[:, last]
        if RF.FLAGS.kv_cache_int8:
            kq, ks = _kv_quantize(k_keep)
            vq, vs = _kv_quantize(v_keep)
            if t > k_keep.shape[1]:
                pad = ((0, 0), (0, t - k_keep.shape[1]), (0, 0), (0, 0))
                kq = jnp.pad(kq, pad)
                vq = jnp.pad(vq, pad)
                ks = jnp.pad(ks, pad[:-1])
                vs = jnp.pad(vs, pad[:-1])
            cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        else:
            ck = jnp.zeros((x.shape[0], t, cfg.n_kv_heads, cfg.head_dim),
                           jnp.bfloat16)
            cv = jnp.zeros_like(ck)
            ck = jax.lax.dynamic_update_slice(
                ck, k_keep.astype(jnp.bfloat16), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cv, v_keep.astype(jnp.bfloat16), (0, 0, 0, 0))
            cache = {"k": ck, "v": cv}
    elif desc.mixer == MAMBA:
        y, cache = M.mamba_prefill(cfg, p["mixer"], h)
    elif desc.mixer == MLSTM:
        y, cache = X.mlstm_block(cfg, p["mixer"], h)
    elif desc.mixer == SLSTM:
        y, cache = X.slstm_block(cfg, p["mixer"], h)
    else:
        raise ValueError(desc.mixer)
    x = x + y
    if desc.ffn != NONE:
        h = L.apply_norm(cfg, p["ffn_norm"], x)
        y = L.mlp_block(cfg, p["ffn"], h) if desc.ffn == MLP else \
            _moe_apply(cfg, p["ffn"], h)
        x = x + y
    return _constrain_acts(x), cache


def prefill(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None, *, t_max: int,
            long_mode: bool = False) -> Tuple[jax.Array, PyTree]:
    """Process the prompt; return (last-position logits, caches)."""
    x = _embed_inputs(cfg, params, tokens, prefix_embeds)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(x, period_params):
        caches = []
        for i, desc in enumerate(cfg.period):
            x, cache = _prefill_layer(cfg, desc, period_params[i], x,
                                      positions, long_mode, t_max)
            caches.append(cache)
        return x, caches

    x, caches = _maybe_scan(body, x, params["layers"])
    logits = _logits(cfg, params, x[:, -1:])
    return logits, caches


# ---------------------------------------------------------- decode step

def _decode_layer(cfg, desc, p, cache, x, pos, long_mode):
    h = L.apply_norm(cfg, p["pre_norm"], x)
    if desc.mixer in (ATTN, ATTN_LOCAL):
        positions = jnp.broadcast_to(pos, x.shape[:2])
        q, k, v = L.project_qkv(cfg, p["mixer"], h, positions)
        t = cache["k"].shape[1]
        slot = jnp.where(t > 0, pos % t, 0)
        if RF.FLAGS.kv_cache_int8:
            kq, ks = _kv_quantize(k)
            vq, vs = _kv_quantize(v)
            ckq = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
            cvq = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
            cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                               (0, slot, 0))
            cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                               (0, slot, 0))
            ck = _kv_dequantize(ckq, cks)
            cv = _kv_dequantize(cvq, cvs)
            new_cache = {"k": ckq, "v": cvq, "k_scale": cks, "v_scale": cvs}
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            new_cache = {"k": ck, "v": cv}
        if RF.FLAGS.use_pallas_attention:
            out = _pallas_decode_attention(cfg, q, ck, cv, pos)
        else:
            mask = (jnp.arange(t) <= pos)[None, None, :]
            out = L.attention_scores(q, ck, cv, mask, cfg.attn_softcap)
        y = L.attention_output(p["mixer"], out)
    elif desc.mixer == MAMBA:
        y, new_cache = M.mamba_step(cfg, p["mixer"], h, cache)
    elif desc.mixer == MLSTM:
        y, new_cache = X.mlstm_block(cfg, p["mixer"], h, state=cache)
    elif desc.mixer == SLSTM:
        y, new_cache = X.slstm_block(cfg, p["mixer"], h, state=cache)
    else:
        raise ValueError(desc.mixer)
    x = x + y
    if desc.ffn != NONE:
        h = L.apply_norm(cfg, p["ffn_norm"], x)
        y = L.mlp_block(cfg, p["ffn"], h) if desc.ffn == MLP else \
            MOE.moe_block(cfg, p["ffn"], h)
        x = x + y
    return x, new_cache


def decode_step(cfg: ArchConfig, params: PyTree, caches: PyTree,
                token: jax.Array, pos: jax.Array,
                long_mode: bool = False) -> Tuple[jax.Array, PyTree]:
    """token: (B,) int32; pos: scalar int32 (current length).  Returns
    (logits (B, vocab), updated caches)."""
    x = params["embed"][token[:, None]].astype(jnp.bfloat16)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    if RF.FLAGS.decode_cache_donate:
        # Carry-DUS variant: the whole cache pytree rides the scan carry and
        # each iteration updates its period slice in place — XLA can alias
        # carry buffers (donation-friendly), avoiding the full-cache copy
        # that scan-ys stacking implies.
        def body_c(carry, period_params):
            x, all_caches, i = carry
            new_caches = []
            for k, desc in enumerate(cfg.period):
                pc = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, i, 0,
                                                           keepdims=False),
                    all_caches[k])
                x, nc = _decode_layer(cfg, desc, period_params[k], pc, x,
                                      pos, long_mode)
                new_caches.append(jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_index_in_dim(
                        c, n.astype(c.dtype), i, 0), all_caches[k], nc))
            return (x, new_caches, i + 1), None

        (x, new_caches, _), _ = _maybe_scan(
            body_c, (x, caches, jnp.zeros((), jnp.int32)), params["layers"])
        logits = _logits(cfg, params, x)[:, 0]
        return logits, new_caches

    def body(x, inp):
        period_params, period_caches = inp
        new_caches = []
        for i, desc in enumerate(cfg.period):
            x, nc = _decode_layer(cfg, desc, period_params[i],
                                  period_caches[i], x, pos, long_mode)
            new_caches.append(nc)
        return x, new_caches

    x, new_caches = _maybe_scan(body, x, (params["layers"], caches))
    logits = _logits(cfg, params, x)[:, 0]
    return logits, new_caches


# ------------------------------------------------- horizon-fused decode

def decode_steps(cfg: ArchConfig, params: PyTree, caches: PyTree,
                 token: jax.Array, pos: jax.Array, *, k: int,
                 long_mode: bool = False) -> Tuple[jax.Array, PyTree]:
    """``k`` greedy decode steps inside one jit via ``lax.scan``.

    The whole cache pytree rides the scan carry (dense ring KV slabs and
    the recurrent Mamba/xLSTM states are all fixed-shape/fixed-dtype, so
    the carry is shape-stable) and the sampled tokens accumulate on-device
    — one dispatch and zero host syncs for the whole horizon.  ``k`` must
    be static (the engine compiles one variant per power-of-two bucket).
    Each iteration is the *same* traced body as :func:`decode_step`
    followed by the same greedy argmax, so a fused chunk is token-for-token
    identical to ``k`` stepwise calls.

    ``token``: (B,) int32 — the last sampled token; ``pos``: scalar int32
    (current length; advances by one per step inside the scan).  Returns
    ``(tokens (B, k), updated caches)``.
    """
    def body(carry, i):
        tok, c = carry
        logits, c = decode_step(cfg, params, c, tok, pos + i,
                                long_mode=long_mode)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, c), nxt

    (_, new_caches), toks = jax.lax.scan(
        body, (token, caches), jnp.arange(k, dtype=jnp.int32))
    return jnp.swapaxes(toks, 0, 1), new_caches


# ---------------------------------------------------- paged decode step

def paged_supported(cfg: ArchConfig) -> bool:
    """Paged decode covers pure-attention stacks (every period layer ATTN)
    without int8 KV; hybrid/recurrent mixers keep dense per-cohort caches."""
    return (all(desc.mixer == ATTN for desc in cfg.period)
            and not RF.FLAGS.kv_cache_int8)


def _paged_decode_core(cfg: ArchConfig, params: PyTree, pools,
                       block_tables: jax.Array, lengths: jax.Array,
                       token: jax.Array, blk: jax.Array,
                       live: jax.Array) -> Tuple[jax.Array, Any]:
    """Shared body of the paged decode step: one token per slot, with the
    new K/V landing in block ``blk[s]`` at offset ``lengths[s] % bs``.
    Callers compute ``blk`` — the single-step entry derives it from
    ``lengths``; the horizon-fused entry computes it *once* per chunk
    (chunks never cross a block boundary, so each slot's write block is
    loop-invariant across the scan).  ``live`` (S,) bool marks occupied
    slots: every empty slot's table points at the shared scratch block, so
    their writes collide on the same pool position — a duplicate-index
    scatter whose winner XLA leaves unspecified.  Zeroing the dead lanes'
    K/V makes every colliding writer write the same value, so pool
    contents (and hence every downstream token) are deterministic whatever
    scatter order the backend picks."""
    s = token.shape[0]
    bs = pools[0]["k"].shape[2]
    mb = block_tables.shape[1]
    x = params["embed"][token[:, None]].astype(jnp.bfloat16)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = lengths[:, None]                               # (S, 1)
    off = lengths % bs
    lane = live[:, None, None]                                 # (S, 1, 1)
    new_pools = [dict(p) for p in pools]
    for pi in range(cfg.n_periods):
        for i, desc in enumerate(cfg.period):
            p = jax.tree.map(lambda leaf: leaf[pi], params["layers"][i])
            h = L.apply_norm(cfg, p["pre_norm"], x)
            q, k, v = L.project_qkv(cfg, p["mixer"], h, positions)
            kp = new_pools[i]["k"].at[pi, blk, off].set(
                jnp.where(lane, k[:, 0], 0).astype(new_pools[i]["k"].dtype))
            vp = new_pools[i]["v"].at[pi, blk, off].set(
                jnp.where(lane, v[:, 0], 0).astype(new_pools[i]["v"].dtype))
            new_pools[i] = {"k": kp, "v": vp}
            if RF.FLAGS.use_pallas_attention:
                from repro.kernels.paged_attention.ops import (
                    paged_decode_attention_op)
                out = paged_decode_attention_op(
                    q[:, 0], kp[pi], vp[pi], block_tables, lengths + 1,
                    softcap=cfg.attn_softcap)[:, None]
            else:
                kc = kp[pi][block_tables].reshape(s, mb * bs, cfg.n_kv_heads,
                                                  cfg.head_dim)
                vc = vp[pi][block_tables].reshape(s, mb * bs, cfg.n_kv_heads,
                                                  cfg.head_dim)
                mask = (jnp.arange(mb * bs)[None, :]
                        <= lengths[:, None])[:, None, :]       # (S, 1, T)
                out = L.attention_scores(q, kc, vc, mask, cfg.attn_softcap)
            x = x + L.attention_output(p["mixer"], out)
            if desc.ffn != NONE:
                h = L.apply_norm(cfg, p["ffn_norm"], x)
                y = L.mlp_block(cfg, p["ffn"], h) if desc.ffn == MLP else \
                    MOE.moe_block(cfg, p["ffn"], h)
                x = x + y
    logits = _logits(cfg, params, x)[:, 0]
    return logits, new_pools


def paged_decode_step(cfg: ArchConfig, params: PyTree, pools,
                      block_tables: jax.Array, lengths: jax.Array,
                      token: jax.Array) -> Tuple[jax.Array, Any]:
    """One lockstep decode step over *every slot* of a paged replica.

    ``pools`` is a per-period-layer list of ``{"k","v"}`` block pools with
    leaves ``(n_periods, num_blocks, block_size, KV, D)``;
    ``block_tables`` is ``(S, blocks_per_seq)`` int32; ``lengths`` is
    ``(S,)`` — the new token of slot ``s`` lands at cache position
    ``lengths[s]`` (block ``tables[s, lengths[s] // bs]``).  Empty slots
    pass ``lengths == 0`` with tables pointing at the reserved scratch
    block; their lanes compute garbage that callers never read.  Returns
    ``(logits (S, vocab), new_pools)``.

    The layer loop is a plain Python loop (not the period scan): the paged
    pools must update in place per period via ``.at[]`` indexed writes, and
    engine archs are reduced-depth so the O(depth) HLO is cheap.
    """
    assert paged_supported(cfg), f"{cfg.name}: unsupported paged arch"
    bs = pools[0]["k"].shape[2]
    rows = jnp.arange(token.shape[0])
    blk = block_tables[rows, lengths // bs]                    # (S,)
    return _paged_decode_core(cfg, params, pools, block_tables, lengths,
                              token, blk, lengths > 0)


def paged_decode_steps(cfg: ArchConfig, params: PyTree, pools,
                       block_tables: jax.Array, lengths: jax.Array,
                       token: jax.Array, *, k: int) -> Tuple[jax.Array, Any]:
    """``k`` greedy lockstep steps over a paged replica inside one jit.

    Contract: **no slot crosses a block boundary within the chunk** — the
    caller splits chunks at ``block_size - lengths % block_size`` (see
    ``PagedEngineCache.steps_to_boundary``), so each slot's write block is
    computed once and only the in-block offset (and the attention length)
    advances inside the scan.  Pools ride the scan carry; sampled tokens
    accumulate on-device.  Each iteration is the same traced body as
    :func:`paged_decode_step` + the same greedy argmax, so fused ≡ stepwise
    token-for-token.  Returns ``(tokens (S, k), new_pools)``.
    """
    assert paged_supported(cfg), f"{cfg.name}: unsupported paged arch"
    bs = pools[0]["k"].shape[2]
    rows = jnp.arange(token.shape[0])
    blk = block_tables[rows, lengths // bs]        # fixed for the chunk
    live = lengths > 0   # occupancy at chunk start (empty lanes' in-scan
                         # lengths tick up from 0 but the slots stay dead)

    def body(carry, i):
        tok, p = carry
        logits, p = _paged_decode_core(cfg, params, p, block_tables,
                                       lengths + i, tok, blk, live)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, p), nxt

    (_, new_pools), toks = jax.lax.scan(
        body, (token, pools), jnp.arange(k, dtype=jnp.int32))
    return jnp.swapaxes(toks, 0, 1), new_pools


# ----------------------------------------------- suffix (prefix-cached) prefill

def prefill_suffix(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
                   pools, prefix_tables: jax.Array, t_prefix: jax.Array,
                   last: jax.Array) -> Tuple[jax.Array, PyTree]:
    """Prefill only a prompt's *suffix* against a cached, paged prefix.

    The warm-prefix path of cross-request prefix caching: the first
    ``t_prefix`` prompt tokens' K/V already sit in the replica's block
    ``pools`` (written by an earlier request), so this entry embeds just
    the ``tokens`` suffix at positions ``t_prefix + i``, gathers the
    prefix context through ``prefix_tables`` exactly like the paged decode
    core, and attends each suffix token over prefix-plus-causal-suffix.

    ``tokens``: (B, S) int32, right-padded (pads are masked out of every
    real token's key set by the causal mask and their own garbage rows are
    never read).  ``prefix_tables``: (B, P) int32 block ids covering the
    cached prefix, padded with the scratch block — entries past
    ``t_prefix`` tokens are masked.  ``t_prefix`` / ``last`` are traced
    scalars (the cached token count and the last *real* suffix index), so
    one compilation serves every (S-bucket, P-bucket) shape.  Pure-ATTN
    archs only (``paged_supported``); positions ride RoPE with the traced
    offset, identical numerics to the cold full-sequence prefill.

    Returns ``(logits (B, vocab) at `last`, suffix caches)`` — the caches
    are the per-layer ``{"k","v"}`` suffix K/V with leaves
    ``(n_periods, B, S, KV, D)``, ready for
    ``PagedEngineCache.admit_prefixed`` to scatter at block-aligned
    position ``t_prefix``.
    """
    assert paged_supported(cfg), f"{cfg.name}: unsupported paged arch"
    b, s = tokens.shape
    bs = pools[0]["k"].shape[2]
    t_ctx = prefix_tables.shape[1] * bs
    x = params["embed"][tokens].astype(jnp.bfloat16)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.broadcast_to(t_prefix + jnp.arange(s), (b, s))
    # (S, T_ctx + S): every suffix token sees the real prefix positions
    # plus its causal suffix slice; table padding and token padding both
    # fall outside the mask.
    ctx_mask = jnp.broadcast_to(jnp.arange(t_ctx)[None, :] < t_prefix,
                                (s, t_ctx))
    causal = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
    mask = jnp.concatenate([ctx_mask, causal], axis=1)
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    ks = [[None] * cfg.n_periods for _ in cfg.period]
    vs = [[None] * cfg.n_periods for _ in cfg.period]
    for pi in range(cfg.n_periods):
        for i, desc in enumerate(cfg.period):
            p = jax.tree.map(lambda leaf: leaf[pi], params["layers"][i])
            h = L.apply_norm(cfg, p["pre_norm"], x)
            q, k, v = L.project_qkv(cfg, p["mixer"], h, positions)
            kc = pools[i]["k"][pi][prefix_tables].reshape(b, t_ctx, kv, dh)
            vc = pools[i]["v"][pi][prefix_tables].reshape(b, t_ctx, kv, dh)
            k_all = jnp.concatenate([kc.astype(k.dtype), k], axis=1)
            v_all = jnp.concatenate([vc.astype(v.dtype), v], axis=1)
            out = L.attention_scores(q, k_all, v_all, mask, cfg.attn_softcap)
            x = x + L.attention_output(p["mixer"], out)
            if desc.ffn != NONE:
                h = L.apply_norm(cfg, p["ffn_norm"], x)
                y = L.mlp_block(cfg, p["ffn"], h) if desc.ffn == MLP else \
                    _moe_apply(cfg, p["ffn"], h)
                x = x + y
            x = _constrain_acts(x)
            ks[i][pi] = k.astype(jnp.bfloat16)
            vs[i][pi] = v.astype(jnp.bfloat16)
    new_caches = [{"k": jnp.stack(ks[i]), "v": jnp.stack(vs[i])}
                  for i in range(len(cfg.period))]
    logits = _logits(cfg, params, jnp.take(x, last[None], axis=1))[:, 0]
    return logits, new_caches
