"""Runtime distribution/perf flags (the §Perf hillclimbing levers).

Defaults reproduce the paper-faithful BASELINE; the dry-run's --opt flag
flips them for the optimized variants so both stay measurable side by side.

  ACT_SEQ_SHARD   Megatron-SP style: constrain layer-boundary activations to
                  shard the sequence over the TP axis, turning each TP
                  all-reduce into a reduce-scatter + all-gather pair (half
                  the bytes on the wire, sharded residuals in memory).
  MOE_EP_SHARD_MAP
                  MoE dispatch via shard_map expert parallelism (local
                  capacity pack + all-to-all) instead of the global
                  sort-and-scatter the XLA partitioner has to all-gather.
  ATTN_Q_CHUNK    query-chunk length for long-sequence attention; smaller
                  chunks shrink the fp32 logits transient (VMEM/HBM).
  DECODE_CACHE_DONATE
                  decode caches flow as scan carry with in-place
                  dynamic-update-slice (buffer-donation friendly) instead of
                  scan ys (whole-cache copy every step).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

Axis = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass
class Flags:
    act_seq_shard: bool = False
    moe_ep_shard_map: bool = False
    decode_cache_donate: bool = False
    # int8 KV cache (beyond-paper serving optimization): halves the
    # cache-read traffic that dominates memory-bound decode; per-(slot,head)
    # absmax scales stored alongside.
    kv_cache_int8: bool = False
    # Route attention through the Pallas TPU kernels (flash prefill /
    # flash-decode).  Interpret-mode on CPU (slow, for validation); native on
    # TPU backends.
    use_pallas_attention: bool = False
    # sharding context used by the flags above
    dp_axes: Axis = None
    tp_axis: Axis = "model"
    mesh: Optional[object] = None


FLAGS = Flags()


def configure(**kw) -> Flags:
    for k, v in kw.items():
        setattr(FLAGS, k, v)
    return FLAGS


def reset() -> None:
    global FLAGS
    new = Flags()
    for f in dataclasses.fields(Flags):
        setattr(FLAGS, f.name, getattr(new, f.name))
