"""Sharding policy: PartitionSpecs for params, batches, and caches.

Baseline policy (the §Perf iterations move these):
  * TP over the ``model`` axis: attention heads, MLP hidden dim, vocab.
  * DP over the ``data`` axis (× ``pod`` when multi-pod): batch.
  * MoE expert parallelism: experts over ``data`` (EP), per-expert hidden dim
    over ``model`` (TP inside the expert).  When E doesn't divide the data
    axis (Mixtral's 8 experts on 16), the expert hidden dim shards over
    (data, model) jointly instead — "tensor-parallel experts".
  * Mamba/xLSTM: inner (expanded) dim over ``model``.
  * Decode KV caches: batch over ``data``; kv-heads over ``model`` when
    divisible.  For ``long_500k`` (batch=1) the cache *sequence* shards over
    ``data`` (distributed flash-decode).

Every rule degrades gracefully: a dim that doesn't divide its axis is left
unsharded (GSPMD requires divisibility), so every (arch × mesh) pair lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import (ATTN, ATTN_LOCAL, MAMBA, MLP, MLSTM,
                                 MOE as FFN_MOE, NONE, SLSTM, ArchConfig)

Axis = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical axis assignment for the current mesh."""

    dp: Axis = "data"        # batch / experts
    tp: Axis = "model"       # heads / hidden dims / vocab

    def sizes(self, mesh: Mesh) -> Tuple[int, int]:
        return _axis_size(mesh, self.dp), _axis_size(mesh, self.tp)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def _div(dim: int, mesh: Mesh, axis: Axis) -> Optional[Axis]:
    """axis if dim divides evenly over it, else None (replicate)."""
    if axis is None or dim == 0:
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


def param_specs(cfg: ArchConfig, mesh: Mesh,
                axes: MeshAxes = MeshAxes()) -> Any:
    """PartitionSpec pytree mirroring ``transformer.init_params``."""
    dp, tp = axes.dp, axes.tp
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def norm_spec():
        p = {"scale": P()}
        if cfg.norm == "layernorm":
            p["bias"] = P()
        return p

    def attn_spec():
        h_ax = _div(h, mesh, tp)
        kv_ax = _div(kv, mesh, tp)
        d_ax = _div(d, mesh, tp)
        p = {
            # shard heads over TP; fall back to the contracting d_model dim
            "wq": P(None, None, h_ax, None) if h_ax else P(None, d_ax, None, None),
            "wk": P(None, None, kv_ax, None) if kv_ax else P(None, d_ax, None, None),
            "wv": P(None, None, kv_ax, None) if kv_ax else P(None, d_ax, None, None),
            "wo": P(None, h_ax, None, None) if h_ax else P(None, None, None, d_ax),
        }
        if cfg.qkv_bias:
            p["bq"] = P(None, h_ax, None) if h_ax else P()
            p["bk"] = P(None, kv_ax, None) if kv_ax else P()
            p["bv"] = P(None, kv_ax, None) if kv_ax else P()
        if cfg.qk_norm:
            p["q_norm"] = P()
            p["k_norm"] = P()
        return p

    def mamba_spec():
        di = cfg.d_inner
        di_ax = _div(di, mesh, tp)
        di2_ax = _div(2 * di, mesh, tp)
        return {
            "in_proj": P(None, None, di2_ax),
            "conv_w": P(None, None, di_ax),
            "conv_b": P(None, di_ax),
            "x_proj": P(None, di_ax, None),
            "dt_proj": P(None, None, di_ax),
            "dt_bias": P(None, di_ax),
            "a_log": P(None, di_ax, None),
            "d_skip": P(None, di_ax),
            "out_proj": P(None, di_ax, None),
        }

    def mlstm_spec():
        di = 2 * d
        di_ax = _div(di, mesh, tp)
        di2_ax = _div(2 * di, mesh, tp)
        return {
            "up_proj": P(None, None, di2_ax),
            "wq": P(None, di_ax, None, None),
            "wk": P(None, di_ax, None, None),
            "wv": P(None, di_ax, None, None),
            "wi": P(None, di_ax, None),
            "bi": P(),
            "wf": P(None, di_ax, None),
            "bf": P(),
            "hnorm": P(None, di_ax),
            "down_proj": P(None, di_ax, None),
        }

    def slstm_spec():
        return {k: P() for k in ("w", "r", "b", "hnorm")} | {
            "ffn_gate": P(None, None, _div(max(4 * d // 3, 8), mesh, tp)),
            "ffn_up": P(None, None, _div(max(4 * d // 3, 8), mesh, tp)),
            "ffn_down": P(None, _div(max(4 * d // 3, 8), mesh, tp), None),
        }

    def mlp_spec():
        ff_ax = _div(cfg.d_ff, mesh, tp)
        p = {"w_up": P(None, None, ff_ax), "w_down": P(None, ff_ax, None)}
        if cfg.mlp_gated:
            p["w_gate"] = P(None, None, ff_ax)
        return p

    def moe_spec():
        ff = (cfg.moe_d_ff or cfg.d_ff) // cfg.moe_expert_shards
        e_ax = _div(cfg.n_experts * cfg.moe_expert_shards, mesh, dp)
        if e_ax is not None:
            ff_ax = _div(ff, mesh, tp)
            return {
                "router": P(),
                "w_gate": P(None, e_ax, None, ff_ax),
                "w_up": P(None, e_ax, None, ff_ax),
                "w_down": P(None, e_ax, ff_ax, None),
            }
        # tensor-parallel experts: hidden dim over (dp, tp) jointly
        joint = _joint_axis(dp, tp)
        ff_ax = _div(ff, mesh, joint)
        return {
            "router": P(),
            "w_gate": P(None, None, None, ff_ax),
            "w_up": P(None, None, None, ff_ax),
            "w_down": P(None, None, ff_ax, None),
        }

    layer_specs = []
    for desc in cfg.period:
        sub = {"pre_norm": norm_spec()}
        if desc.mixer in (ATTN, ATTN_LOCAL):
            sub["mixer"] = attn_spec()
        elif desc.mixer == MAMBA:
            sub["mixer"] = mamba_spec()
        elif desc.mixer == MLSTM:
            sub["mixer"] = mlstm_spec()
        elif desc.mixer == SLSTM:
            sub["mixer"] = slstm_spec()
        if desc.ffn != NONE:
            sub["ffn_norm"] = norm_spec()
            sub["ffn"] = mlp_spec() if desc.ffn == MLP else moe_spec()
        layer_specs.append(sub)

    specs = {
        "embed": P(_div(cfg.vocab_size, mesh, tp), None),
        "layers": layer_specs,
        "final_norm": norm_spec(),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, _div(cfg.vocab_size, mesh, tp))
    return specs


def _joint_axis(dp: Axis, tp: Axis) -> Tuple[str, ...]:
    out: Tuple[str, ...] = ()
    for a in (dp, tp):
        if isinstance(a, str):
            out += (a,)
        elif a:
            out += tuple(a)
    return out


def batch_specs(cfg: ArchConfig, mesh: Mesh, batch: int,
                axes: MeshAxes = MeshAxes()) -> Any:
    """Training-batch specs (tokens/labels [+ prefix embeds])."""
    b_ax = _div(batch, mesh, axes.dp)
    spec = {"tokens": P(b_ax, None), "labels": P(b_ax, None)}
    if cfg.frontend != "none":
        spec["prefix_embeds"] = P(b_ax, None, None)
    return spec


def cache_specs(cfg: ArchConfig, mesh: Mesh, batch: int, *,
                long_mode: bool = False, t_max: int = 0,
                axes: MeshAxes = MeshAxes()) -> Any:
    """Decode-cache specs.

    Priority: batch over dp, kv-heads over tp.  Whatever can't shard moves
    to the cache *sequence* dim (distributed flash-decode: the per-step
    softmax reduces over the sequence shards with a psum — GSPMD emits it
    automatically from the einsum/softmax pattern):
      * kv-heads don't divide tp (GQA kv < 16)  -> sequence over tp
      * batch doesn't divide dp (long_500k b=1) -> sequence over dp
      * neither                                  -> sequence over (dp, tp)
    """
    from repro.models import transformer as _T
    dp, tp = axes.dp, axes.tp
    b_ax = _div(batch, mesh, dp)
    kv_ax = _div(cfg.n_kv_heads, mesh, tp)
    di_ax = _div(cfg.d_inner, mesh, tp)
    specs = []
    for desc in cfg.period:
        if desc.mixer in (ATTN, ATTN_LOCAL):
            t_len = _T._cache_len(cfg, desc, t_max, long_mode) if t_max else 0
            if b_ax is not None and kv_ax is not None:
                t_ax = None
            elif b_ax is not None:
                t_ax = _div(t_len, mesh, tp) if t_max else tp
            elif kv_ax is not None:
                t_ax = _div(t_len, mesh, dp) if t_max else dp
            else:
                joint = _joint_axis(dp, tp)
                t_ax = _div(t_len, mesh, joint) if t_max else joint
            entry = {
                "k": P(None, b_ax, t_ax, kv_ax, None),
                "v": P(None, b_ax, t_ax, kv_ax, None),
            }
            from repro.models import runtime_flags as _RF
            if _RF.FLAGS.kv_cache_int8:
                entry["k_scale"] = P(None, b_ax, t_ax, kv_ax)
                entry["v_scale"] = P(None, b_ax, t_ax, kv_ax)
            specs.append(entry)
        elif desc.mixer == MAMBA:
            specs.append({"conv": P(None, b_ax, None, di_ax),
                          "h": P(None, b_ax, di_ax, None)})
        elif desc.mixer == MLSTM:
            specs.append({"c": P(None, b_ax, None, None, None),
                          "n": P(None, b_ax, None, None),
                          "m": P(None, b_ax, None)})
        elif desc.mixer == SLSTM:
            specs.append({k: P(None, b_ax, None, None)
                          for k in ("c", "n", "h", "m")})
    return specs


def logits_spec(cfg: ArchConfig, mesh: Mesh, batch: int,
                axes: MeshAxes = MeshAxes()) -> P:
    return P(_div(batch, mesh, axes.dp), None,
             _div(cfg.vocab_size, mesh, axes.tp))


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
