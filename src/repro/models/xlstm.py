"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with recurrent mixing), after arXiv:2405.04517.

TPU adaptation: the mLSTM's exponential-gated linear recurrence is computed
in the *chunkwise-parallel* form — quadratic within fixed chunks (MXU-sized
matmuls), a small (B, H, Dh, Dh) carry across chunks — instead of the CUDA
fused recurrent kernel.  A sequential-scan oracle (``mlstm_seq``) validates
it.  The sLSTM's memory mixing is genuinely sequential → ``jax.lax.scan``.

All gating is max-stabilized: forget gates are sigmoid (log f = -softplus(-f̃)),
input gates exponential, with running stabilizer m.

Decode-state contract (horizon-fused decode): both blocks' states are
fixed-shape fp32 pytrees — mLSTM ``{"c","n","m"}``, sLSTM
``{"c","n","h","m"}`` — stable under repeated single-token application, so
they ride a ``jax.lax.scan`` carry and ``transformer.decode_steps`` can
fuse k recurrent steps into one jit.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

MLSTM_CHUNK = 128


# ------------------------------------------------------------------ mLSTM

def _gates(p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (log_f, i_tilde), each (B, S, H), fp32."""
    xf = x.astype(jnp.float32)
    i_t = jnp.einsum("bsd,dh->bsh", xf, p["wi"].astype(jnp.float32)) + p["bi"]
    f_t = jnp.einsum("bsd,dh->bsh", xf, p["wf"].astype(jnp.float32)) + p["bf"]
    log_f = -jax.nn.softplus(-f_t)          # log sigmoid
    return log_f, i_t


def _qkv(p: dict, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    return q, k * (k.shape[-1] ** -0.5), v


def mlstm_seq(cfg: ArchConfig, p: dict, x: jax.Array,
              state: dict | None = None) -> Tuple[jax.Array, dict]:
    """Sequential oracle / decode path.  x: (B,S,Di) inner activations."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x)
    h_heads, dh = q.shape[2], q.shape[3]
    log_f, i_t = _gates(p, x)
    if state is None:
        state = {
            "c": jnp.zeros((b, h_heads, dh, dh), jnp.float32),
            "n": jnp.zeros((b, h_heads, dh), jnp.float32),
            "m": jnp.full((b, h_heads), -1e30, jnp.float32),
        }

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, lf, it = inp               # (B,H,Dh) / (B,H)
        m_new = jnp.maximum(lf + m, it)
        fp = jnp.exp(lf + m - m_new)[..., None]
        ip = jnp.exp(it - m_new)[..., None]
        c = fp[..., None] * c + (ip * vt)[..., None] * kt[..., None, :].astype(jnp.float32)
        n = fp * n + ip * kt.astype(jnp.float32)
        num = jnp.einsum("bhxy,bhy->bhx", c, qt.astype(jnp.float32))
        den = jnp.abs(jnp.einsum("bhy,bhy->bh", n, qt.astype(jnp.float32)))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (c, n, m_new), num / den

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_f.transpose(1, 0, 2),
          i_t.transpose(1, 0, 2))
    (c, n, m), hs = jax.lax.scan(step, (state["c"], state["n"], state["m"]), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, -1).astype(x.dtype)
    return h, {"c": c, "n": n, "m": m}


def mlstm_chunkwise(cfg: ArchConfig, p: dict, x: jax.Array,
                    chunk: int = MLSTM_CHUNK) -> Tuple[jax.Array, dict]:
    """Chunkwise-parallel mLSTM (prefill/train path)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x)
    nh, dh = q.shape[2], q.shape[3]
    log_f, i_t = _gates(p, x)

    pad = (-s) % chunk
    if pad:
        padq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, padq) for t in (q, k, v))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        i_t = jnp.pad(i_t, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
    nc = q.shape[1] // chunk

    def resh(t):  # (B, NC, L, H, ...) -> scan-major (NC, B, H, L, ...)
        t = t.reshape((b, nc, chunk) + t.shape[2:])
        perm = (1, 0, 3, 2) + tuple(range(4, t.ndim))
        return t.transpose(perm)

    qc, kc, vc = resh(q), resh(k), resh(v)            # (NC,B,H,L,Dh)
    lfc, itc = resh(log_f), resh(i_t)                 # (NC,B,H,L)

    c0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    m0 = jnp.full((b, nh), -1e30, jnp.float32)

    def chunk_body(carry, inp):
        c, n, m = carry
        qj, kj, vj, lf, it = inp                      # (B,H,L,·)
        f_cum = jnp.cumsum(lf, axis=-1)               # F_j (B,H,L)
        f_tot = f_cum[..., -1]
        # intra-chunk logits: D_js = F_j − F_s + ĩ_s  (s ≤ j)
        d_mat = f_cum[..., :, None] - f_cum[..., None, :] + it[..., None, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        d_mat = jnp.where(mask, d_mat, -jnp.inf)
        # carry scale as seen by query j: b_j = F_j + m
        b_j = f_cum + m[..., None]
        m_j = jnp.maximum(jnp.max(d_mat, axis=-1), b_j)
        m_j = jnp.maximum(m_j, -1e30)
        w_intra = jnp.exp(d_mat - m_j[..., None])     # (B,H,L,L)
        g_inter = jnp.exp(b_j - m_j)                  # (B,H,L)
        qf = qj.astype(jnp.float32)
        kf = kj.astype(jnp.float32)
        vf = vj.astype(jnp.float32)
        scores = jnp.einsum("bhld,bhsd->bhls", qf, kf) * w_intra
        num = jnp.einsum("bhls,bhsd->bhld", scores, vf)
        num += g_inter[..., None] * jnp.einsum("bhxy,bhly->bhlx", c, qf)
        den = jnp.sum(scores, axis=-1) + g_inter * jnp.einsum(
            "bhy,bhly->bhl", n, qf)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_j))
        h = num / den[..., None]
        # carry update
        m_new = jnp.maximum(f_tot + m, jnp.max(f_tot[..., None] - f_cum + it,
                                               axis=-1))
        scale_c = jnp.exp(f_tot + m - m_new)          # (B,H)
        w_kv = jnp.exp(f_tot[..., None] - f_cum + it - m_new[..., None])
        c = scale_c[..., None, None] * c + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w_kv, vf, kf)
        n = scale_c[..., None] * n + jnp.einsum("bhs,bhsd->bhd", w_kv, kf)
        return (c, n, m_new), h

    (c, n, m), hs = jax.lax.scan(chunk_body, (c0, n0, m0),
                                 (qc, kc, vc, lfc, itc))
    # hs: (NC, B, H, L, Dh) -> (B, NC, L, H, Dh) -> (B, S, H*Dh)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(b, nc * chunk, nh * dh)[:, :s]
    return h.astype(x.dtype), {"c": c, "n": n, "m": m}


def mlstm_block(cfg: ArchConfig, p: dict, x: jax.Array, *,
                state: dict | None = None, sequential: bool = False
                ) -> Tuple[jax.Array, dict]:
    """Full mLSTM residual block: up-proj, mLSTM, gate, down-proj.

    x: (B,S,d_model).  state=None → prefill (chunkwise); else decode.
    """
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    if state is None and not sequential:
        h, new_state = mlstm_chunkwise(cfg, p, xin)
    else:
        h, new_state = mlstm_seq(cfg, p, xin, state)
    h = h * (1.0 + p["hnorm"])        # headwise scale (group-norm lite)
    out = h * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", out, p["down_proj"]), new_state


# ------------------------------------------------------------------ sLSTM

def slstm_block(cfg: ArchConfig, p: dict, x: jax.Array, *,
                state: dict | None = None) -> Tuple[jax.Array, dict]:
    """sLSTM with per-head recurrent memory mixing + gated 4/3 FFN.

    x: (B,S,d_model).  Sequential by construction.
    """
    b, s, d = x.shape
    nh = p["r"].shape[1]
    dh = d // nh
    xg = jnp.einsum("bsd,dghk->bsghk", x, p["w"]) + p["b"]   # (B,S,4,H,Dh)

    if state is None:
        state = {
            "c": jnp.zeros((b, nh, dh), jnp.float32),
            "n": jnp.ones((b, nh, dh), jnp.float32),
            "h": jnp.zeros((b, nh, dh), jnp.float32),
            "m": jnp.zeros((b, nh, dh), jnp.float32),
        }

    r = p["r"].astype(jnp.float32)                            # (4,H,Dh,Dh)

    def step(carry, xt):
        c, n, h, m = carry
        pre = xt.astype(jnp.float32) + jnp.einsum(
            "ghxy,bhy->bghx", r, h)                           # (B,4,H,Dh)
        it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        log_f = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(log_f + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(log_f + m - m_new)
        c = fp * c + ip * jnp.tanh(zt)
        n = fp * n + ip
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    carry, hs = jax.lax.scan(step, (state["c"], state["n"], state["h"],
                                    state["m"]),
                             xg.transpose(1, 0, 2, 3, 4))
    c, n, h, m = carry
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = y * (1.0 + p["hnorm"])
    # gated FFN (factor 4/3) fused into the block, per the paper.
    g = jax.nn.gelu(jnp.einsum("bsd,df->bsf", y, p["ffn_gate"]))
    u = jnp.einsum("bsd,df->bsf", y, p["ffn_up"])
    out = jnp.einsum("bsf,fd->bsd", g * u, p["ffn_down"])
    return out, {"c": c, "n": n, "h": h, "m": m}
