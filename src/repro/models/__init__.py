"""JAX model zoo: composable decoder covering dense / MoE / hybrid(Mamba) /
xLSTM / audio / VLM backbones."""
from repro.models.config import ArchConfig, LayerDesc
from repro.models.model import (decode_step, greedy_sample, init_cache,
                                init_params, loss_fn, make_batch_spec,
                                param_count, prefill, synthetic_batch)
