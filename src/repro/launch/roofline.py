"""Roofline post-processing.

``cost_analysis()`` FLOPs (with --unroll) and the parsed collective bytes are
trustworthy; the CPU backend's "bytes accessed", however, counts every
unfused pass-through op (parameter/get-tuple-element/convert re-listings
inside while bodies), inflating HBM traffic by 10-40x vs what a fusing TPU
backend executes.  This module derives an *analytic* per-device HBM-traffic
estimate from first principles for each (arch x shape), used alongside the
raw HLO number:

  decode : active weights read + KV/state cache read+write
  prefill: weights read + ~12 activation passes/layer + attention score traffic
  train  : fwd+bwd weight reads + grad + fp32 Adam moments r/w (~12x weights)
           + 3x the prefill activation traffic (fwd, recompute, bwd)

All terms are per-device (sharded) bytes; divide by 819 GB/s for seconds.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import get_config
from repro.launch.shapes import INPUT_SHAPES
from repro.models.config import ATTN, ATTN_LOCAL, ArchConfig

HBM_BW = 819e9
BYTES = 2  # bf16
ACT_PASSES = 12  # reads+writes of the (tokens, d_model) activation per layer


def _chips(mesh: str) -> int:
    n = 1
    for p in mesh.split("x"):
        n *= int(p)
    return n


def _cache_bytes_per_device(cfg: ArchConfig, batch: int, t_max: int,
                            long_mode: bool, chips: int) -> float:
    """Total decode-cache bytes (all layers), already divided by chips —
    caches shard over either batch, kv-heads, or sequence (sharding.py
    guarantees one of these covers each mesh axis)."""
    from repro.models import transformer as T
    total = 0.0
    per = len(cfg.period)
    for desc in cfg.period:
        n_layers = cfg.n_periods
        if desc.mixer in (ATTN, ATTN_LOCAL):
            t = T._cache_len(cfg, desc, t_max, long_mode)
            total += n_layers * 2 * batch * t * cfg.n_kv_heads * cfg.head_dim * BYTES
        elif desc.mixer == "mamba":
            total += n_layers * batch * cfg.d_inner * (cfg.ssm_state_dim * 4 + 3 * BYTES)
        elif desc.mixer == "mlstm":
            di = 2 * cfg.d_model
            total += n_layers * batch * cfg.n_heads * ((di // cfg.n_heads) ** 2 + di // cfg.n_heads) * 4
        elif desc.mixer == "slstm":
            total += n_layers * batch * cfg.d_model * 4 * 4
    return total / chips


def analytic_memory_term(arch: str, shape_name: str, mesh: str) -> Dict[str, float]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    chips = _chips(mesh)
    long_mode = shape_name == "long_500k"
    weights_dev = cfg.param_count() * BYTES / chips
    active_dev = cfg.active_param_count() * BYTES / chips

    if shape.kind == "decode":
        cache_dev = _cache_bytes_per_device(cfg, shape.global_batch,
                                            shape.seq_len, long_mode, chips)
        traffic = active_dev + 2 * cache_dev
    else:
        tokens_dev = shape.global_batch * shape.seq_len / chips
        act = cfg.n_layers * tokens_dev * cfg.d_model * BYTES * ACT_PASSES
        # attention score traffic (fp32 logits+probs, ~2 passes), windowed
        ctx = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        attn = (cfg.n_periods * cfg.attn_layers_per_period * 2 * 4
                * tokens_dev * ctx * cfg.n_kv_heads / max(cfg.n_kv_heads, 1))
        if shape.kind == "train":
            traffic = 12 * weights_dev + 3 * (act + attn)
        else:
            cache_dev = _cache_bytes_per_device(cfg, shape.global_batch,
                                                shape.seq_len, False, chips)
            traffic = weights_dev + act + attn + cache_dev
    return {"analytic_bytes_per_device": traffic,
            "memory_term_analytic_s": traffic / HBM_BW}


ICI_BW = 50e9

# Ring-algorithm wire cost per device, as a multiple of the operand bytes:
# all-reduce moves ~2x (reduce-scatter phase + all-gather phase); the others
# move ~1x.
WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def wire_collective_term(record: Dict) -> float:
    coll = record.get("collectives", {})
    wire = sum(WIRE_FACTOR.get(op, 1.0) * b for op, b in coll.items())
    return wire / ICI_BW


def enrich(record: Dict) -> Dict:
    """Add analytic memory + wire-weighted collective term + re-derive the
    bottleneck with them."""
    extra = analytic_memory_term(record["arch"], record["shape"],
                                 record["mesh"])
    record = dict(record, **extra)
    record["collective_term_wire_s"] = wire_collective_term(record)
    terms = {"compute": record["compute_term_s"],
             "memory": record["memory_term_analytic_s"],
             "collective": record["collective_term_wire_s"]}
    record["bottleneck_analytic"] = max(terms, key=terms.get)
    return record
