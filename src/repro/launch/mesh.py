"""Production meshes.

Single pod: 256 v5e chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the ``pod``
axis is an extra data-parallel factor (batch / sequence shard) crossing DCN.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for single-device runs (tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (includes pod when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
