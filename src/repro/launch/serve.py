"""Serving driver: schedule a plan for a trace + budget, then run it through
the unified runtime — predicted metrics from the cost-model backend, and
optionally real token execution on CPU replicas with the same scheduler.

    PYTHONPATH=src python -m repro.launch.serve \
        --trace trace1 --budget 30 --avail avail1 --requests 100 \
        --arrival-rate 2.0 --slo-ttft 30 --slo-tpot 1.0
"""
from __future__ import annotations

import argparse

import repro
from repro.configs import get_config
from repro.core import (AVAILABILITY_SNAPSHOTS, GPU_CATALOG, DeploymentSpec,
                        make_trace, plan, simulate)
from repro.core.costmodel import LLAMA3_8B, LLAMA3_70B
from repro.runtime import SLO


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="trace1")
    ap.add_argument("--budget", type=float, default=30.0)
    ap.add_argument("--avail", default="avail1",
                    choices=list(AVAILABILITY_SNAPSHOTS))
    ap.add_argument("--model", default="llama3-70b",
                    choices=["llama3-8b", "llama3-70b"])
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson req/s (default: all arrive at t=0)")
    ap.add_argument("--method", default="binary_search",
                    choices=["binary_search", "milp"])
    ap.add_argument("--slo-ttft", type=float, default=float("inf"),
                    help="TTFT SLO in seconds (for goodput)")
    ap.add_argument("--slo-tpot", type=float, default=float("inf"),
                    help="TPOT SLO in seconds (for goodput)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--execute", action="store_true",
                    help="also run real token generation on CPU replicas")
    args = ap.parse_args()

    profile = LLAMA3_70B if args.model == "llama3-70b" else LLAMA3_8B
    trace = make_trace(args.trace, num_requests=args.requests,
                       arrival_rate=args.arrival_rate, seed=0)
    spec = DeploymentSpec(models=[profile], workload=trace,
                          catalog=GPU_CATALOG,
                          availability=AVAILABILITY_SNAPSHOTS[args.avail],
                          budget=args.budget)
    deployment = plan(spec, method=args.method)
    print(deployment.summary())
    slo = SLO(ttft=args.slo_ttft, tpot=args.slo_tpot)
    sim = simulate(deployment, trace, [profile])
    print(f"predicted: makespan={sim.makespan:.1f}s "
          f"throughput={sim.throughput:.3f} req/s "
          f"p90={sim.percentile(90):.1f}s "
          f"ttft_p90={sim.ttft_percentile(90):.1f}s "
          f"tpot_p90={sim.tpot_percentile(90):.3f}s "
          f"goodput={sim.goodput(slo):.3f} req/s "
          f"({100 * sim.slo_attainment(slo):.0f}% in SLO)")

    if args.execute:
        import time
        cfg = get_config(args.model).reduced()
        session = repro.serve(deployment, arch_cfgs=[cfg], input_len=16,
                              max_new=args.max_new, max_batch=8)
        t0 = time.perf_counter()
        res = session.replay(trace)
        wall = time.perf_counter() - t0
        toks = session.executor.generated_tokens
        print(f"executed: {res.num_completed} requests, "
              f"{toks} tokens, "
              f"{toks / max(wall, 1e-9):.1f} tok/s on "
              f"{len(deployment.replicas)} replicas "
              f"(per-replica: {res.per_replica_requests}); "
              f"ttft_p90={res.ttft_percentile(90):.2f}s "
              f"tpot_p90={res.tpot_percentile(90):.3f}s "
              f"goodput={res.goodput(slo):.3f} req/s")


if __name__ == "__main__":
    main()
