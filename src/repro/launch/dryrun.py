import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization, and the production meshes need 512
# placeholder host devices (2 pods x 16 x 16).

import argparse          # noqa: E402
import functools         # noqa: E402
import gc                # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, get_config      # noqa: E402
from repro.launch.mesh import dp_axes, make_production_mesh  # noqa: E402
from repro.launch.shapes import INPUT_SHAPES, InputShape, input_specs  # noqa: E402
from repro.models import model as M                       # noqa: E402
from repro.models import sharding as SH                   # noqa: E402
from repro.models import transformer as T                 # noqa: E402
from repro.training.optimizer import AdamW                # noqa: E402

"""Multi-pod dry-run: for every (architecture x input shape x mesh), lower
and compile the real step function against ShapeDtypeStruct stand-ins (no
allocation), then extract the roofline terms:

  compute   = HLO FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory    = HLO bytes / (chips x 819e9 B/s HBM)
  collective= collective bytes / (chips x 50e9 B/s ICI link)

FLOPs/bytes come from compiled.cost_analysis(); collective bytes are parsed
from the partitioned HLO (sum of operand bytes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, scaled back to global).
"""

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 1024**3

_COLLECTIVE_LINE_RE = re.compile(
    r"=\s+(?P<result>[^=]*?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_per_device(hlo_text: str) -> Dict[str, float]:
    """Per-device *operand* bytes of every collective in the partitioned
    module.  HLO operands aren't typed inline, so operand sizes are
    reconstructed from result shapes + group sizes:

      all-reduce / all-to-all / collective-permute : operand == result
      all-gather    : operand == result / group_size
      reduce-scatter: operand == result * group_size
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_LINE_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        result_bytes = _shape_bytes(m.group("result"))
        gm = _GROUPS_RE.search(line)
        group = int(gm.group(2)) if gm else 1
        if op == "all-gather" and group > 1:
            operand_bytes = result_bytes / group
        elif op == "reduce-scatter":
            operand_bytes = result_bytes * group
        else:
            operand_bytes = result_bytes
        out[op] = out.get(op, 0.0) + operand_bytes
    return out


def _leaf_device_bytes(sds, spec, mesh) -> float:
    """Per-device bytes of one sharded array."""
    shards = 1
    for entry in (spec or P()):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            shards *= mesh.shape[a]
    return float(np.prod(sds.shape)) * sds.dtype.itemsize / shards if sds.shape else sds.dtype.itemsize


def tree_device_bytes(sds_tree, spec_tree, mesh) -> float:
    leaves_sds = jax.tree.leaves(sds_tree)
    leaves_spec = jax.tree.leaves(spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_sds) == len(leaves_spec), \
        (len(leaves_sds), len(leaves_spec))
    return sum(_leaf_device_bytes(s, p, mesh)
               for s, p in zip(leaves_sds, leaves_spec))


def build_step(cfg, spec, mesh, include_optimizer: bool):
    """Returns (fn, arg_sds, in_shardings, out_shardings)."""
    ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    axes = SH.MeshAxes(dp=dp_axes(mesh), tp="model")

    if spec["kind"] == "train":
        opt = AdamW()
        if include_optimizer:
            def step(params, opt_m, opt_v, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
                from repro.training.optimizer import AdamWState
                state = AdamWState(jnp.zeros((), jnp.int32), opt_m, opt_v)
                new_p, new_s = opt.update(grads, state, params)
                return loss, new_p, new_s.m, new_s.v
            p_sds = spec["params"]
            f32 = lambda t: jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
            arg_sds = (p_sds, f32(p_sds), f32(p_sds), spec["args"][0])
            ps = spec["params_spec"]
            in_spec = (ps, ps, ps, spec["args_spec"][0])
            out_spec = (P(), ps, ps, ps)
            return step, arg_sds, ns(in_spec), ns(out_spec)
        def step(params, batch):
            loss, _ = M.loss_fn(cfg, params, batch)
            return loss
        arg_sds = (spec["params"], spec["args"][0])
        in_spec = (spec["params_spec"], spec["args_spec"][0])
        return step, arg_sds, ns(in_spec), ns(P())

    if spec["kind"] == "prefill":
        t_max = spec["t_max"]
        has_prefix = len(spec["args"]) > 1
        if has_prefix:
            def step(params, tokens, prefix):
                return T.prefill(cfg, params, tokens, prefix, t_max=t_max)
        else:
            def step(params, tokens):
                return T.prefill(cfg, params, tokens, t_max=t_max)
        arg_sds = (spec["params"],) + spec["args"]
        in_spec = (spec["params_spec"],) + spec["args_spec"]
        b_ax = spec["args_spec"][0][0]
        out_spec = (P(b_ax, None, None), spec["cache_spec"])
        return step, arg_sds, ns(in_spec), ns(out_spec)

    # decode
    long_mode = spec["long_mode"]

    def step(params, caches, token, pos):
        logits, new_caches = T.decode_step(cfg, params, caches, token, pos,
                                           long_mode=long_mode)
        return logits, new_caches

    arg_sds = (spec["params"],) + spec["args"]
    in_spec = (spec["params_spec"],) + spec["args_spec"]
    b_ax = spec["args_spec"][1]
    out_spec = (P(b_ax[0] if isinstance(b_ax, P) else None, None),
                spec["args_spec"][0])
    return step, arg_sds, ns(in_spec), ns(out_spec)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            include_optimizer: bool = True, unroll: bool = False,
            opts: str = "") -> Dict[str, Any]:
    from repro.models import transformer as _T
    from repro.models import runtime_flags as RF
    _T.UNROLL_PERIODS = unroll
    RF.reset()
    cfg = get_config(arch)
    opt_pre = {o for o in opts.split(",") if o}
    if "moe_split2" in opt_pre and cfg.n_experts:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe_expert_shards=2)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    opt_set = {o for o in opts.split(",") if o}
    RF.configure(
        mesh=mesh,
        dp_axes=dp_axes(mesh),
        tp_axis="model",
        act_seq_shard="act_seq_shard" in opt_set,
        moe_ep_shard_map="moe_ep" in opt_set,
        decode_cache_donate="cache_donate" in opt_set,
        kv_cache_int8="kv_int8" in opt_set,
    )
    spec = input_specs(cfg, shape, mesh)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "chips": chips, "kind": spec["kind"], "unrolled": unroll,
        "opts": sorted(opt_set),
    }
    t0 = time.perf_counter()
    with mesh:
        fn, arg_sds, in_shardings, out_shardings = build_step(
            cfg, spec, mesh, include_optimizer)
        donate = ()
        if spec["kind"] == "decode" and "cache_donate" in opt_set:
            donate = (1,)
        jitted = jax.jit(fn, in_shardings=in_shardings,
                         out_shardings=out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*arg_sds)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax <= 0.4.x: one dict per module
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception:
        record["memory_analysis"] = None

    hlo = compiled.as_text()
    coll = collective_bytes_per_device(hlo)
    coll_total_dev = sum(coll.values())

    # Analytic per-device residency (sharded args): weights + caches + opt.
    arg_bytes = tree_device_bytes(arg_sds, jax.tree.map(
        lambda s: s.spec, in_shardings,
        is_leaf=lambda x: isinstance(x, NamedSharding)), mesh)

    # cost_analysis flops on the partitioned module are per-device.
    model_flops_token = 6 * cfg.active_param_count()
    if spec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 3 * 2 * cfg.active_param_count() * tokens  # fwd+bwd
    elif spec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * cfg.active_param_count() * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * cfg.active_param_count() * tokens

    flops_global = flops * chips
    bytes_global = bytes_accessed * chips
    coll_global = coll_total_dev * chips
    record.update({
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_total_dev,
        "collectives": coll,
        "arg_bytes_per_device": arg_bytes,
        "compute_term_s": flops_global / (chips * PEAK_FLOPS),
        "memory_term_s": bytes_global / (chips * HBM_BW),
        "collective_term_s": coll_global / (chips * ICI_BW),
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / flops_global if flops_global else 0.0,
        "fits_hbm": arg_bytes <= HBM_PER_CHIP,
    })
    terms = {"compute": record["compute_term_s"],
             "memory": record["memory_term_s"],
             "collective": record["collective_term_s"]}
    record["bottleneck"] = max(terms, key=terms.get)
    return record


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (assigned 10)")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(INPUT_SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--no-optimizer", action="store_true",
                    help="lower train loss only (no AdamW update)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the period scan for exact cost_analysis "
                         "(slower compiles; used for the roofline table)")
    ap.add_argument("--opt", default="",
                    help="comma list of perf levers: act_seq_shard, moe_ep, "
                         "cache_donate (default: paper-faithful baseline)")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_fail = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                    try:
                        rec = run_one(arch, shape, mp,
                                      include_optimizer=not args.no_optimizer,
                                      unroll=args.unroll, opts=args.opt)
                        print(f"[ok] {tag}: bottleneck={rec['bottleneck']} "
                              f"compute={rec['compute_term_s']:.4f}s "
                              f"memory={rec['memory_term_s']:.4f}s "
                              f"collective={rec['collective_term_s']:.4f}s "
                              f"args/dev={rec['arg_bytes_per_device']/2**30:.2f}GiB "
                              f"compile={rec['compile_s']:.0f}s", flush=True)
                    except Exception as e:
                        n_fail += 1
                        rec = {"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "error": f"{type(e).__name__}: {e}",
                               "traceback": traceback.format_exc()[-2000:]}
                        print(f"[FAIL] {tag}: {type(e).__name__}: {e}",
                              flush=True)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    gc.collect()
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
