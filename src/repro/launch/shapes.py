"""The four assigned input shapes and their ShapeDtypeStruct stand-ins.

``input_specs(arch, shape, mesh)`` returns everything a dry-run needs:
the step kind (train / prefill / serve), argument ShapeDtypeStructs, and
in/out sharding specs — weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import sharding as SH
from repro.models import transformer as T
from repro.models.config import ATTN, ATTN_LOCAL, ArchConfig
from repro.launch.mesh import dp_axes


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _params_sds(cfg: ArchConfig) -> Any:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))


def _cache_sds(cfg: ArchConfig, batch: int, t_max: int, long_mode: bool):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch, t_max, long_mode))


def long_mode_for(cfg: ArchConfig, shape: InputShape) -> bool:
    """long_500k runs in long-context mode (serve-time SWA on full-attn
    archs, native windows/states elsewhere)."""
    return shape.name == "long_500k"


def input_specs(cfg: ArchConfig, shape: InputShape, mesh,
                axes: SH.MeshAxes | None = None) -> Dict[str, Any]:
    """Returns dict(kind, args=(SDS...), in_specs, out_specs, t_max)."""
    axes = axes or SH.MeshAxes(dp=dp_axes(mesh), tp="model")
    b, s = shape.global_batch, shape.seq_len
    p_sds = _params_sds(cfg)
    p_spec = SH.param_specs(cfg, mesh, axes)
    long_mode = long_mode_for(cfg, shape)
    n_prefix = cfg.num_patches if cfg.frontend != "none" else 0
    b_ax = SH._div(b, mesh, axes.dp)

    if shape.kind == "train":
        batch_sds = {
            "tokens": _sds((b, s - n_prefix), jnp.int32),
            "labels": _sds((b, s - n_prefix), jnp.int32),
        }
        batch_spec = {"tokens": P(b_ax, None), "labels": P(b_ax, None)}
        if n_prefix:
            batch_sds["prefix_embeds"] = _sds((b, n_prefix, cfg.d_model),
                                              jnp.bfloat16)
            batch_spec["prefix_embeds"] = P(b_ax, None, None)
        return dict(kind="train", cfg=cfg, params=p_sds, params_spec=p_spec,
                    args=(batch_sds,), args_spec=(batch_spec,),
                    long_mode=False, t_max=s)

    if shape.kind == "prefill":
        t_max = s
        tokens = _sds((b, s - n_prefix), jnp.int32)
        args = [tokens]
        args_spec = [P(b_ax, None)]
        if n_prefix:
            args.append(_sds((b, n_prefix, cfg.d_model), jnp.bfloat16))
            args_spec.append(P(b_ax, None, None))
        cache_spec = SH.cache_specs(cfg, mesh, b, long_mode=False, t_max=t_max, axes=axes)
        return dict(kind="prefill", cfg=cfg, params=p_sds, params_spec=p_spec,
                    args=tuple(args), args_spec=tuple(args_spec),
                    cache_spec=cache_spec, long_mode=False, t_max=t_max)

    # decode: ONE new token against a cache of seq_len.
    t_max = s
    cache_sds = _cache_sds(cfg, b, t_max, long_mode)
    cache_spec = SH.cache_specs(cfg, mesh, b, long_mode=long_mode, t_max=t_max, axes=axes)
    token = _sds((b,), jnp.int32)
    pos = _sds((), jnp.int32)
    return dict(kind="decode", cfg=cfg, params=p_sds, params_spec=p_spec,
                args=(cache_sds, token, pos),
                args_spec=(cache_spec, P(b_ax), P()),
                long_mode=long_mode, t_max=t_max)
