"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSONL records.

    PYTHONPATH=src python -m repro.launch.report \
        --baseline results/dryrun_baseline.jsonl \
        --unrolled results/dryrun_unrolled.jsonl
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.launch.roofline import enrich


def load(path: str) -> List[dict]:
    try:
        with open(path) as f:
            return [json.loads(l) for l in f if l.strip()]
    except FileNotFoundError:
        return []


def merge(baseline: List[dict], unrolled: List[dict]) -> Dict[tuple, dict]:
    """Prefer unrolled (exact cost_analysis) records for the single-pod
    roofline; baseline records prove multi-pod lowering."""
    recs = {}
    for r in baseline:
        if "error" not in r:
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    for r in unrolled:
        if "error" not in r:
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def roofline_table(recs: Dict[tuple, dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s (analytic) | collective s | "
        "bottleneck | MODEL/HLO flops | GiB/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh), r in sorted(recs.items()):
        if mesh != "16x16":
            continue
        r = enrich(r)
        lines.append(
            f"| {arch} | {shape} | {r['compute_term_s']:.4f} | "
            f"{r['memory_term_analytic_s']:.4f} | "
            f"{r['collective_term_wire_s']:.4f} | {r['bottleneck_analytic']} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['arg_bytes_per_device']/2**30:.2f} | "
            f"{'y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


def multipod_table(recs: Dict[tuple, dict]) -> str:
    lines = ["| arch | shape | 16x16 | 2x16x16 | collective bytes/dev (multi) |",
             "|---|---|---|---|---|"]
    archs = sorted({k[0] for k in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for arch in archs:
        for shape in shapes:
            single = (arch, shape, "16x16") in recs
            multi = (arch, shape, "2x16x16") in recs
            cb = recs.get((arch, shape, "2x16x16"), {}).get(
                "collective_bytes_per_device", 0)
            lines.append(f"| {arch} | {shape} | "
                         f"{'ok' if single else 'FAIL'} | "
                         f"{'ok' if multi else 'FAIL'} | {cb/2**20:.1f} MiB |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="results/dryrun_baseline.jsonl")
    ap.add_argument("--unrolled", default="results/dryrun_unrolled.jsonl")
    ap.add_argument("--section", default="all",
                    choices=["all", "roofline", "dryrun"])
    args = ap.parse_args()
    recs = merge(load(args.baseline), load(args.unrolled))
    if args.section in ("all", "roofline"):
        print("### Roofline (single-pod 16x16, 256 x v5e)\n")
        print(roofline_table(recs))
        print()
    if args.section in ("all", "dryrun"):
        print("### Dry-run lowering matrix\n")
        print(multipod_table(recs))


if __name__ == "__main__":
    main()
