"""Training driver: train any ``--arch`` (reduced or full) for N steps.

Reduced configs run real steps on CPU (the ~100M-scale end-to-end example);
full configs at production shapes are exercised via the dry-run.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, list_archs
from repro.training import AdamW, data_stream, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=list_archs())
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the FULL config (needs real accelerators)")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"params~{cfg.param_count()/1e6:.1f}M")

    opt = AdamW(lr=args.lr)
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    stream = data_stream(cfg, args.batch, args.seq, seed=0)

    t0 = time.perf_counter()
    for i in range(args.steps):
        state, metrics = step(state, next(stream))
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            tok_s = (i + 1) * args.batch * args.seq / dt
            print(f"step {i:4d}  loss {loss:.4f}  {tok_s:,.0f} tok/s")
    print("done.")


if __name__ == "__main__":
    main()
