"""Checkpointing: save/restore parameter + optimizer pytrees.

Self-contained .npz format (no orbax dependency): leaves are flattened with
jax.tree flatten order and stored with their tree structure fingerprint so a
mismatched restore fails loudly.  bf16 leaves round-trip via uint16 views
(npz has no native bfloat16).
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_BF16 = "bfloat16"


def _to_numpy(x) -> Tuple[np.ndarray, str]:
    arr = np.asarray(x)
    if str(arr.dtype) == _BF16:
        return arr.view(np.uint16), _BF16
    return arr, str(arr.dtype)


def save(path: str, tree: PyTree, *, step: int = 0) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {}
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr, dt = _to_numpy(leaf)
        payload[f"leaf_{i}"] = arr
        dtypes.append(dt)
    meta = {"treedef": str(treedef), "n_leaves": len(leaves),
            "dtypes": dtypes, "step": step}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, __meta__=json.dumps(meta), **payload)
    os.replace(tmp, path)


def restore(path: str, like: PyTree) -> Tuple[PyTree, int]:
    """Restore into the structure of ``like`` (shapes/dtypes verified)."""
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
        leaves_like, treedef = jax.tree.flatten(like)
        if meta["n_leaves"] != len(leaves_like):
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, expected "
                f"{len(leaves_like)}")
        if meta["treedef"] != str(treedef):
            raise ValueError("checkpoint tree structure mismatch")
        leaves = []
        for i, (ref, dt) in enumerate(zip(leaves_like, meta["dtypes"])):
            arr = data[f"leaf_{i}"]
            if dt == _BF16:
                arr = arr.view(jnp.bfloat16)
            if tuple(arr.shape) != tuple(np.shape(ref)):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != "
                    f"expected {np.shape(ref)}")
            leaves.append(jnp.asarray(arr))
        return jax.tree.unflatten(treedef, leaves), int(meta["step"])
