"""Minimal AdamW on parameter pytrees (fp32 moments, bf16 params)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> Tuple[PyTree, AdamWState]:
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-12))
        step = state.step + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - self.lr * delta).astype(p.dtype)
            return new_p, m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, m=new_m, v=new_v)
