"""Training substrate: AdamW optimizer + train-step builder + data stream."""
from repro.training.optimizer import AdamW, AdamWState
from repro.training.train import TrainState, data_stream, init_state, make_train_step
