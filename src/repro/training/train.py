"""Training step builder (used by the train_4k input shape, the end-to-end
training example, and the dry-run)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.training.optimizer import AdamW, AdamWState

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: AdamWState


def init_state(cfg: ArchConfig, key: jax.Array,
               optimizer: AdamW = AdamW()) -> TrainState:
    params = M.init_params(cfg, key)
    return TrainState(params=params, opt=optimizer.init(params))


def make_train_step(cfg: ArchConfig, optimizer: AdamW = AdamW()):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(state.params)
        new_params, new_opt = optimizer.update(grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def data_stream(cfg: ArchConfig, batch: int, seq_len: int, seed: int = 0):
    """Synthetic deterministic token pipeline (self-contained substrate)."""
    key = jax.random.PRNGKey(seed)
    i = 0
    while True:
        yield M.synthetic_batch(cfg, batch, seq_len, jax.random.fold_in(key, i))
        i += 1
