"""Runtime observability: lifecycle tracing + live metrics for the
serving stack (default **off**; a pure observer when on).

One :class:`Observability` object bundles the two capture surfaces —

* :class:`~repro.obs.tracer.Tracer` — request-lifecycle spans
  (QUEUED → PREFILL → DECODE → DONE, preempt/readmit) and machine
  phases on per-replica tracks, plus control-plane events (route picks
  with prefix-affinity score, replans with before/after plans, autoscale
  decisions), exportable as Chrome trace-event JSON
  (:func:`~repro.obs.export.chrome_trace`, loads in Perfetto);
* :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
  histograms with ring-buffer time series sampled at event-heap
  granularity (queue depth, KV occupancy + watermark, prefix hit rate,
  step-time EMA, tokens/s, preemptions), exportable as Prometheus text
  exposition (:func:`~repro.obs.export.prometheus_text`).

Wire-up::

    from repro.obs import Observability
    obs = Observability()
    runtime = ServingRuntime(plan, executor, obs=obs)
    result = runtime.run(trace)
    runtime.export_trace("trace.json")        # open in ui.perfetto.dev
    print(obs.prometheus_text())

or, online, ``repro.serve(spec, observability=True)`` and
``session.metrics()`` for a live snapshot while serving.

**Purity contract**: with observability enabled, the runtime's decisions
are byte-identical to a disabled run — the hooks only *read* runtime
state at commit points, never read the runtime clock (all timestamps are
passed in from already-measured values), and never touch RNG.  Admission
logs and per-request token streams are asserted identical on/off on both
backends in ``tests/test_observability.py``, and
``benchmarks/bench_observability.py`` holds the enabled-mode wall-clock
overhead under 2% on the CI shape.  The per-call cost of *disabled*
observability is one ``is None`` check at each instrumentation point.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.clock import TickClock
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               RingSeries)
from repro.obs.tracer import Tracer

__all__ = ["Observability", "Tracer", "MetricsRegistry", "TickClock",
           "Counter", "Gauge", "Histogram", "RingSeries",
           "CONTROL_TRACK", "WORKER_TRACK0"]

CONTROL_TRACK = 1000           # control-plane events (router/replan/scale)
WORKER_TRACK0 = 2000           # wall-clock actor-worker occupancy tracks


class _ReplicaHandles:
    """Pre-resolved metric objects for one replica — the hot hooks run
    per event-heap event, so they must not pay the registry's name+label
    formatting and lookup on every call (that alone blows the <2%
    overhead budget on small steps)."""

    __slots__ = ("label", "admissions", "prefill_s", "ttft", "decode_steps",
                 "decode_chunks", "decode_chunk_s", "preemptions",
                 "completed", "latency_s", "queue_depth", "active",
                 "step_ema", "kv_used", "kv_frac", "kv_watermark",
                 "prefix_hit", "gen_tokens", "tok_rate",
                 "swap_outs", "swap_ins", "swap_out_bytes", "swap_in_bytes",
                 "kv_host_used", "handoffs", "handoff_bytes")

    def __init__(self, m: MetricsRegistry, index: int):
        lbl = self.label = str(index)
        self.admissions = m.counter("admissions_total", replica=lbl)
        self.prefill_s = m.histogram("prefill_s", replica=lbl)
        self.ttft = m.histogram("ttft_s")
        self.decode_steps = m.counter("decode_steps_total", replica=lbl)
        self.decode_chunks = m.counter("decode_chunks_total", replica=lbl)
        self.decode_chunk_s = m.histogram("decode_chunk_s", replica=lbl)
        self.preemptions = m.counter("preemptions_total", replica=lbl)
        self.completed = m.counter("completed_total", replica=lbl)
        self.latency_s = m.histogram("latency_s")
        self.queue_depth = m.gauge("queue_depth", replica=lbl)
        self.active = m.gauge("active_requests", replica=lbl)
        self.step_ema = m.gauge("step_time_ema_s", replica=lbl)
        self.kv_used = m.gauge("kv_used_blocks", replica=lbl)
        self.kv_frac = m.gauge("kv_used_frac", replica=lbl)
        self.kv_watermark = m.gauge("kv_watermark_blocks", series=False,
                                    replica=lbl)
        # registered lazily so they only appear in snapshots when the
        # replica actually has a prefix cache / generates real tokens /
        # runs a host KV tier
        self.prefix_hit: Optional[Gauge] = None
        self.gen_tokens: Optional[Gauge] = None
        self.tok_rate: Optional[Gauge] = None
        self.swap_outs: Optional[Counter] = None
        self.swap_ins: Optional[Counter] = None
        self.swap_out_bytes: Optional[Counter] = None
        self.swap_in_bytes: Optional[Counter] = None
        self.kv_host_used: Optional[Gauge] = None
        self.handoffs: Optional[Counter] = None
        self.handoff_bytes: Optional[Counter] = None

    def swap_handles(self, m: MetricsRegistry
                     ) -> Tuple[Counter, Counter, Counter, Counter]:
        if self.swap_outs is None:
            self.swap_outs = m.counter("swap_outs_total", replica=self.label)
            self.swap_ins = m.counter("swap_ins_total", replica=self.label)
            self.swap_out_bytes = m.counter("swap_out_bytes_total",
                                            replica=self.label)
            self.swap_in_bytes = m.counter("swap_in_bytes_total",
                                           replica=self.label)
        return (self.swap_outs, self.swap_ins,
                self.swap_out_bytes, self.swap_in_bytes)

    def handoff_handles(self, m: MetricsRegistry) -> Tuple[Counter, Counter]:
        if self.handoffs is None:
            self.handoffs = m.counter("handoffs_total", replica=self.label)
            self.handoff_bytes = m.counter("handoff_bytes_total",
                                           replica=self.label)
        return self.handoffs, self.handoff_bytes


class Observability:
    """Tracer + metrics registry + the runtime's instrumentation hooks.

    The runtime calls the ``on_*`` / ``sample_*`` hooks below at its
    commit points (orchestrator thread) and from executor / worker
    threads for compute-time metrics; every hook receives the timestamps
    it records — :class:`Observability` never reads the runtime clock, so
    enabling it cannot perturb measured durations (see module docstring).
    """

    def __init__(self, *, series_capacity: int = 1024):
        self.tracer = Tracer()
        self.metrics = MetricsRegistry(series_capacity=series_capacity)
        self.wall_start: Optional[float] = None
        self._lock = threading.Lock()
        self._worker_tids: Dict[str, int] = {}
        # rid -> when the request last (re-)entered a queue (readmissions
        # after preemption; initial queued phases start at req.arrival)
        self._queued_since: Dict[int, float] = {}
        # rep -> (t, tokens) of the previous sample, for tokens/s gauges
        self._tok_last: Dict[int, Tuple[float, int]] = {}
        self._serving_t = 0.0
        # replica index -> pre-resolved metric handles (hot-path cache)
        self._rep: Dict[int, _ReplicaHandles] = {}
        # (replica, kind) -> (Histogram, Counter) for executor compute
        self._compute: Dict[Tuple[int, str], Tuple[Histogram, Counter]] = {}

    # ----------------------------------------------------------- lifecycle

    def begin_run(self, plan, *, live: bool = False) -> None:
        """Called once per ``run_source``; stamps the wall-time origin the
        worker occupancy tracks are measured against."""
        self.wall_start = time.perf_counter()
        self.tracer.track(CONTROL_TRACK, "control-plane")
        self.tracer.instant(CONTROL_TRACK, "run-start", 0.0, cat="run",
                            args={"live": bool(live),
                                  "replicas": len(plan.replicas)})

    def register_replica(self, index: int, config) -> None:
        self.tracer.track(index, f"replica-{index} ({config.key})")
        if index not in self._rep:
            self._rep[index] = _ReplicaHandles(self.metrics, index)

    def _handles(self, index: int) -> _ReplicaHandles:
        h = self._rep.get(index)
        if h is None:       # replica used without register_replica
            h = self._rep[index] = _ReplicaHandles(self.metrics, index)
        return h

    # --------------------------------------------- replica commit hooks
    # (orchestrator thread; ``rep`` is the ReplicaRuntime)

    def on_admit(self, rep, group: Sequence, t0: float,
                 offsets: Sequence[float]) -> None:
        """One admission group finished its prefill at ``t0 + offsets[-1]``."""
        t1 = t0 + offsets[-1]
        rids = [s.req.req_id for s in group]
        self.tracer.span(rep.index, f"prefill[B={len(group)}]", t0, t1,
                         cat="prefill", args={"req_ids": rids})
        h = self._handles(rep.index)
        h.admissions.inc(len(group))
        for s, off in zip(group, offsets):
            rid = s.req.req_id
            q0 = self._queued_since.pop(rid, s.req.arrival)
            self.tracer.async_span(rid, "queued", q0, t0,
                                   args={"req_id": rid,
                                         "replica": rep.index})
            self.tracer.async_span(rid, "prefill", t0, t0 + off,
                                   args={"req_id": rid,
                                         "preemptions": s.preemptions})
            h.ttft.observe(t0 + off - s.req.arrival)
        h.prefill_s.observe(offsets[-1])
        self.sample_replica(rep, t1)

    def on_decode_chunk(self, rep, batch: Sequence, k: int, t0: float,
                        t1: float) -> None:
        """One fused lockstep decode chunk committed."""
        self.tracer.span(rep.index, f"decode[k={k},B={len(batch)}]", t0, t1,
                         cat="decode", args={"k": k, "batch": len(batch)})
        h = self._handles(rep.index)
        h.decode_steps.inc(k)
        h.decode_chunks.inc()
        h.decode_chunk_s.observe(t1 - t0)
        self.sample_replica(rep, t1)

    def on_preempt(self, rep, state, t: float, *, swapped: bool = False,
                   swap_bytes: float = 0.0) -> None:
        """A request was evicted mid-decode at ``t`` — by recompute (its
        blocks were dropped) or, when ``swapped``, by copy-out to the host
        KV tier (``swap_bytes`` of KV left the device)."""
        rid = state.req.req_id
        self.tracer.instant(rep.index,
                            "swap-out" if swapped else "preempt", t,
                            cat="preempt",
                            args={"req_id": rid,
                                  "policy": rep.preempt_policy,
                                  "mode": "swap" if swapped else "recompute",
                                  "bytes": float(swap_bytes),
                                  "preemptions": state.preemptions})
        self.tracer.async_span(rid, "decode", state.first_token_at, t,
                               args={"req_id": rid, "preempted": True})
        self._queued_since[rid] = t
        h = self._handles(rep.index)
        h.preemptions.inc()
        if swapped:
            outs, _, out_bytes, _ = h.swap_handles(self.metrics)
            outs.inc()
            out_bytes.inc(float(swap_bytes))

    def on_swap_in(self, rep, group: Sequence, t0: float,
                   offsets: Sequence[float], *,
                   swap_bytes: float = 0.0) -> None:
        """One group of host-swapped requests was readmitted by restoring
        its KV blocks from the host tier (no prefill recompute)."""
        t1 = t0 + offsets[-1]
        rids = [s.req.req_id for s in group]
        self.tracer.span(rep.index, f"swapin[B={len(group)}]", t0, t1,
                         cat="swapin",
                         args={"req_ids": rids, "bytes": float(swap_bytes)})
        h = self._handles(rep.index)
        for s in group:
            rid = s.req.req_id
            q0 = self._queued_since.pop(rid, s.req.arrival)
            self.tracer.async_span(rid, "queued", q0, t0,
                                   args={"req_id": rid,
                                         "replica": rep.index})
        _, ins, _, in_bytes = h.swap_handles(self.metrics)
        ins.inc(len(group))
        in_bytes.inc(float(swap_bytes))
        self.sample_replica(rep, t1)

    def on_handoff(self, rep, group: Sequence, t0: float, t1: float, *,
                   blocks: int = 0, n_bytes: float = 0.0) -> None:
        """One group of prefill-finished requests exported its KV blocks
        toward decode-role replicas (prefill/decode disaggregation)."""
        rids = [s.req.req_id for s in group]
        self.tracer.span(rep.index, f"handoff[B={len(group)}]", t0, t1,
                         cat="handoff",
                         args={"req_ids": rids, "blocks": int(blocks),
                               "bytes": float(n_bytes)})
        h = self._handles(rep.index)
        count, out_bytes = h.handoff_handles(self.metrics)
        count.inc(len(group))
        out_bytes.inc(float(n_bytes))
        self.sample_replica(rep, t1)

    def on_finish(self, rep, state, t: float) -> None:
        rid = state.req.req_id
        if state.quota > 0:     # it decoded (not finished at first token)
            self.tracer.async_span(rid, "decode", state.first_token_at, t,
                                   args={"req_id": rid})
        self.tracer.instant(rep.index, "done", t, cat="lifecycle",
                            args={"req_id": rid})
        h = self._handles(rep.index)
        h.completed.inc()
        h.latency_s.observe(t - state.req.arrival)

    def sample_replica(self, rep, t: float) -> None:
        """Event-heap-granularity gauge sampling of one replica's load."""
        h = self._handles(rep.index)
        h.queue_depth.set(len(rep.queue), t=t)
        h.active.set(len(rep.active), t=t)
        h.step_ema.set(rep.executor.step_time_estimate(rep.index), t=t)
        mgr = rep.executor.kv_manager(rep.index)
        if mgr is not None:
            st = mgr.stats()
            h.kv_used.set(st["used_blocks"], t=t)
            h.kv_frac.set(st["used_frac"], t=t)
            h.kv_watermark.set(st["watermark"])
            if st["prefix_cache"]:
                if h.prefix_hit is None:
                    h.prefix_hit = self.metrics.gauge("prefix_hit_rate",
                                                      replica=h.label)
                h.prefix_hit.set(st["prefix_hit_rate"], t=t)
            if st.get("host_blocks", 0):
                if h.kv_host_used is None:
                    h.kv_host_used = self.metrics.gauge(
                        "kv_host_used_blocks", replica=h.label)
                h.kv_host_used.set(st["host_used_blocks"], t=t)
        tok = rep.executor.generated_tokens_for(rep.index)
        if tok:
            if h.gen_tokens is None:
                h.gen_tokens = self.metrics.gauge("generated_tokens_total",
                                                  replica=h.label)
                h.tok_rate = self.metrics.gauge("tokens_per_s",
                                                replica=h.label)
            h.gen_tokens.set(tok, t=t)
            last_t, last_tok = self._tok_last.get(rep.index, (0.0, 0))
            if t > last_t:
                h.tok_rate.set((tok - last_tok) / (t - last_t), t=t)
            self._tok_last[rep.index] = (t, tok)
        with self._lock:
            self._serving_t = max(self._serving_t, t)

    # ------------------------------------------------ control-plane hooks

    def on_route(self, t: float, req, replica: Optional[int],
                 warmth: Optional[int], fallback: bool) -> None:
        """Router pick (``replica is None`` = dropped as unroutable)."""
        args = {"req_id": req.req_id, "model": req.model,
                "workload": req.workload, "replica": replica,
                "fallback": bool(fallback)}
        if warmth is not None:
            args["prefix_warmth"] = int(warmth)
        self.tracer.instant(CONTROL_TRACK,
                            "drop" if replica is None else "route",
                            t, cat="router", args=args)
        self.metrics.counter("dropped_total" if replica is None
                             else "routed_total").inc()

    def on_replan(self, t: float, before: List[str], after: List[str],
                  *, migrated: int, kept: int) -> None:
        self.tracer.instant(CONTROL_TRACK, "replan", t, cat="replan",
                            args={"before": before, "after": after,
                                  "migrated": migrated, "kept": kept})
        self.metrics.counter("replans_total").inc()

    def on_scale_decision(self, t: float, decision,
                          before: List[str]) -> None:
        """One autoscale action (the before plan is the live pool; the
        after plan is ``decision.plan``)."""
        self.tracer.instant(
            CONTROL_TRACK, f"autoscale-{decision.action}", t,
            cat="autoscale",
            args={"action": decision.action, "config": decision.config_key,
                  "reason": decision.reason, "before": before,
                  "after": [c.key for c in decision.plan.replicas]})
        self.metrics.counter("autoscale_total",
                             action=decision.action).inc()

    def on_scale_observe(self, t: float, queue_depth: float,
                         kv_util: float) -> None:
        """One ScalePolicy observation tick (decision or not)."""
        m = self.metrics
        m.gauge("autoscale_queue_depth").set(queue_depth, t=t)
        m.gauge("autoscale_kv_util").set(kv_util, t=t)

    # ----------------------------------------------------------- fault hooks

    def on_fault(self, t: float, kind: str, gpu_type: str,
                 victims: Sequence[int]) -> None:
        """One injected fault event applied (``victims`` are the replica
        indices torn down; empty for recoveries)."""
        self.tracer.instant(CONTROL_TRACK, f"fault-{kind}", t, cat="fault",
                            args={"kind": kind, "gpu_type": gpu_type,
                                  "victims": list(victims)})
        self.metrics.counter("faults_total", kind=kind).inc()

    def on_replica_dead(self, index: int, t: float) -> None:
        """A replica was torn down by a fault (or a wedged worker) at
        ``t``; it stays down for the rest of the run, so its downtime is
        the gap from this instant to the trace end (recomputed per
        replica by ``tools/trace_summarize.py``)."""
        self.tracer.instant(index, "dead", t, cat="fault",
                            args={"replica": index})
        self.metrics.counter("replicas_lost_total").inc()
        self.metrics.gauge("replica_down_since_s", series=False,
                           replica=str(index)).set(t)

    def on_worker_failure(self, index: int, t: float, error: str) -> None:
        """An executor call on replica ``index``'s worker raised (or hit
        its ``call_timeout``) — surfaced as a structured failure."""
        self.tracer.instant(CONTROL_TRACK, "worker-failure", t,
                            cat="fault",
                            args={"replica": index, "error": error})
        self.metrics.counter("worker_failures_total").inc()

    def on_request_failed(self, t: float, req, retries: int) -> None:
        """The runtime gave up on a request (retry budget exhausted or
        orphaned at run end)."""
        self.tracer.instant(CONTROL_TRACK, "request-failed", t,
                            cat="fault",
                            args={"req_id": req.req_id,
                                  "retries": int(retries)})
        self.metrics.counter("requests_failed_total").inc()

    # ------------------------------------------- executor / worker hooks
    # (may run on per-replica worker threads)

    def on_compute(self, rep: int, kind: str, seconds: float) -> None:
        """One executor call's duration — *measured wall* seconds on the
        engine backend, *modeled* seconds on the cost backend."""
        pair = self._compute.get((rep, kind))
        if pair is None:    # registry dedups, so a racing double-create
            pair = (        # from two worker threads resolves identically
                self.metrics.histogram("compute_s", replica=str(rep),
                                       kind=kind),
                self.metrics.counter("executor_calls_total",
                                     replica=str(rep), kind=kind))
            self._compute[(rep, kind)] = pair
        pair[0].observe(seconds)
        pair[1].inc()

    def on_worker_task(self, name: str, wall_t0: float,
                       wall_t1: float) -> None:
        """One actor-worker task's **wall-clock** occupancy (its own time
        base: ``time.perf_counter`` seconds since ``begin_run`` — these
        tracks show real overlap across workers, next to the replicas'
        serving-time tracks)."""
        origin = self.wall_start
        if origin is None:
            return
        with self._lock:
            tid = self._worker_tids.get(name)
            if tid is None:
                tid = WORKER_TRACK0 + len(self._worker_tids)
                self._worker_tids[name] = tid
                self.tracer.track(tid, f"{name} (wall)")
        self.tracer.span(tid, "task", wall_t0 - origin, wall_t1 - origin,
                         cat="wall")

    # -------------------------------------------------------------- export

    def snapshot(self) -> Dict[str, object]:
        """Live point-in-time view: every metric plus derived rates."""
        snap = self.metrics.snapshot()
        with self._lock:
            serving_t = self._serving_t
            total_tokens = sum(tok for _, tok in self._tok_last.values())
        snap["serving_time_s"] = serving_t
        if total_tokens:
            snap["generated_tokens"] = total_tokens
            if serving_t > 0:
                snap["tokens_per_s_overall"] = total_tokens / serving_t
        snap["trace_records"] = self.tracer.num_records
        return snap

    def chrome_trace(self) -> Dict[str, object]:
        from repro.obs.export import chrome_trace
        return chrome_trace(self)

    def export_chrome_trace(self, path: str) -> str:
        from repro.obs.export import write_chrome_trace
        return write_chrome_trace(self, path)

    def prometheus_text(self) -> str:
        from repro.obs.export import prometheus_text
        return prometheus_text(self.metrics)
