"""Injectable clocks for the serving runtime.

The engine backend schedules on *measured* wall time: every
``EngineExecutor`` prefill/decode brackets its jit call with
``t0 = clock(); ...; elapsed = clock() - t0`` and the replica clock
advances by ``elapsed``.  With the default ``time.perf_counter`` a loaded
machine stretches those measurements, which can shift admission cohorts —
the pre-existing load-sensitive flake in the decode-fusion equivalence
tests.  ``EngineExecutor(clock=...)`` (and ``ServingRuntime(clock=...)``,
which forwards to the executor) is the seam: tests pin a
:class:`TickClock` so every measured duration — and every trace
timestamp derived from it — is deterministic under any machine load.
"""
from __future__ import annotations

import threading

__all__ = ["TickClock"]


class TickClock:
    """Deterministic monotone clock: every call advances by ``tick``.

    An ``elapsed = clock() - t0`` bracket therefore measures exactly
    ``tick`` times the number of clock calls in between (one, for an
    uninstrumented executor call) — independent of machine load, sleep,
    or scheduling jitter.  Thread-safe: concurrent replica workers share
    one monotone sequence, and per-bracket durations stay deterministic
    as long as each thread's brackets do not interleave other threads'
    clock calls (the observability layer never reads the runtime clock,
    precisely to keep this property — see ``repro.obs.tracer``).
    """

    def __init__(self, tick: float = 1e-4, start: float = 0.0):
        if tick <= 0:
            raise ValueError(f"tick must be > 0, got {tick}")
        self.tick = float(tick)
        self._t = float(start)
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            self._t += self.tick
            return self._t

    @property
    def now(self) -> float:
        """Last value handed out (no advance)."""
        with self._lock:
            return self._t
