"""Low-overhead metrics primitives for the serving runtime.

A :class:`MetricsRegistry` hands out three metric kinds —
:class:`Counter` (monotone totals: preemptions, admissions, routed
requests), :class:`Gauge` (point-in-time values: queue depth, KV
occupancy, step-time EMA), and :class:`Histogram` (distributions:
TTFT, prefill/decode durations) — keyed by name + label set, exactly
the Prometheus data model.  Every gauge additionally keeps a bounded
:class:`RingSeries` of ``(t, value)`` samples so runs can be inspected
*over time* (the runtime samples at event-heap granularity), without
unbounded growth on long-lived sessions: the ring drops its oldest
samples once ``capacity`` is reached and counts what it dropped.

Thread model: metric mutation happens from the orchestrator thread and
(for executor-side compute metrics) per-replica worker threads; a single
registry lock serializes creation, mutation, and :meth:`snapshot`, so a
live ``Session.metrics()`` call always sees a consistent view.  The lock
is uncontended at event granularity — the runtime emits a handful of
updates per *event*, not per token — which is what keeps the enabled-mode
overhead inside the <2% budget (``benchmarks/bench_observability.py``
measures it).
"""
from __future__ import annotations

import bisect
import collections
import math
import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "RingSeries", "DEFAULT_BUCKETS"]

# Exponential-ish latency buckets (seconds) covering jit dispatch (~100us)
# through multi-minute makespans — the Prometheus ``le`` upper bounds.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 300.0)


class RingSeries:
    """Bounded ``(t, value)`` time series (oldest samples drop first)."""

    __slots__ = ("_buf", "appended")

    def __init__(self, capacity: int):
        self._buf: "collections.deque[Tuple[float, float]]" = \
            collections.deque(maxlen=max(1, int(capacity)))
        self.appended = 0          # lifetime appends (dropped = appended-len)

    def append(self, t: float, value: float) -> None:
        self._buf.append((float(t), float(value)))
        self.appended += 1

    @property
    def dropped(self) -> int:
        return self.appended - len(self._buf)

    def items(self) -> List[Tuple[float, float]]:
        return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)


class Counter:
    """Monotone total.  ``inc`` only — resets happen by new registry."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Point-in-time value; ``set(v, t=...)`` also samples the series."""

    __slots__ = ("_lock", "value", "series")

    def __init__(self, lock: threading.RLock,
                 series: Optional[RingSeries] = None):
        self._lock = lock
        self.value = math.nan
        self.series = series

    def set(self, value: float, t: Optional[float] = None) -> None:
        with self._lock:
            self.value = float(value)
            if t is not None and self.series is not None:
                self.series.append(t, value)


class Histogram:
    """Fixed-bucket distribution (Prometheus-style cumulative buckets)."""

    __slots__ = ("_lock", "bounds", "counts", "sum", "count")

    def __init__(self, lock: threading.RLock,
                 bounds: Sequence[float] = DEFAULT_BUCKETS):
        self._lock = lock
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # last = +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        with self._lock:
            self.counts[bisect.bisect_left(self.bounds, value)] += 1
            self.sum += value
            self.count += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        the ``q``-th observation falls in; NaN when empty)."""
        if not self.count:
            return math.nan
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return self.bounds[i] if i < len(self.bounds) else math.inf
        return math.inf


def _key(name: str, labels: Dict[str, str]) -> str:
    """Canonical ``name{k="v",...}`` identity (sorted label keys)."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Name+label-addressed metric store with a consistent snapshot."""

    def __init__(self, *, series_capacity: int = 1024):
        self.series_capacity = int(series_capacity)
        self._lock = threading.RLock()
        self._metrics: "collections.OrderedDict[str, tuple]" = \
            collections.OrderedDict()   # key -> (kind, name, labels, metric)

    # ------------------------------------------------------------- factories

    def _get(self, kind: str, name: str, labels: Dict[str, str], build):
        key = _key(name, labels)
        with self._lock:
            entry = self._metrics.get(key)
            if entry is None:
                entry = (kind, name, dict(labels), build())
                self._metrics[key] = entry
            elif entry[0] != kind:
                raise TypeError(f"metric {key!r} already registered as "
                                f"{entry[0]}, not {kind}")
            return entry[3]

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get("counter", name, labels,
                         lambda: Counter(self._lock))

    def gauge(self, name: str, *, series: bool = True,
              **labels: str) -> Gauge:
        return self._get("gauge", name, labels, lambda: Gauge(
            self._lock,
            RingSeries(self.series_capacity) if series else None))

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels: str) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(self._lock, buckets))

    # --------------------------------------------------------------- queries

    def walk(self) -> Iterator[Tuple[str, str, Dict[str, str], object]]:
        """Yield ``(kind, name, labels, metric)`` in registration order
        (a consistent copy — safe to iterate while serving)."""
        with self._lock:
            entries = list(self._metrics.values())
        return iter(entries)

    def snapshot(self) -> Dict[str, object]:
        """One consistent point-in-time view: counters and gauges as
        scalars, histograms as ``{count, sum, mean, p50, p90, p99}``."""
        out: Dict[str, object] = {}
        with self._lock:
            for key, (kind, _name, _labels, m) in self._metrics.items():
                if kind == "counter":
                    out[key] = m.value
                elif kind == "gauge":
                    out[key] = m.value
                else:
                    out[key] = {"count": m.count, "sum": m.sum,
                                "mean": m.mean,
                                "p50": m.quantile(0.50),
                                "p90": m.quantile(0.90),
                                "p99": m.quantile(0.99)}
        return out

    def series(self) -> Dict[str, List[Tuple[float, float]]]:
        """Every gauge's ring-buffer time series, keyed like the snapshot."""
        out: Dict[str, List[Tuple[float, float]]] = {}
        with self._lock:
            for key, (kind, _n, _l, m) in self._metrics.items():
                if kind == "gauge" and m.series is not None and len(m.series):
                    out[key] = m.series.items()
        return out
