"""Trace and metrics exporters.

* :func:`chrome_trace` — Chrome trace-event JSON (the ``traceEvents``
  array format) from an :class:`~repro.obs.Observability` capture: load
  the written file in Perfetto (https://ui.perfetto.dev) or
  ``chrome://tracing`` to see one track per replica (prefill groups and
  fused decode chunks as nested ``X`` spans, preemptions as instants),
  one control-plane track (route picks, replans, autoscale decisions),
  per-request QUEUED/PREFILL/DECODE async spans, wall-clock worker
  occupancy tracks, and every gauge ring-series as a Perfetto counter
  track.
* :func:`prometheus_text` — Prometheus text exposition (version 0.0.4)
  of a :class:`~repro.obs.metrics.MetricsRegistry`: counters, gauges,
  and cumulative-bucket histograms, ready to serve from a ``/metrics``
  endpoint or push through a textfile collector.

Runtime timestamps are seconds; Chrome events use microseconds.
"""
from __future__ import annotations

import json
import math
import re
from typing import Dict, List

from repro.obs.metrics import MetricsRegistry

__all__ = ["chrome_trace", "write_chrome_trace", "prometheus_text"]

_US = 1e6
PID = 0                      # one logical "serving" process


def _args(d) -> dict:
    return d if d else {}


def chrome_trace(obs) -> Dict[str, object]:
    """Chrome trace-event document for an Observability capture."""
    tracer = obs.tracer
    events: List[dict] = [{
        "ph": "M", "pid": PID, "name": "process_name", "ts": 0,
        "args": {"name": "repro-serving"}}]
    with tracer._lock:
        track_names = dict(tracer.track_names)
        spans = list(tracer.spans)
        instants = list(tracer.instants)
        asyncs = list(tracer.asyncs)
    for tid, name in sorted(track_names.items()):
        events.append({"ph": "M", "pid": PID, "tid": tid, "ts": 0,
                       "name": "thread_name", "args": {"name": name}})
        # sort_index keeps replicas on top, control plane and wall-time
        # worker tracks below, in registration order
        events.append({"ph": "M", "pid": PID, "tid": tid, "ts": 0,
                       "name": "thread_sort_index",
                       "args": {"sort_index": tid}})
    body: List[dict] = []
    for tid, name, t0, t1, cat, args in spans:
        body.append({"ph": "X", "pid": PID, "tid": tid, "name": name,
                     "cat": cat, "ts": t0 * _US,
                     "dur": max(0.0, (t1 - t0) * _US),
                     "args": _args(args)})
    for tid, name, t, cat, args in instants:
        body.append({"ph": "i", "pid": PID, "tid": tid, "name": name,
                     "cat": cat, "ts": t * _US, "s": "t",
                     "args": _args(args)})
    for phase, rid, name, t, args in asyncs:
        body.append({"ph": phase, "pid": PID, "tid": 0, "cat": "request",
                     "id": rid, "name": name, "ts": t * _US,
                     "args": _args(args)})
    for key, points in obs.metrics.series().items():
        for t, v in points:
            body.append({"ph": "C", "pid": PID, "tid": 0, "name": key,
                         "ts": t * _US, "args": {"value": v}})
    body.sort(key=lambda e: e["ts"])
    events.extend(body)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.obs",
                          "spans": len(spans), "instants": len(instants),
                          "async_events": len(asyncs)}}


def write_chrome_trace(obs, path: str) -> str:
    """Serialize :func:`chrome_trace` to ``path``; returns ``path``."""
    with open(path, "w") as f:
        json.dump(chrome_trace(obs), f)
    return path


# ---------------------------------------------------------------- prometheus

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    return name if not name[:1].isdigit() else "_" + name


def _prom_labels(labels: Dict[str, str], extra: str = "") -> str:
    parts = [f'{_prom_name(k)}="{labels[k]}"' for k in sorted(labels)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _prom_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def prometheus_text(registry: MetricsRegistry) -> str:
    """Prometheus text exposition of every metric in the registry."""
    lines: List[str] = []
    typed = set()
    for kind, name, labels, m in registry.walk():
        pname = _prom_name(name)
        if pname not in typed:
            typed.add(pname)
            lines.append(f"# TYPE {pname} {kind}")
        if kind == "counter":
            lines.append(f"{pname}{_prom_labels(labels)} "
                         f"{_prom_value(m.value)}")
        elif kind == "gauge":
            lines.append(f"{pname}{_prom_labels(labels)} "
                         f"{_prom_value(m.value)}")
        else:   # histogram: cumulative le-buckets + _sum/_count
            cum = 0
            for bound, count in zip(m.bounds, m.counts):
                cum += count
                le = 'le="{}"'.format(_prom_value(bound))
                lines.append(f"{pname}_bucket{_prom_labels(labels, le)} "
                             f"{cum}")
            cum += m.counts[-1]
            inf_le = 'le="+Inf"'
            lines.append(f"{pname}_bucket{_prom_labels(labels, inf_le)} "
                         f"{cum}")
            lines.append(f"{pname}_sum{_prom_labels(labels)} "
                         f"{_prom_value(m.sum)}")
            lines.append(f"{pname}_count{_prom_labels(labels)} {m.count}")
    return "\n".join(lines) + "\n"
