"""Structured span/event recorder for the serving runtime.

The :class:`Tracer` is a pure buffer: instrumentation points hand it
**already-known timestamps** (replica clocks, event durations, arrival
stamps — the runtime's serving-time axis) and it appends tuples under a
lock.  It never reads a clock itself, which is what makes tracing a pure
observer: enabling it adds no clock calls between an executor's
``t0 = clock(); ...; elapsed = clock() - t0`` pairs, so measured
durations — and therefore admission cohorts and token streams — are
byte-identical with tracing on or off (asserted in
``tests/test_observability.py``).

Three record kinds, matching the Chrome trace-event phases the exporter
emits (:mod:`repro.obs.export`):

* **spans** (``ph: "X"``) — machine-phase intervals on a *track* (one
  track per replica, one for the control plane, one per actor worker):
  prefill groups, fused decode chunks, worker wall-time occupancy.
  Spans on one track never overlap (each replica executes one event at
  a time), so Perfetto renders each track as a clean timeline.
* **instants** (``ph: "i"``) — points: preemptions, route picks, replans,
  autoscale decisions.
* **async request phases** (``ph: "b"``/``"e"``, ``id=req_id``) — each
  request's QUEUED → PREFILL → DECODE lifecycle as overlapping async
  spans (requests on one replica overlap freely; async events carry
  their own id, so they may).

Times are seconds on the runtime's time base; the exporter converts to
the microseconds Chrome/Perfetto expect.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["Tracer", "Span", "Instant", "AsyncEvent"]

# record tuples (plain tuples: appended per event, kept cheap)
Span = Tuple[int, str, float, float, str, Optional[dict]]
#      (track, name, t0, t1, category, args)
Instant = Tuple[int, str, float, str, Optional[dict]]
#      (track, name, t, category, args)
AsyncEvent = Tuple[str, int, str, float, Optional[dict]]
#      (phase "b"|"e", id, name, t, args)


class Tracer:
    """Append-only trace buffer with named tracks."""

    def __init__(self):
        self._lock = threading.Lock()
        self.track_names: Dict[int, str] = {}
        self.spans: List[Span] = []
        self.instants: List[Instant] = []
        self.asyncs: List[AsyncEvent] = []

    # -------------------------------------------------------------- tracks

    def track(self, tid: int, name: str) -> int:
        """Register (or rename) display track ``tid``; returns ``tid``."""
        with self._lock:
            self.track_names[tid] = name
        return tid

    # ------------------------------------------------------------- records

    def span(self, tid: int, name: str, t0: float, t1: float,
             cat: str = "phase", args: Optional[dict] = None) -> None:
        with self._lock:
            self.spans.append((tid, name, float(t0), float(t1), cat, args))

    def instant(self, tid: int, name: str, t: float, cat: str = "event",
                args: Optional[dict] = None) -> None:
        with self._lock:
            self.instants.append((tid, name, float(t), cat, args))

    def async_span(self, rid: int, name: str, t0: float, t1: float,
                   args: Optional[dict] = None) -> None:
        """One complete request-phase interval (begin + end in one call —
        lifecycle phases are recorded retroactively, once their end time
        is known; the exporter orders events by timestamp)."""
        with self._lock:
            self.asyncs.append(("b", rid, name, float(t0), args))
            self.asyncs.append(("e", rid, name, float(t1), None))

    # -------------------------------------------------------------- queries

    @property
    def num_records(self) -> int:
        with self._lock:
            return len(self.spans) + len(self.instants) + len(self.asyncs)

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.instants.clear()
            self.asyncs.clear()
