"""Workload-assignment router: dispatches requests to replicas according to
the plan's fractional assignment x_{c,w} (§4.3), with deterministic
low-discrepancy rounding so realized fractions track the plan."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.plan import ServingPlan
from repro.core.workloads import Request


class AssignmentRouter:
    """Routes each request to a replica index per the plan's x matrix."""

    def __init__(self, plan: ServingPlan):
        self.plan = plan
        self._index = {(m, w): d for d, (m, w, _) in enumerate(plan.demands)}
        # deficit-round-robin credit per (demand, replica)
        self._credit = np.zeros_like(plan.assignment)

    def route(self, req: Request) -> int:
        d = self._index.get((req.model, req.workload))
        if d is None:
            return req.req_id % max(len(self.plan.replicas), 1)
        probs = np.clip(self.plan.assignment[:, d], 0, None)
        total = probs.sum()
        if total <= 0:
            return req.req_id % len(self.plan.replicas)
        self._credit[:, d] += probs / total
        i = int(np.argmax(self._credit[:, d]))
        self._credit[i, d] -= 1.0
        return i

    def realized_fractions(self) -> np.ndarray:
        """How far realized routing drifted from the plan (for tests)."""
        return self._credit
