"""Workload-assignment router (compatibility re-export).

The implementation moved to ``repro.runtime.router`` so the simulator and
the real-token server share one dispatch path; import it from there in new
code.  Fallback routing for uncovered demands is now model-aware: requests
only ever land on replicas serving their model.
"""
from repro.runtime.router import AssignmentRouter

__all__ = ["AssignmentRouter"]
