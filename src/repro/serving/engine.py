"""Replica engine: jit'd prefill + decode over one model replica.

The engine executes real token generation (used by the CPU end-to-end
examples and the runtime tests).  Requests are bucketed by prompt length so a
batch shares one prefill shape; decode runs greedy with a shared position
counter (continuous batching across buckets happens in the server layer).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ArchConfig

# Prefill compilations are cached per t_max; distinct prompt+generation
# budgets used to pin one compiled function each, forever.  Rounding t_max
# up to the next power of two collapses the distinct shapes to O(log T)
# buckets, and the shared-LRU bound below caps total retained compilations.
MIN_T_BUCKET = 16


def bucket_t_max(t_max: int) -> int:
    """Round a requested cache length up to a power-of-two bucket."""
    b = MIN_T_BUCKET
    while b < t_max:
        b *= 2
    return b


# Jitted callables are pure in (params, inputs), so replicas of the same
# architecture share them: N same-model replicas compile once instead of N
# times, and a replica added mid-run by the autoscaler joins *warm* — no
# compile latency lands on its measured clock.  Keyed by ArchConfig value
# (hashable frozen dataclass) + mode; LRU-bounded like the per-engine
# prefill cache.  The lock only guards the dict (wrapper creation is lazy;
# XLA compilation happens at first call, outside it) — concurrent replica
# workers hit this on every prefill-bucket lookup.
SHARED_JIT_MAX = 64
_shared_jit_cache: "collections.OrderedDict[tuple, object]" = \
    collections.OrderedDict()
_shared_jit_lock = threading.Lock()


def _shared_jit(key: tuple, make):
    with _shared_jit_lock:
        fn = _shared_jit_cache.get(key)
        if fn is not None:
            _shared_jit_cache.move_to_end(key)
            return fn
        fn = _shared_jit_cache[key] = make()
        while len(_shared_jit_cache) > SHARED_JIT_MAX:
            _shared_jit_cache.popitem(last=False)
    return fn


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, max_new)
    prefill_s: float
    decode_s: float

    @property
    def new_tokens(self) -> int:
        return int(self.tokens.size)


class ReplicaEngine:
    """One model replica with jit-compiled prefill/decode."""

    def __init__(self, cfg: ArchConfig, params=None, *, seed: int = 0,
                 long_mode: bool = False, device=None):
        self.cfg = cfg
        self.long_mode = long_mode
        self.device = device
        self.params = params if params is not None else M.init_params(
            cfg, jax.random.PRNGKey(seed))
        if device is not None:
            # One accelerator per replica: computations follow the params'
            # placement, so concurrent replicas execute on distinct devices.
            self.params = jax.device_put(self.params, device)
        self._step = _shared_jit(
            ("step", cfg, long_mode),
            lambda: jax.jit(functools.partial(M.decode_step, cfg,
                                              long_mode=long_mode)))
        self._paged_step = None

    def _prefill_fn(self, t_max: int):
        """Compiled prefill for the power-of-two bucket covering ``t_max``
        (bounded LRU, shared across same-arch replicas — see
        ``bucket_t_max`` / ``_shared_jit``).  The returned caches are sized
        to the bucket; callers treat ``t_max`` as a lower bound."""
        bucket = bucket_t_max(t_max)
        return _shared_jit(
            ("prefill", self.cfg, self.long_mode, bucket),
            lambda: jax.jit(functools.partial(M.prefill, self.cfg,
                                              t_max=bucket,
                                              long_mode=self.long_mode)))

    def prefill_batch(self, prompts: jax.Array, t_max: int,
                      prefix_embeds: Optional[jax.Array] = None):
        """Run prefill for one batch; returns (first_token, caches).

        This is the incremental entry point the runtime's
        ``EngineExecutor`` uses for continuous batching: one admission
        cohort shares a prefill shape and its caches decode in lockstep via
        :meth:`decode_batch`.
        """
        logits, caches = self._prefill_fn(t_max)(self.params, prompts,
                                                 prefix_embeds)
        return M.greedy_sample(logits[:, -1]), caches

    def decode_batch(self, caches, tok: jax.Array, pos: int):
        """One greedy decode step for a batch; returns (next_token, caches)."""
        logits, caches = self._step(self.params, caches, tok,
                                    jnp.asarray(pos, jnp.int32))
        return M.greedy_sample(logits), caches

    @property
    def paged_supported(self) -> bool:
        return M.paged_supported(self.cfg)

    def paged_decode(self, pools, block_tables: jax.Array,
                     lengths: jax.Array, tok: jax.Array):
        """One greedy lockstep step over every slot of a paged replica;
        returns (next_token (S,), new_pools).  Shape-stable: one compile
        per replica regardless of which slots are live."""
        if self._paged_step is None:
            self._paged_step = _shared_jit(
                ("paged", self.cfg),
                lambda: jax.jit(functools.partial(M.paged_decode_step,
                                                  self.cfg)))
        logits, pools = self._paged_step(self.params, pools, block_tables,
                                         lengths, tok)
        return M.greedy_sample(logits), pools

    def generate(self, prompts: jax.Array, max_new: int,
                 prefix_embeds: Optional[jax.Array] = None
                 ) -> GenerationResult:
        """prompts: (B, S) int32.  Greedy decode for max_new tokens."""
        b, s = prompts.shape
        n_prefix = prefix_embeds.shape[1] if prefix_embeds is not None else 0
        t_max = s + n_prefix + max_new
        t0 = time.perf_counter()
        tok, caches = self.prefill_batch(prompts, t_max, prefix_embeds)
        jax.block_until_ready(tok)
        t1 = time.perf_counter()
        out = [tok]
        pos = s + n_prefix
        for i in range(max_new - 1):
            tok, caches = self.decode_batch(caches, tok, pos + i)
            out.append(tok)
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        return GenerationResult(tokens=np.stack([np.asarray(t) for t in out], 1),
                                prefill_s=t1 - t0, decode_s=t2 - t1)
