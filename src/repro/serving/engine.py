"""Replica engine: jit'd prefill + decode over one model replica.

The engine executes real token generation (used by the CPU end-to-end
examples and the runtime tests).  Requests are bucketed by prompt length so a
batch shares one prefill shape; decode runs greedy with a shared position
counter (continuous batching across buckets happens in the server layer).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ArchConfig

# Prefill compilations are cached per t_max; distinct prompt+generation
# budgets used to pin one compiled function each, forever.  Rounding t_max
# up to the next power of two collapses the distinct shapes to O(log T)
# buckets, and the LRU bound caps total retained compilations.
PREFILL_CACHE_MAX = 8
MIN_T_BUCKET = 16


def bucket_t_max(t_max: int) -> int:
    """Round a requested cache length up to a power-of-two bucket."""
    b = MIN_T_BUCKET
    while b < t_max:
        b *= 2
    return b


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, max_new)
    prefill_s: float
    decode_s: float

    @property
    def new_tokens(self) -> int:
        return int(self.tokens.size)


class ReplicaEngine:
    """One model replica with jit-compiled prefill/decode."""

    def __init__(self, cfg: ArchConfig, params=None, *, seed: int = 0,
                 long_mode: bool = False):
        self.cfg = cfg
        self.long_mode = long_mode
        self.params = params if params is not None else M.init_params(
            cfg, jax.random.PRNGKey(seed))
        self._prefill: "collections.OrderedDict[int, object]" = \
            collections.OrderedDict()
        self._step = jax.jit(
            functools.partial(M.decode_step, cfg, long_mode=long_mode))
        self._paged_step = None

    def _prefill_fn(self, t_max: int):
        """Compiled prefill for the power-of-two bucket covering ``t_max``
        (bounded LRU — see ``bucket_t_max``).  The returned caches are
        sized to the bucket; callers treat ``t_max`` as a lower bound."""
        bucket = bucket_t_max(t_max)
        if bucket in self._prefill:
            self._prefill.move_to_end(bucket)
        else:
            self._prefill[bucket] = jax.jit(
                functools.partial(M.prefill, self.cfg, t_max=bucket,
                                  long_mode=self.long_mode))
            while len(self._prefill) > PREFILL_CACHE_MAX:
                self._prefill.popitem(last=False)
        return self._prefill[bucket]

    def prefill_batch(self, prompts: jax.Array, t_max: int,
                      prefix_embeds: Optional[jax.Array] = None):
        """Run prefill for one batch; returns (first_token, caches).

        This is the incremental entry point the runtime's
        ``EngineExecutor`` uses for continuous batching: one admission
        cohort shares a prefill shape and its caches decode in lockstep via
        :meth:`decode_batch`.
        """
        logits, caches = self._prefill_fn(t_max)(self.params, prompts,
                                                 prefix_embeds)
        return M.greedy_sample(logits[:, -1]), caches

    def decode_batch(self, caches, tok: jax.Array, pos: int):
        """One greedy decode step for a batch; returns (next_token, caches)."""
        logits, caches = self._step(self.params, caches, tok,
                                    jnp.asarray(pos, jnp.int32))
        return M.greedy_sample(logits), caches

    @property
    def paged_supported(self) -> bool:
        return M.paged_supported(self.cfg)

    def paged_decode(self, pools, block_tables: jax.Array,
                     lengths: jax.Array, tok: jax.Array):
        """One greedy lockstep step over every slot of a paged replica;
        returns (next_token (S,), new_pools).  Shape-stable: one compile
        per replica regardless of which slots are live."""
        if self._paged_step is None:
            self._paged_step = jax.jit(
                functools.partial(M.paged_decode_step, self.cfg))
        logits, pools = self._paged_step(self.params, pools, block_tables,
                                         lengths, tok)
        return M.greedy_sample(logits), pools

    def generate(self, prompts: jax.Array, max_new: int,
                 prefix_embeds: Optional[jax.Array] = None
                 ) -> GenerationResult:
        """prompts: (B, S) int32.  Greedy decode for max_new tokens."""
        b, s = prompts.shape
        n_prefix = prefix_embeds.shape[1] if prefix_embeds is not None else 0
        t_max = s + n_prefix + max_new
        t0 = time.perf_counter()
        tok, caches = self.prefill_batch(prompts, t_max, prefix_embeds)
        jax.block_until_ready(tok)
        t1 = time.perf_counter()
        out = [tok]
        pos = s + n_prefix
        for i in range(max_new - 1):
            tok, caches = self.decode_batch(caches, tok, pos + i)
            out.append(tok)
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        return GenerationResult(tokens=np.stack([np.asarray(t) for t in out], 1),
                                prefill_s=t1 - t0, decode_s=t2 - t1)
