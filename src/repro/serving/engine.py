"""Replica engine: jit'd prefill + decode over one model replica.

The engine executes real token generation (used by the CPU end-to-end
examples and the runtime tests).  Requests are bucketed by prompt length so a
batch shares one prefill shape; decode runs greedy with a shared position
counter (continuous batching across buckets happens in the server layer) and
is *horizon-fused*: ``decode_batch_k`` / ``paged_decode_k`` run a whole
k-step chunk on-device as power-of-two ``lax.scan`` jit pieces, returning
the ``(B, k)`` token block for a single host transfer per chunk.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ArchConfig

# Prefill compilations are cached per t_max; distinct prompt+generation
# budgets used to pin one compiled function each, forever.  Rounding t_max
# up to the next power of two collapses the distinct shapes to O(log T)
# buckets, and the shared-LRU bound below caps total retained compilations.
MIN_T_BUCKET = 16
# Warm-prefix suffixes are much shorter than full prompts, so their jit
# shapes bucket from a smaller floor — a 5-token unique suffix compiles an
# 8-wide kernel, not the full-prompt bucket it no longer executes.
MIN_SUFFIX_BUCKET = 8


def _pow2_bucket(n: int, floor: int) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


def bucket_t_max(t_max: int) -> int:
    """Round a requested cache length up to a power-of-two bucket."""
    return _pow2_bucket(t_max, MIN_T_BUCKET)


def bucket_suffix(s: int) -> int:
    """Power-of-two bucket for a warm request's *suffix* length: after a
    prefix hit the prefill jit cache keys on this (plus the prefix-table
    width bucket), so warm requests reuse small-shape compilations instead
    of the full-prompt shapes they no longer execute."""
    return _pow2_bucket(max(1, s), MIN_SUFFIX_BUCKET)


def pow2_chunks(k: int) -> List[int]:
    """Binary decomposition of ``k`` into powers of two, largest first
    (13 -> [8, 4, 1]).  Fused decode runs one jit'd scan per piece, so an
    arbitrary chunk length costs O(log k) dispatches against O(log k)
    cached compilations — never a fresh compile per distinct k."""
    out: List[int] = []
    while k > 0:
        c = 1 << (k.bit_length() - 1)
        out.append(c)
        k -= c
    return out


# Jitted callables are pure in (params, inputs), so replicas of the same
# architecture share them: N same-model replicas compile once instead of N
# times, and a replica added mid-run by the autoscaler joins *warm* — no
# compile latency lands on its measured clock.  Keyed by ArchConfig value
# (hashable frozen dataclass) + mode; LRU-bounded like the per-engine
# prefill cache.  The lock only guards the dict (wrapper creation is lazy;
# XLA compilation happens at first call, outside it) — concurrent replica
# workers hit this on every prefill-bucket lookup.
SHARED_JIT_MAX = 64
_shared_jit_cache: "collections.OrderedDict[tuple, object]" = \
    collections.OrderedDict()
_shared_jit_lock = threading.Lock()


def _shared_jit(key: tuple, make):
    with _shared_jit_lock:
        fn = _shared_jit_cache.get(key)
        if fn is not None:
            _shared_jit_cache.move_to_end(key)
            return fn
        fn = _shared_jit_cache[key] = make()
        while len(_shared_jit_cache) > SHARED_JIT_MAX:
            _shared_jit_cache.popitem(last=False)
    return fn


class HostBlockPool:
    """Preallocated host-memory (NumPy) storage for paged KV blocks.

    The engine's pools live on-device as ``(n_periods, num_blocks, bs, KV,
    D)`` k/v tensors per layer; this pool mirrors that layout in host RAM as
    ``(capacity, n_periods, bs, KV, D)`` arrays so one host *slot* holds one
    device *block* across all cache periods of one layer.  Copies are
    block-granular: :meth:`put` is a device_get (gather the block columns,
    land them in pinned-path NumPy rows), :meth:`get` returns the rows for
    the caller's ``device_put`` scatter.  The pool only manages slots and
    bytes — which hashes or requests occupy them is the
    :class:`~repro.runtime.kvcache.paged.PagedEngineCache`'s bookkeeping.
    """

    def __init__(self, n_layers: int, n_periods: int, capacity: int,
                 block_size: int, kv_heads: int, head_dim: int, dtype):
        self.capacity = int(capacity)
        shape = (self.capacity, n_periods, block_size, kv_heads, head_dim)
        self._store = [
            {"k": np.zeros(shape, dtype=dtype),
             "v": np.zeros(shape, dtype=dtype)}
            for _ in range(n_layers)
        ]
        self._free: List[int] = list(range(self.capacity - 1, -1, -1))

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def used_slots(self) -> int:
        return self.capacity - len(self._free)

    @property
    def nbytes(self) -> int:
        return sum(d["k"].nbytes + d["v"].nbytes for d in self._store)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"host pool exhausted: requested {n} slots, "
                f"{len(self._free)} free of {self.capacity}")
        return [self._free.pop() for _ in range(n)]

    def free(self, slots: List[int]) -> None:
        self._free.extend(slots)

    def reset(self) -> None:
        """Release every slot at once — the teardown path when a fault
        (spot reclaim / crash) kills the owning replica: the backing
        arrays stay allocated (the pool object may be garbage-collected
        wholesale) but the slot accounting returns to empty so nothing
        reads stale occupancy from a dead replica's host tier."""
        self._free = list(range(self.capacity - 1, -1, -1))

    def put(self, slots: List[int], pools, block_ids: List[int]) -> int:
        """Copy device blocks ``block_ids`` (one per slot) out of the
        per-layer ``pools`` into host ``slots``.  Returns bytes moved."""
        idx = np.asarray(block_ids, dtype=np.int32)
        moved = 0
        for layer, pool in zip(self._store, pools):
            for key in ("k", "v"):
                # (n_periods, n, bs, KV, D) gather -> host rows (n, np_, ...)
                rows = np.asarray(pool[key][:, idx])
                layer[key][np.asarray(slots)] = np.moveaxis(rows, 1, 0)
                moved += rows.nbytes
        return moved

    def get(self, slots: List[int], pools, block_ids: List[int]):
        """Scatter host ``slots`` back into device blocks ``block_ids``;
        returns ``(new_pools, bytes_moved)`` (functional `.at` update)."""
        idx = jnp.asarray(np.asarray(block_ids, dtype=np.int32))
        sel = np.asarray(slots)
        out = []
        moved = 0
        for layer, pool in zip(self._store, pools):
            new = dict(pool)
            for key in ("k", "v"):
                rows = np.moveaxis(layer[key][sel], 0, 1)  # (np_, n, bs, ...)
                new[key] = pool[key].at[:, idx].set(jnp.asarray(rows))
                moved += rows.nbytes
            out.append(new)
        return out, moved


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, max_new)
    prefill_s: float
    decode_s: float

    @property
    def new_tokens(self) -> int:
        return int(self.tokens.size)


class ReplicaEngine:
    """One model replica with jit-compiled prefill/decode."""

    def __init__(self, cfg: ArchConfig, params=None, *, seed: int = 0,
                 long_mode: bool = False, device=None):
        self.cfg = cfg
        self.long_mode = long_mode
        self.device = device
        self.params = params if params is not None else M.init_params(
            cfg, jax.random.PRNGKey(seed))
        if device is not None:
            # One accelerator per replica: computations follow the params'
            # placement, so concurrent replicas execute on distinct devices.
            self.params = jax.device_put(self.params, device)
        self._step = _shared_jit(
            ("step", cfg, long_mode),
            lambda: jax.jit(functools.partial(M.decode_step, cfg,
                                              long_mode=long_mode)))
        self._paged_step = None

    def _prefill_fn(self, t_max: int):
        """Compiled prefill for the power-of-two bucket covering ``t_max``
        (bounded LRU, shared across same-arch replicas — see
        ``bucket_t_max`` / ``_shared_jit``).  The returned caches are sized
        to the bucket; callers treat ``t_max`` as a lower bound."""
        bucket = bucket_t_max(t_max)
        return _shared_jit(
            ("prefill", self.cfg, self.long_mode, bucket),
            lambda: jax.jit(functools.partial(M.prefill, self.cfg,
                                              t_max=bucket,
                                              long_mode=self.long_mode)))

    def prefill_batch(self, prompts: jax.Array, t_max: int,
                      prefix_embeds: Optional[jax.Array] = None):
        """Run prefill for one batch; returns (first_token, caches).

        This is the incremental entry point the runtime's
        ``EngineExecutor`` uses for continuous batching: one admission
        cohort shares a prefill shape and its caches decode in lockstep via
        :meth:`decode_batch`.
        """
        logits, caches = self._prefill_fn(t_max)(self.params, prompts,
                                                 prefix_embeds)
        return M.greedy_sample(logits[:, -1]), caches

    def _suffix_fn(self, s_bucket: int, p_bucket: int):
        """Compiled suffix-only prefill for the (suffix, prefix-table)
        power-of-two bucket pair — keyed on the *suffix* length, never the
        full prompt shape, so warm-prefix cohorts share small
        compilations (bounded LRU, shared across same-arch replicas)."""
        return _shared_jit(
            ("prefill_suffix", self.cfg, s_bucket, p_bucket),
            lambda: jax.jit(functools.partial(M.prefill_suffix, self.cfg)))

    def prefill_suffix_batch(self, suffix_tokens: jax.Array, pools,
                             prefix_tables: jax.Array, t_prefix: int):
        """Warm-prefix prefill: run only the cohort's unique suffix against
        the replica's cached prefix blocks; returns (first_token,
        suffix_caches) shaped like :meth:`prefill_batch`'s but covering
        suffix positions only.  Tokens pad to the suffix bucket, tables to
        the table bucket (scratch-block entries, masked by ``t_prefix``);
        the traced last-index keeps logits on the last *real* token."""
        b, s = suffix_tokens.shape
        s_buc = bucket_suffix(s)
        if s_buc > s:
            suffix_tokens = jnp.pad(suffix_tokens, ((0, 0), (0, s_buc - s)))
        p = prefix_tables.shape[1]
        p_buc = _pow2_bucket(max(1, p), 1)
        if p_buc > p:
            prefix_tables = jnp.pad(prefix_tables,
                                    ((0, 0), (0, p_buc - p)))
        logits, caches = self._suffix_fn(s_buc, p_buc)(
            self.params, suffix_tokens, pools, prefix_tables,
            jnp.asarray(t_prefix, jnp.int32), jnp.asarray(s - 1, jnp.int32))
        return M.greedy_sample(logits), caches

    def decode_batch(self, caches, tok: jax.Array, pos: int):
        """One greedy decode step for a batch; returns (next_token, caches)."""
        logits, caches = self._step(self.params, caches, tok,
                                    jnp.asarray(pos, jnp.int32))
        return M.greedy_sample(logits), caches

    def _steps_fn(self, k: int):
        """Compiled k-step fused decode (scan over :func:`M.decode_steps`),
        shared across same-arch replicas like every other jit here."""
        return _shared_jit(
            ("steps", self.cfg, self.long_mode, k),
            lambda: jax.jit(functools.partial(M.decode_steps, self.cfg,
                                              k=k, long_mode=self.long_mode)))

    def decode_batch_k(self, caches, tok: jax.Array, pos: int, k: int):
        """``k`` greedy lockstep steps with O(log k) jit dispatches and no
        host syncs: the horizon is split into power-of-two pieces
        (:func:`pow2_chunks`), each one ``lax.scan`` inside one jit, the
        last token of each piece feeding the next on-device.  Returns
        ``(tokens (B, k) device array, caches)`` — callers transfer the
        whole block with a single ``np.asarray``."""
        blocks = []
        p = int(pos)
        for kk in pow2_chunks(max(1, int(k))):
            toks, caches = self._steps_fn(kk)(self.params, caches, tok,
                                              jnp.asarray(p, jnp.int32))
            blocks.append(toks)
            tok = toks[:, -1]
            p += kk
        toks = blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, 1)
        return toks, caches

    @property
    def paged_supported(self) -> bool:
        return M.paged_supported(self.cfg)

    def paged_decode(self, pools, block_tables: jax.Array,
                     lengths: jax.Array, tok: jax.Array):
        """One greedy lockstep step over every slot of a paged replica;
        returns (next_token (S,), new_pools).  Shape-stable: one compile
        per replica regardless of which slots are live."""
        if self._paged_step is None:
            self._paged_step = _shared_jit(
                ("paged", self.cfg),
                lambda: jax.jit(functools.partial(M.paged_decode_step,
                                                  self.cfg)))
        logits, pools = self._paged_step(self.params, pools, block_tables,
                                         lengths, tok)
        return M.greedy_sample(logits), pools

    def _paged_steps_fn(self, k: int):
        return _shared_jit(
            ("paged_steps", self.cfg, k),
            lambda: jax.jit(functools.partial(M.paged_decode_steps,
                                              self.cfg, k=k)))

    def paged_decode_k(self, pools, block_tables: jax.Array,
                       lengths: jax.Array, tok: jax.Array, k: int):
        """``k`` fused greedy lockstep steps over every slot of a paged
        replica (power-of-two jit pieces, see :meth:`decode_batch_k`).

        Caller contract: **no slot may cross a block boundary within the
        chunk** — split at ``PagedEngineCache.steps_to_boundary()`` first.
        Returns ``(tokens (S, k) device array, new_pools)``."""
        blocks = []
        live = lengths > 0
        done = 0
        for kk in pow2_chunks(max(1, int(k))):
            # advance only occupied lanes between pieces: empty slots must
            # stay at length 0 so each piece's dead-lane zeroing (and the
            # scratch-write determinism it guarantees) keeps seeing them
            # as empty
            stepped = jnp.where(live, lengths + done, lengths)
            toks, pools = self._paged_steps_fn(kk)(
                self.params, pools, block_tables, stepped, tok)
            blocks.append(toks)
            tok = toks[:, -1]
            done += kk
        toks = blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, 1)
        return toks, pools

    def generate(self, prompts: jax.Array, max_new: int,
                 prefix_embeds: Optional[jax.Array] = None
                 ) -> GenerationResult:
        """prompts: (B, S) int32.  Greedy decode for max_new tokens.

        Decode is horizon-fused (:meth:`decode_batch_k`): tokens accumulate
        on-device and the whole (B, max_new) block crosses to the host in
        one transfer — not one ``np.asarray`` per token."""
        b, s = prompts.shape
        n_prefix = prefix_embeds.shape[1] if prefix_embeds is not None else 0
        t_max = s + n_prefix + max_new
        t0 = time.perf_counter()
        tok, caches = self.prefill_batch(prompts, t_max, prefix_embeds)
        jax.block_until_ready(tok)
        t1 = time.perf_counter()
        if max_new > 1:
            toks, caches = self.decode_batch_k(caches, tok, s + n_prefix,
                                               max_new - 1)
            out = jnp.concatenate([tok[:, None], toks], axis=1)
        else:
            out = tok[:, None]
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        return GenerationResult(tokens=np.asarray(out),
                                prefill_s=t1 - t0, decode_s=t2 - t1)
