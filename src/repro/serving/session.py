"""Online serving session: live ``submit()``/streaming over the runtime.

The user-facing façade of the spec → plan → serve lifecycle::

    import repro
    from repro.core import DeploymentSpec

    spec = DeploymentSpec(models=[...], workload=trace, catalog=GPU_CATALOG,
                          availability=snapshot, budget=30.0)
    with repro.serve(spec, arch_cfgs=[cfg]) as session:
        handle = session.submit("why is the sky blue?", max_new=32)
        for tok in handle.tokens():     # streams as the engine decodes
            ...
        print(handle.ttft, handle.tpot)
    result = session.result             # the usual RuntimeResult

A :class:`Session` owns one long-lived :class:`~repro.runtime.ServingRuntime`
over the plan's replicas.  ``submit()`` stamps the request with a
wall-clock arrival through a :class:`~repro.runtime.LiveSource` and
returns a :class:`RequestHandle`; the runtime thread routes it, batches it
into the continuous-batching loop alongside everything else in flight,
and the executor streams each event's ``(B, k)`` token chunk back through
the handle.  ``close()`` (or leaving the ``with`` block) drains in-flight
requests and returns the same :class:`~repro.runtime.RuntimeResult` a
trace replay produces.  :meth:`Session.replay` serves a recorded trace
through the same runtime (what the deprecated ``HeterogeneousServer.serve``
wraps).
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.core.plan import ServingPlan
from repro.core.spec import DeploymentSpec
from repro.core.spec import plan as plan_spec
from repro.core.workloads import WORKLOAD_TYPES, Request, Trace
from repro.runtime import (CostModelExecutor, EngineExecutor, LiveSource,
                           RequestState, RuntimeResult, ServingRuntime)

__all__ = ["RequestHandle", "Session", "serve"]


def _encode_prompt(prompt) -> Optional[np.ndarray]:
    """Token ids for a submitted prompt: a string is byte-encoded (the
    engine vocabulary is synthetic — what matters is determinism), a
    sequence of ints passes through, None keeps the per-request RNG
    prompt."""
    if prompt is None:
        return None
    if isinstance(prompt, str):
        return np.frombuffer(prompt.encode("utf-8"), dtype=np.uint8
                             ).astype(np.int64)
    return np.asarray(list(prompt), dtype=np.int64)


def _nearest_workload(input_len: int, output_len: int) -> int:
    """The workload class whose (input, output) averages are closest —
    routing and the cost model are keyed on workload classes."""
    return min(range(len(WORKLOAD_TYPES)),
               key=lambda i: (abs(WORKLOAD_TYPES[i].input_len - input_len)
                              + abs(WORKLOAD_TYPES[i].output_len
                                    - output_len)))


class RequestHandle:
    """One submitted request: token stream + per-request SLO metrics.

    Tokens arrive in executed-event chunks (exactly the executor's
    ``token_log`` trail, including recompute re-prefills after a
    preemption); :meth:`tokens` blocks until the next token or end of
    stream.  :meth:`result` blocks until the request leaves the runtime
    (finished — or dropped, see :attr:`failed`).
    """

    def __init__(self, session: "Session", slo=None):
        self._session = session
        self.slo = slo
        self.state: Optional[RequestState] = None   # set at submit time
        self._cond = threading.Condition()
        self._stream: List[int] = []
        self._done = False

    @property
    def req_id(self) -> int:
        return self.state.req.req_id

    # ------------------------------------------------------- producer side

    def _push(self, tokens: Sequence[int]) -> None:
        with self._cond:
            self._stream.extend(tokens)
            self._cond.notify_all()

    def _finish(self) -> None:
        with self._cond:
            self._done = True
            self._cond.notify_all()

    # ------------------------------------------------------- consumer side

    def tokens(self, timeout: Optional[float] = None) -> Iterator[int]:
        """Yield this request's tokens as the engine produces them; the
        iterator ends when the request completes (empty on analytical
        backends, which generate no tokens)."""
        i = 0
        while True:
            with self._cond:
                while i >= len(self._stream) and not self._done:
                    if not self._cond.wait(timeout):
                        raise TimeoutError(f"no token within {timeout}s")
                if i >= len(self._stream):
                    return
                tok = self._stream[i]
            i += 1
            yield tok

    def result(self, timeout: Optional[float] = None) -> RequestState:
        """Block until the request left the runtime; returns its record
        (None only if the serving loop died before the request was
        built — see :attr:`failed`)."""
        with self._cond:
            if not self._cond.wait_for(lambda: self._done, timeout):
                raise TimeoutError("request still in flight")
        return self.state

    @property
    def done(self) -> bool:
        return self._done and self.state is not None and self.state.done

    @property
    def failed(self) -> bool:
        """True when the request left the runtime unserved: no replica
        serves its model (dropped), its fault-retry budget ran out
        (``state.failed``), or the serving loop died before the request
        was even built."""
        return self._done and (self.state is None or not self.state.done)

    @property
    def retries(self) -> int:
        """Re-serves forced by replica faults so far (0 before submit)."""
        return 0 if self.state is None else self.state.retries

    @property
    def ttft(self) -> float:
        """Time to first token (seconds on the runtime's clock; live
        sessions stamp arrivals in wall time, so this is the observed
        submit → first-token latency)."""
        return self.state.ttft

    @property
    def tpot(self) -> float:
        return self.state.tpot

    @property
    def latency(self) -> float:
        return self.state.latency

    def slo_met(self) -> Optional[bool]:
        """Whether this request met its per-request SLO (None if no SLO
        was attached at submit or session level)."""
        if self.slo is None:
            return None
        return self.slo.met(self.state)


class Session:
    """A live serving session over one plan (see module docstring).

    The session is lazy: the serving thread starts at the first
    :meth:`submit` (or :meth:`open`), so a fresh session can also
    :meth:`replay` recorded traces through the same runtime — the
    reuse-across-runs lifecycle ``HeterogeneousServer`` now wraps.
    """

    def __init__(self, plan: ServingPlan, executor, *,
                 mode: str = "events", preempt_policy: str = "latest",
                 preempt_mode: str = "recompute",
                 replan=None, autoscale=None, faults=None,
                 retry_budget: int = 2, worker_timeout=None,
                 slo=None, obs=None, clock=None):
        self.plan = plan
        self.executor = executor
        self.slo = slo
        self.obs = obs          # repro.obs.Observability or None
        self.runtime = ServingRuntime(plan, executor, mode=mode,
                                      preempt_policy=preempt_policy,
                                      preempt_mode=preempt_mode,
                                      retry_budget=retry_budget,
                                      worker_timeout=worker_timeout,
                                      on_done=self._on_done, obs=obs,
                                      clock=clock)
        executor.token_sink = self._on_tokens
        self._replan = replan
        self._autoscale = autoscale
        self._faults = faults   # FaultPlan / FaultInjector / event sequence
        self._lock = threading.Lock()
        self._handles: Dict[int, RequestHandle] = {}
        self._next_id = 0
        self.source: Optional[LiveSource] = None
        self._thread: Optional[threading.Thread] = None
        self._result: Optional[RuntimeResult] = None
        self._error: Optional[BaseException] = None
        self._closed = False

    # ------------------------------------------------------------ lifecycle

    def open(self) -> "Session":
        """Start the serving thread (idempotent; ``submit`` calls it).
        Thread-safe: concurrent first submits race to one serving loop."""
        if self._closed:
            raise RuntimeError("session is closed")
        with self._lock:
            if self._thread is not None:
                return self
            # A prior replay() may have used this runtime/executor: start
            # the live run from clean state (fresh replica clocks, empty
            # token trails) with the streaming sink re-attached.
            configure = getattr(self.executor, "configure", None)
            if configure is not None:
                configure()
            self.executor.token_sink = self._on_tokens
            self.runtime.reset()
            self.source = LiveSource()
            self._thread = threading.Thread(
                target=self._serve_loop, name="session-serve", daemon=True)
            self._thread.start()
        return self

    def _serve_loop(self) -> None:
        try:
            self._result = self.runtime.run_source(
                self.source, replan=self._replan, autoscale=self._autoscale,
                faults=self._faults)
        except BaseException as exc:   # surface through close()/submit()
            self._error = exc
        finally:
            # A crashed loop must not leave the source accepting
            # submissions nobody will ever serve.
            self.source.close()
            with self._lock:
                handles = list(self._handles.values())
            for h in handles:          # unblock every waiting consumer
                h._finish()

    def close(self, timeout: Optional[float] = None) -> RuntimeResult:
        """Drain in-flight requests and stop serving; returns the run's
        :class:`~repro.runtime.RuntimeResult` (idempotent).  On a drain
        timeout the session stays open so ``close`` can be retried."""
        if self._closed:
            if self._error is not None:
                raise self._error
            return self._result
        self.open()
        self.source.close()
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(f"session did not drain within {timeout}s")
        self._closed = True
        if self._error is not None:
            raise self._error
        return self._result

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # Don't mask an in-flight exception with a drain timeout.
        if exc_type is None:
            self.close()
        else:
            try:
                self.close(timeout=5.0)
            except Exception:
                pass

    @property
    def result(self) -> Optional[RuntimeResult]:
        """The drained run's result (None until :meth:`close`)."""
        return self._result

    # -------------------------------------------------------- observability

    def metrics(self) -> Dict[str, object]:
        """Live point-in-time metrics snapshot (queue depths, KV
        occupancy, prefix hit rates, latency histograms, ...) — callable
        from any thread *while serving*.  Requires the session to have
        been opened with observability (``serve(...,
        observability=True)`` or ``Session(..., obs=Observability())``)."""
        if self.obs is None:
            raise RuntimeError(
                "metrics() requires observability: open the session with "
                "serve(..., observability=True) or "
                "Session(..., obs=Observability())")
        return self.obs.snapshot()

    def export_trace(self, path: str) -> str:
        """Write the session's trace capture as Chrome trace-event JSON
        (see :meth:`ServingRuntime.export_trace`)."""
        return self.runtime.export_trace(path)

    # --------------------------------------------------------------- submit

    def submit(self, prompt: Union[str, Sequence[int], None] = None, *,
               model: int = 0, workload: Optional[int] = None,
               input_len: Optional[int] = None,
               output_len: Optional[int] = None,
               max_new: Optional[int] = None,
               slo=None) -> RequestHandle:
        """Submit one request to the live session; returns its handle.

        ``prompt`` — a string, token-id sequence, or None (synthetic
        per-request prompt).  ``max_new`` / ``output_len`` bound generated
        tokens (the executor's runtime budget still caps real engines).
        ``workload`` pins the paper's workload class for routing/costing;
        when omitted it's inferred as the class nearest the request's
        (input, output) lengths.  ``slo`` attaches a per-request
        :class:`~repro.runtime.SLO` scored by :meth:`RequestHandle.slo_met`.
        """
        if self._closed:
            raise RuntimeError("session is closed")
        if self._error is not None:
            raise RuntimeError("serving loop died") from self._error
        self.open()
        tokens = _encode_prompt(prompt)
        out = output_len if output_len is not None else max_new
        if workload is None:
            win = input_len if input_len is not None else (
                len(tokens) if tokens is not None else
                WORKLOAD_TYPES[0].input_len)
            wout = out if out is not None else WORKLOAD_TYPES[0].output_len
            workload = _nearest_workload(win, wout)
        wtype = WORKLOAD_TYPES[workload]
        if input_len is None:
            input_len = len(tokens) if tokens is not None else wtype.input_len
        if out is None:
            out = wtype.output_len
        handle = RequestHandle(self, slo=slo if slo is not None else self.slo)
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            self._handles[rid] = handle
        if tokens is not None and hasattr(self.executor, "prompt_overrides"):
            self.executor.prompt_overrides[rid] = tokens

        def build(arrival: float) -> RequestState:
            handle.state = RequestState(req=Request(
                req_id=rid, workload=workload, input_len=int(input_len),
                output_len=int(out), arrival=arrival, model=model,
                prompt=(tuple(int(t) for t in tokens)
                        if tokens is not None else None)))
            return handle.state

        self.source.submit(build)
        return handle

    # --------------------------------------------------------------- replay

    def replay(self, trace: Trace, *, replan=None,
               autoscale=None, faults=None) -> RuntimeResult:
        """Serve a recorded trace through this session's runtime (offline
        twin of the live path; resets runtime *and* executor state first —
        token trails, counters, replan-added replicas — so sessions and
        servers can run many traces back to back).  ``faults`` injects a
        :class:`~repro.runtime.FaultPlan` (or injector / event sequence)
        for this replay only — the session-level plan stays live-only."""
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("session is live; replay needs a fresh or "
                               "drained session")
        configure = getattr(self.executor, "configure", None)
        if configure is not None:
            configure()       # keeps the scale/seed set at serve() time
        self.runtime.reset()
        return self.runtime.run(trace, replan=replan, autoscale=autoscale,
                                faults=faults)

    # ------------------------------------------------------------ callbacks

    def _on_tokens(self, req_id: int, tokens: List[int]) -> None:
        with self._lock:
            handle = self._handles.get(req_id)
        if handle is not None:
            handle._push(tokens)

    def _on_done(self, state: RequestState) -> None:
        # Pop, don't get: a long-lived session must not accumulate one
        # handle (plus its token stream and prompt) per request served —
        # the caller's own reference keeps the handle alive.
        rid = state.req.req_id
        with self._lock:
            handle = self._handles.pop(rid, None)
        overrides = getattr(self.executor, "prompt_overrides", None)
        if overrides is not None:
            overrides.pop(rid, None)
        if handle is not None:
            handle._finish()


def serve(spec_or_plan: Union[DeploymentSpec, ServingPlan], *,
          strategy: str = "milp", plan_options: Optional[dict] = None,
          backend: str = "engine", arch_cfgs: Optional[Sequence] = None,
          models: Optional[Sequence] = None, executor=None,
          input_len: Optional[int] = None, max_new: Optional[int] = None,
          seed: Optional[int] = None,
          mode: str = "events", preempt_policy: str = "latest",
          preempt_mode: str = "recompute",
          replan=None, autoscale=None, faults=None,
          retry_budget: int = 2, worker_timeout: Optional[float] = None,
          slo=None, observability=False, clock=None,
          **executor_options) -> Session:
    """Open a serving :class:`Session` from a spec (planned via the
    registry: ``strategy`` + ``plan_options``) or an existing plan.

    ``backend="engine"`` serves real JAX replicas (``arch_cfgs`` maps each
    spec/plan model index to its :class:`~repro.models.config.ArchConfig`;
    ``input_len``/``max_new``/``seed`` set the runtime scale — left None,
    the executor's existing configuration stands, so a pre-built
    ``executor=`` keeps the scale its owner chose) and ``backend="cost"``
    serves the analytical cost model (no tokens — useful for capacity
    dry-runs of the same session code).

    ``faults`` injects spot-churn events into the live serving loop (a
    :class:`~repro.runtime.FaultPlan`, an event sequence, or a
    :class:`~repro.runtime.FaultInjector` carrying an
    :class:`~repro.runtime.AvailabilityWatcher` for availability-driven
    replanning); ``retry_budget`` bounds per-request fault re-serves
    before the request is dropped with ``handle.failed``; and
    ``worker_timeout`` (seconds) turns a hung replica worker call into a
    structured :class:`~repro.runtime.WorkerTimeout` crash.

    ``observability`` — ``True`` (builds a fresh
    :class:`repro.obs.Observability`) or an existing instance; enables
    ``session.metrics()`` / ``session.export_trace(path)``.  ``clock``
    injects the engine executor's measurement time source (tests pin
    ``repro.obs.TickClock()`` for load-independent schedules).
    """
    if isinstance(spec_or_plan, DeploymentSpec):
        spec = spec_or_plan
        the_plan = plan_spec(spec, strategy=strategy, **(plan_options or {}))
        models = list(spec.models) if models is None else list(models)
        slo = spec.slo if slo is None else slo
        if (spec.host_ram_bytes is not None
                and "host_ram_bytes" not in executor_options
                and executor is None):
            # The spec's host-RAM budget sizes each replica's two-tier KV
            # host pool (see kvcache.budget.host_blocks_for); an explicit
            # executor option still wins.
            executor_options["host_ram_bytes"] = spec.host_ram_bytes
    elif isinstance(spec_or_plan, ServingPlan):
        the_plan = spec_or_plan
    else:
        raise TypeError(f"serve() wants a DeploymentSpec or ServingPlan, "
                        f"got {type(spec_or_plan).__name__}")
    if executor is None:
        if backend == "cost":
            executor = CostModelExecutor(the_plan.replicas, models,
                                         **executor_options)
        elif backend == "engine":
            if arch_cfgs is None:
                raise ValueError(
                    'backend="engine" needs arch_cfgs (one ArchConfig per '
                    'model index) — or pass backend="cost" / executor=')
            executor = EngineExecutor(the_plan, arch_cfgs, models=models,
                                      **executor_options)
        else:
            raise ValueError(f'backend must be "engine" or "cost", '
                             f'got {backend!r}')
    if isinstance(executor, EngineExecutor):
        executor.configure(input_len=input_len, max_new=max_new, seed=seed)
    obs = None
    if observability:
        if observability is True:
            from repro.obs import Observability
            obs = Observability()
        else:
            obs = observability
    return Session(the_plan, executor, mode=mode,
                   preempt_policy=preempt_policy, preempt_mode=preempt_mode,
                   replan=replan, autoscale=autoscale, faults=faults,
                   retry_budget=retry_budget, worker_timeout=worker_timeout,
                   slo=slo, obs=obs, clock=clock)
