"""Deprecated multi-replica serving orchestrator.

:class:`HeterogeneousServer` predates the session API and survives as a
deprecated alias for the trace-replay half of :class:`repro.serving.Session`:
it builds one :class:`~repro.runtime.executor.EngineExecutor` over the plan
and replays traces through one **persistent**
:class:`~repro.runtime.ServingRuntime` (rebuilt only when the drive mode
changes; every ``serve`` call resets state and reuses it — the session
lifecycle).  New code should use ``repro.serve(spec_or_plan, ...)``, which
adds live ``submit()``/streaming on the same runtime.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Sequence

from repro.core.plan import ServingPlan
from repro.core.workloads import Trace
from repro.models.config import ArchConfig
from repro.runtime import (EngineExecutor, ReplanEvent, RuntimeResult,
                           ServingRuntime)


@dataclasses.dataclass
class ServeStats:
    completed: int
    generated_tokens: int
    wall_s: float
    per_replica_requests: List[int]
    result: Optional[RuntimeResult] = None   # full per-request SLO metrics

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)


class HeterogeneousServer:
    """Deprecated: use ``repro.serve(...)`` / ``repro.serving.Session``."""

    def __init__(self, plan: ServingPlan, arch_cfgs: Sequence[ArchConfig],
                 *, params_per_model: Optional[Dict[int, object]] = None,
                 max_batch: int = 8, models=None,
                 paged: Optional[bool] = None, concurrent: bool = True,
                 fused_steps: Optional[int] = None):
        warnings.warn(
            "HeterogeneousServer is deprecated; use repro.serve(spec_or_plan,"
            " arch_cfgs=...) — Session.replay(trace) is the serve() "
            "equivalent, and submit() adds live streaming",
            DeprecationWarning, stacklevel=2)
        self.plan = plan
        self.executor = EngineExecutor(plan, arch_cfgs,
                                       params_per_model=params_per_model,
                                       models=models, max_batch=max_batch,
                                       paged=paged, concurrent=concurrent,
                                       fused_steps=fused_steps)
        self.runtime: Optional[ServingRuntime] = None

    @property
    def engines(self):
        return self.executor.engines

    @property
    def last_runtime(self) -> Optional[ServingRuntime]:
        """Backwards-compatible alias: the (now persistent) runtime."""
        return self.runtime

    def serve(self, trace: Trace, *, input_len: int = 16, max_new: int = 8,
              seed: int = 0, replan: Optional[ReplanEvent] = None,
              autoscale=None, mode: str = "events") -> ServeStats:
        """Serve every request in the trace with synthetic prompts of
        ``input_len`` tokens and at most ``max_new`` generated tokens per
        request (trace token lengths are cost-model scale; runtime scale
        stays CPU-sized).  ``autoscale`` optionally passes a
        :class:`repro.core.scheduler.ScalePolicy` for online scaling;
        ``mode="sequential"`` forces the legacy replica-at-a-time loop
        (used by equivalence tests).  The underlying runtime persists
        across calls — state resets, jit caches and replica identities
        stay warm."""
        self.executor.configure(input_len=input_len, max_new=max_new,
                                seed=seed)
        if self.runtime is None or self.runtime.mode != mode:
            self.runtime = ServingRuntime(self.plan, self.executor,
                                          mode=mode)
        else:
            self.runtime.reset()
        t0 = time.perf_counter()
        result = self.runtime.run(trace, replan=replan, autoscale=autoscale)
        wall = time.perf_counter() - t0
        return ServeStats(
            completed=result.num_completed,
            generated_tokens=self.executor.generated_tokens,
            wall_s=wall,
            per_replica_requests=result.per_replica_requests,
            result=result)
