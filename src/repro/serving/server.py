"""Multi-replica serving orchestrator (thin wrapper over the runtime).

Executes a ``ServingPlan`` end-to-end with *real* JAX model replicas
through the unified serving runtime: the same continuous-batching
scheduler, streaming dispatch, and router that power the cost-model
simulator drive an :class:`~repro.runtime.executor.EngineExecutor`, so the
executed batches are exactly the batches the plan was evaluated on.  On
this container all replicas share one CPU device (they'd each own their
rented accelerators in deployment); the heterogeneous *speeds* are the cost
model's domain — this layer proves the plan is executable and the routing
math is consistent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

from repro.core.plan import ServingPlan
from repro.core.workloads import Trace
from repro.models.config import ArchConfig
from repro.runtime import (EngineExecutor, ReplanEvent, RuntimeResult,
                           ServingRuntime)


@dataclasses.dataclass
class ServeStats:
    completed: int
    generated_tokens: int
    wall_s: float
    per_replica_requests: List[int]
    result: Optional[RuntimeResult] = None   # full per-request SLO metrics

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)


class HeterogeneousServer:
    """Executes a plan: one ReplicaEngine per plan replica."""

    def __init__(self, plan: ServingPlan, arch_cfgs: Sequence[ArchConfig],
                 *, params_per_model: Optional[Dict[int, object]] = None,
                 max_batch: int = 8, models=None,
                 paged: Optional[bool] = None, concurrent: bool = True,
                 fused_steps: Optional[int] = None):
        self.plan = plan
        self.executor = EngineExecutor(plan, arch_cfgs,
                                       params_per_model=params_per_model,
                                       models=models, max_batch=max_batch,
                                       paged=paged, concurrent=concurrent,
                                       fused_steps=fused_steps)

    @property
    def engines(self):
        return self.executor.engines

    def serve(self, trace: Trace, *, input_len: int = 16, max_new: int = 8,
              seed: int = 0, replan: Optional[ReplanEvent] = None,
              autoscale=None, mode: str = "events") -> ServeStats:
        """Serve every request in the trace with synthetic prompts of
        ``input_len`` tokens and at most ``max_new`` generated tokens per
        request (trace token lengths are cost-model scale; runtime scale
        stays CPU-sized).  ``autoscale`` optionally passes a
        :class:`repro.core.scheduler.ScalePolicy` for online scaling;
        ``mode="sequential"`` forces the legacy replica-at-a-time loop
        (used by equivalence tests)."""
        self.executor.configure(input_len=input_len, max_new=max_new,
                                seed=seed)
        runtime = ServingRuntime(self.plan, self.executor, mode=mode)
        self.last_runtime = runtime     # scale_log / admission_log access
        t0 = time.perf_counter()
        result = runtime.run(trace, replan=replan, autoscale=autoscale)
        wall = time.perf_counter() - t0
        return ServeStats(
            completed=result.num_completed,
            generated_tokens=self.executor.generated_tokens,
            wall_s=wall,
            per_replica_requests=result.per_replica_requests,
            result=result)
