"""Multi-replica serving orchestrator.

Executes a ``ServingPlan`` end-to-end with *real* JAX model replicas: the
router dispatches requests per the plan's workload assignment, each replica
batches its queue by prompt length and generates real tokens.  On this
container all replicas share one CPU device (they'd each own their rented
accelerators in deployment); the heterogeneous *speeds* are the cost model's
domain — this layer proves the plan is executable and the routing math is
consistent.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import ServingPlan
from repro.core.workloads import Request, Trace
from repro.models.config import ArchConfig
from repro.serving.engine import ReplicaEngine
from repro.serving.router import AssignmentRouter


@dataclasses.dataclass
class ServeStats:
    completed: int
    generated_tokens: int
    wall_s: float
    per_replica_requests: List[int]

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.wall_s, 1e-9)


class HeterogeneousServer:
    """Executes a plan: one ReplicaEngine per plan replica."""

    def __init__(self, plan: ServingPlan, arch_cfgs: Sequence[ArchConfig],
                 *, params_per_model: Optional[Dict[int, object]] = None,
                 max_batch: int = 8):
        self.plan = plan
        self.router = AssignmentRouter(plan)
        self.max_batch = max_batch
        self.engines: List[ReplicaEngine] = []
        params_per_model = params_per_model or {}
        for cfg in plan.replicas:
            arch = arch_cfgs[cfg.model_index]
            self.engines.append(ReplicaEngine(
                arch, params=params_per_model.get(cfg.model_index),
                seed=cfg.model_index))

    def serve(self, trace: Trace, *, input_len: int = 16, max_new: int = 8,
              seed: int = 0) -> ServeStats:
        """Serve every request in the trace with synthetic prompts of
        ``input_len`` tokens (trace token lengths are cost-model scale;
        runtime scale stays CPU-sized)."""
        rng = np.random.default_rng(seed)
        queues: Dict[int, List[Request]] = defaultdict(list)
        for req in trace.requests:
            queues[self.router.route(req)].append(req)

        t0 = time.perf_counter()
        completed = 0
        generated = 0
        per_replica = [0] * len(self.engines)
        for i, engine in enumerate(self.engines):
            reqs = queues.get(i, [])
            per_replica[i] = len(reqs)
            arch = engine.cfg
            for start in range(0, len(reqs), self.max_batch):
                chunk = reqs[start:start + self.max_batch]
                prompts = jnp.asarray(rng.integers(
                    0, arch.vocab_size, size=(len(chunk), input_len)),
                    jnp.int32)
                prefix = None
                if arch.frontend != "none":
                    prefix = jnp.asarray(rng.normal(
                        0, 0.02, size=(len(chunk), arch.num_patches,
                                       arch.d_model)), jnp.bfloat16)
                result = engine.generate(prompts, max_new,
                                         prefix_embeds=prefix)
                completed += len(chunk)
                generated += result.new_tokens
        wall = time.perf_counter() - t0
        return ServeStats(completed=completed, generated_tokens=generated,
                          wall_s=wall, per_replica_requests=per_replica)
