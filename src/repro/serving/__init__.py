"""Serving runtime: replica engines, workload-assignment routing, and the
multi-replica orchestrator that executes a ServingPlan."""
from repro.serving.engine import GenerationResult, ReplicaEngine
from repro.serving.router import AssignmentRouter
from repro.serving.server import HeterogeneousServer, ServeStats
