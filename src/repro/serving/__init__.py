"""Serving layer: replica engines and the multi-replica orchestrator that
executes a ServingPlan on the unified runtime (``repro.runtime``)."""
from repro.serving.engine import GenerationResult, ReplicaEngine
from repro.serving.router import AssignmentRouter
from repro.serving.server import HeterogeneousServer, ServeStats
