"""Serving layer: replica engines, the online Session façade
(``repro.serve`` → live submit/stream over the unified runtime), and the
deprecated ``HeterogeneousServer`` trace-replay wrapper."""
from repro.serving.engine import GenerationResult, ReplicaEngine
from repro.serving.router import AssignmentRouter
from repro.serving.server import HeterogeneousServer, ServeStats
from repro.serving.session import RequestHandle, Session, serve

__all__ = [
    "AssignmentRouter", "GenerationResult", "HeterogeneousServer",
    "ReplicaEngine", "RequestHandle", "ServeStats", "Session", "serve",
]
