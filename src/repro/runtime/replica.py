"""Continuous-batching replica scheduler — the shared serving core.

One :class:`ReplicaRuntime` owns one replica's queue and active batch and
advances a local clock through admission (prefill) and lockstep decode
events.  The same loop drives both backends: with the
:class:`~repro.runtime.executor.CostModelExecutor` it *is* the cluster
simulator's inner loop; with the
:class:`~repro.runtime.executor.EngineExecutor` every event performs real
jit'd token generation and the clock advances by measured wall time.

Semantics (inherited from the validated simulator, now shared):

* admission groups every queued request that has arrived and fits under
  both the backend's concurrency cap and the replica's **KV block budget**
  (:class:`~repro.runtime.kvcache.KVCacheManager`): a request is admitted
  when its prompt (+ first token) blocks can be reserved, in FCFS order —
  memory, not a fixed ``max_batch``, is what bounds the batch;
* decode advances the whole active batch in lockstep steps; the scheduler
  fast-forwards at most ``executor.max_steps_per_event`` steps, never
  overshoots the next queued arrival (so admission happens mid-flight),
  and never outgrows the block pool.  The chosen chunk ``k`` is the
  *fused-decode horizon*: a real engine executes all ``k`` steps in one
  on-device call (``EngineExecutor`` with ``fused_steps > 1``), so the
  chunk's KV growth is reserved up front (``mgr.grow(... + k)`` below) —
  preemption and admission decisions land at the same token positions as
  stepwise execution.  When the next step does not fit, one
  request is **preempted by recompute** — its blocks are freed and it
  re-enters the queue to prefill again later (recorded in
  ``RequestState.preemptions``).  The victim is chosen by
  ``preempt_policy``: ``"latest"`` (vLLM recompute default: the
  most-recently-admitted request) or ``"fewest-blocks"`` (the cheapest
  recompute: the request holding the fewest KV blocks).  With prefix
  caching on, both steps are refcount-aware: freeing a victim only
  *decrefs* blocks shared with live requests (they stay resident),
  ``held_blocks`` counts only the blocks eviction would actually
  reclaim, and readmission re-resolves the prefix index — a preempted
  request typically re-aliases its own still-cached prefix;
* with a host KV tier configured, ``preempt_mode`` selects what eviction
  does with the victim's blocks: ``"recompute"`` (drop and re-prefill,
  the default), ``"swap"`` (copy the blocks to the host pool and readmit
  by a **swap-in** event that restores them without re-running prefill),
  or ``"auto"`` (per victim, compare the modeled swap transfer time
  against the modeled prefill-recompute time and take the cheaper one —
  both backends use the same analytical model, so they decide
  identically).  A swapped request keeps its decode position
  (``remaining`` is preserved) and re-enters the queue FCFS like any
  preempted request;
* a ``draining`` replica (removed by a replan) finishes its active batch
  but admits nothing new — and never preempts, since its queue can no
  longer drain through admission;
* a replica always makes progress: a single active request may overflow
  the budget rather than starve (undersized replicas serve one request at
  a time, exactly like the legacy fixed-cap scheduler).

Two equivalent drive modes:

* **sequential** — :meth:`ReplicaRuntime.step` advances one compound event
  (admission groups and/or one decode chunk) and the orchestrator loops
  each replica to exhaustion (the pre-event-heap behavior, kept as the
  equivalence baseline);
* **event** — :meth:`next_event_time` / :meth:`begin_step` /
  :meth:`complete_step` split every event into *plan* (pure bookkeeping on
  the orchestrator thread) → *execute* (the executor call, which a
  concurrent backend may run on a per-replica worker thread) → *commit*,
  so a global event heap can pop the earliest event across replicas and
  overlap executor calls in wall time.  Both modes produce byte-identical
  schedules on the analytical backend (asserted in ``tests/test_runtime``).
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.plan import Config

from repro.runtime.executor import Executor
from repro.runtime.kvcache.manager import batch_tokens, logical_tokens
from repro.runtime.lifecycle import Phase, RequestState

PREEMPT_POLICIES = ("latest", "fewest-blocks")
PREEMPT_MODES = ("recompute", "swap", "auto")

# Admission-group cap for prefill-role replicas under disaggregation.
# Prefill is compute-bound — batching prompts into one admission event is
# time-linear (no throughput gain on either backend) but delays every
# prompt's KV handoff until the whole group finishes, stalling the decode
# pool.  Small groups keep the prefill->handoff->decode pipeline full.
PREFILL_HANDOFF_GROUP_CAP = 4

# Fused-decode horizon for decode-role replicas under disaggregation.
# A colocated replica may fuse a whole quota when its queue is empty —
# nothing else will feed it.  A decode-role replica is different: handoffs
# stream in continuously, and an unbounded fused chunk planned against a
# momentarily-empty queue locks the batch for seconds, parks every later
# delivery behind it, and re-forms aligned admission waves (batch
# collapses to the dribble admitted between waves).  Bounding the chunk
# forces a re-plan at a cadence where fresh deliveries join the batch.
DECODE_HANDOFF_CHUNK_STEPS = 32


class PendingEvent:
    """One planned-but-not-yet-executed replica event.

    ``kind`` is ``"prefill"`` (``batch`` is the admission group),
    ``"swapin"`` (``batch`` is a group of host-swapped requests being
    readmitted by block restore instead of prefill), ``"handoff"``
    (``batch`` is a group of prefill-finished requests whose KV is being
    exported to decode-role replicas; ``t_step`` carries the modeled
    transfer seconds) or ``"decode"`` (``batch``/``k``/``t_step`` are the
    lockstep chunk).  ``until`` records the barrier the event was planned
    under so completion can reproduce the sequential scheduler's
    post-event admission gating exactly.
    """

    __slots__ = ("kind", "batch", "k", "t_step", "until")

    def __init__(self, kind: str, batch: Sequence[RequestState], *,
                 k: int = 0, t_step: float = 0.0, until: float = math.inf):
        self.kind = kind
        self.batch = batch
        self.k = k
        self.t_step = t_step
        self.until = until

    def execute(self, executor: Executor, rep: int):
        """Run the executor side of this event (the only part that may run
        off the orchestrator thread).  Returns the executor's result —
        prefill offsets or the decode duration."""
        if self.kind == "prefill":
            return executor.prefill(rep, self.batch)
        if self.kind == "swapin":
            return executor.swap_in(rep, self.batch)
        if self.kind == "handoff":
            return executor.handoff_out(rep, self.batch, self.t_step)
        return executor.decode(rep, self.batch, self.k, self.t_step)


class ReplicaRuntime:
    """Event-driven continuous batching for one replica."""

    def __init__(self, index: int, config: Config, executor: Executor, *,
                 preempt_policy: str = "latest",
                 preempt_mode: str = "recompute", on_done=None, obs=None):
        if preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(f"preempt_policy must be one of "
                             f"{PREEMPT_POLICIES}, got {preempt_policy!r}")
        if preempt_mode not in PREEMPT_MODES:
            raise ValueError(f"preempt_mode must be one of "
                             f"{PREEMPT_MODES}, got {preempt_mode!r}")
        self.index = index
        self.config = config
        self.executor = executor
        self.preempt_policy = preempt_policy
        self.preempt_mode = preempt_mode
        # Disaggregation: "both" (colocated, the default), "prefill"
        # (admission + prefill, then KV handoff) or "decode" (receives
        # handoffs).  Prefill behavior only activates when the
        # orchestrator wires a HandoffManager into ``handoff_mgr``; a
        # bare ReplicaRuntime with a prefill-role config serves colocated.
        self.role = getattr(config, "role", "both")
        self.handoff_mgr = None
        # Prefill-finished requests awaiting a handoff event, in
        # admission order; they hold device blocks until the export.
        self.handoff_ready: List[RequestState] = []
        self.handoffs = 0
        self.handoff_blocks = 0
        # (req_id, target index, blocks) per completed handoff, in source
        # commit order — backend-independent for a deterministic target
        # topology, asserted in tests/test_disagg.
        self.handoff_log: List[Tuple[int, int, int]] = []
        # NIC timeline for KV exports: transfers overlap compute but
        # serialize among themselves on the replica's interconnect.
        self.nic_free = 0.0
        # Optional repro.obs.Observability; hooks fire at commit points
        # only and never read the clock (pure observer — see repro.obs).
        self.obs = obs
        # Completion hook (live sessions stream per-request results); always
        # fired on the orchestrator thread, after backend resources are
        # released.
        self.on_done = on_done
        self.queue: List[RequestState] = []    # sorted by arrival
        self.active: List[RequestState] = []
        self.now = 0.0
        self.busy = 0.0
        self.completed = 0
        self.preempted = 0
        self.draining = False
        self.dead = False             # torn down by a fault: never serves again
        self.dead_at = math.nan
        self._admission_seq = 0
        # event mode: after a completed event, whether the next event should
        # attempt admission before decoding (mirrors the sequential step's
        # trailing `_admit`)
        self._admit_turn = False
        # one tuple of req_ids per prefill group, in admission order —
        # backend-independent, so tests can assert the cost-model and
        # engine backends make identical admission decisions
        self.admission_log: List[Tuple[int, ...]] = []

    def enqueue(self, state: RequestState) -> None:
        state.replica = self.index
        bisect.insort(self.queue, state, key=lambda s: s.ready_at)

    def strip_queue(self) -> List[RequestState]:
        """Remove and return all not-yet-admitted requests (for migration).
        A host-swapped request cannot carry its parked blocks to another
        replica: its swap state is dropped and it degrades to recompute."""
        stripped, self.queue = self.queue, []
        mgr = self.executor.kv_manager(self.index)
        for s in stripped:
            if s.swapped:
                self.executor.drop_swapped(self.index, s)
                if mgr is not None:
                    mgr.drop_swapped(s.req.req_id)
                s.swapped = False
                s.remaining = 0
        return stripped

    def force_drain(self, t: float, *, grace: float = 0.0,
                    extra: Sequence[RequestState] = ()
                    ) -> Tuple[List[RequestState], List[RequestState],
                               Dict[int, tuple]]:
        """Tear this replica down at time ``t`` (spot reclaim or crash):
        the fault-driven counterpart of the replan ``draining`` path,
        except nothing gets to finish here — the device is going away.

        With ``grace > 0`` (a reclaim with notice) live requests swap out
        to the host tier in admission order for as long as the modeled
        copy-out time fits the remaining grace budget, and their host
        payloads are *exported* for adoption by a surviving replica
        (cross-replica swap restore); already-parked host copies of queued
        requests travel for free.  Everything that doesn't fit the window
        — and everything on an ungraceful crash, including the host tier
        itself — loses its KV state and degrades to a from-scratch
        re-serve (one ``retries`` tick).  ``extra`` carries requests in a
        planned-but-uncommitted event (a prefill group is in neither
        ``active`` nor ``queue``).

        Returns ``(displaced, lost, payloads)``: every request the caller
        must re-route (in admission order, then queue order), the subset
        whose work was lost (retry accounting), and the exported host
        payloads by req_id (``(symbolic blocks, physical payload)``).
        """
        self.dead = True
        self.dead_at = t
        self.draining = True
        self.now = max(self.now, t)
        mgr = self.executor.kv_manager(self.index)
        payloads: Dict[int, tuple] = {}
        lost: List[RequestState] = []
        seen = set()
        affected: List[RequestState] = []
        # handoff_ready requests hold device blocks exactly like active
        # ones (their export never ran): same swap-or-lose treatment.
        for s in list(self.active) + list(self.handoff_ready) + list(extra):
            if id(s) not in seen:
                seen.add(id(s))
                affected.append(s)
        self.handoff_ready = []
        affected.sort(key=lambda s: s.admission_index)
        budget = float(grace)
        for s in affected:
            rid = s.req.req_id
            use_swap = False
            if budget > 0 and self.executor.can_swap(self.index, s):
                swap_s, _ = self.executor.preempt_costs(self.index, s)
                if swap_s <= budget:
                    use_swap = True
                    budget -= swap_s
            if use_swap:
                # Physical copy-out before the symbolic swap-out recycles
                # the block ids (same order as ``_preempt``).
                self.executor.swap_out(self.index, s)
                mgr.swap_out(rid)
                sym = mgr.export_swapped(rid)
                phys = self.executor.export_swapped(self.index, s)
                payloads[rid] = (sym, phys)
                s.swapped = True
                s.preemptions += 1
                self.preempted += 1
            else:
                if mgr is not None:
                    mgr.free(rid)
                self.executor.preempt(self.index, s)
                s.remaining = 0
                s.swapped = False
                s.retries += 1
                lost.append(s)
            s.phase = Phase.QUEUED
        queued, self.queue = self.queue, []
        for s in queued:
            if not s.swapped:
                continue            # nothing parked: plain queue migration
            rid = s.req.req_id
            if grace > 0:
                sym = mgr.export_swapped(rid) if mgr is not None else 0
                phys = self.executor.export_swapped(self.index, s)
                payloads[rid] = (sym, phys)
            else:
                # the crash took the host tier with it
                self.executor.drop_swapped(self.index, s)
                if mgr is not None:
                    mgr.drop_swapped(rid)
                s.swapped = False
                s.remaining = 0
                s.retries += 1
                lost.append(s)
        self.active = []
        return affected + queued, lost, payloads

    def _finish(self, state: RequestState) -> None:
        state.phase = Phase.DONE
        state.finished_at = self.now
        self.completed += 1
        mgr = self.executor.kv_manager(self.index)
        if mgr is not None:
            mgr.free(state.req.req_id)
        self.executor.release(self.index, state)
        if self.obs is not None:
            self.obs.on_finish(self, state, self.now)
        if self.on_done is not None:
            self.on_done(state)

    def _pick_victim(self, batch: Sequence[RequestState]) -> RequestState:
        """Choose the preemption victim per ``preempt_policy``."""
        if self.preempt_policy == "fewest-blocks":
            mgr = self.executor.kv_manager(self.index)
            # cheapest recompute first; break ties toward latest-admitted
            # so the policy degenerates to the default on uniform holdings
            return min(batch, key=lambda s: (
                mgr.held_blocks(s.req.req_id), -s.admission_index))
        return max(batch, key=lambda s: s.admission_index)

    def _preempt(self, state: RequestState) -> None:
        """Evict one decoding request.  Recompute mode frees its KV blocks
        and sends it back to the queue to prefill again; swap mode parks
        the blocks in the host tier so readmission restores them instead.
        Auto mode compares the two modeled costs per victim."""
        self.active.remove(state)
        mgr = self.executor.kv_manager(self.index)
        use_swap = (self.preempt_mode != "recompute"
                    and self.executor.can_swap(self.index, state))
        if use_swap and self.preempt_mode == "auto":
            swap_s, recompute_s = self.executor.preempt_costs(self.index,
                                                              state)
            use_swap = swap_s < recompute_s
        swap_bytes = 0.0
        if use_swap:
            # Copy the physical blocks out *before* the symbolic swap-out
            # recycles their ids (the engine backend reads the device pool
            # rows the ids still address).
            self.executor.swap_out(self.index, state)
            n = mgr.swap_out(state.req.req_id)
            swap_bytes = n * self.executor.kv_block_bytes(self.index)
            state.swapped = True
        else:
            if mgr is not None:
                mgr.free(state.req.req_id)
            self.executor.preempt(self.index, state)
            state.remaining = 0
        state.phase = Phase.QUEUED
        state.preemptions += 1
        self.preempted += 1
        bisect.insort(self.queue, state, key=lambda s: s.ready_at)
        if self.obs is not None:
            self.obs.on_preempt(self, state, self.now, swapped=use_swap,
                                swap_bytes=swap_bytes)

    # ------------------------------------------------------------ planning

    def _plan_admission_event(self, until: float = math.inf
                              ) -> Optional[PendingEvent]:
        """One iteration of the admission loop: pop every queued request
        that has arrived and fits (count cap + KV blocks, FCFS) into one
        admission group, reserving its blocks.  A group is homogeneous —
        all fresh (kind ``"prefill"``) or all host-swapped (kind
        ``"swapin"``) — because the two readmission paths are different
        executor calls; the queue head decides the kind, keeping FCFS
        exact.  Returns None when no group can start (admission never
        *starts* at or after ``until``, so a replan barrier sees a
        consistent queue)."""
        if self.draining or not self.queue or self.now >= until:
            return None
        if self.handoff_ready or (
                self.handoff_mgr is not None
                and self.handoff_mgr.queue.parked_from(self.index)):
            # Handoff backpressure: while this replica has exported-but-
            # undelivered (or not-yet-exported) KV outstanding, admission
            # throttles — prefill capacity must not outrun the decode
            # pool's ability to absorb it.
            return None
        mgr = self.executor.kv_manager(self.index)
        group: List[RequestState] = []
        kind = "prefill"
        cap = math.inf
        if self.role == "prefill" and self.handoff_mgr is not None:
            cap = PREFILL_HANDOFF_GROUP_CAP
        for s in self.active:
            cap = min(cap, self.executor.max_batch(self.index,
                                                   s.req.workload))
        while self.queue:
            nxt = self.queue[0]
            if nxt.ready_at > self.now:
                if self.active or group:
                    break
                if nxt.ready_at >= until:
                    break   # the jump would start admission at/after the
                            # barrier (e.g. arrival == replan time): defer,
                            # exactly like the event heap does
                self.now = nxt.ready_at   # idle: jump to next arrival
            if group and nxt.swapped != (kind == "swapin"):
                break       # homogeneous group: next kind waits its turn
            c = min(cap, self.executor.max_batch(self.index,
                                                 nxt.req.workload))
            if len(self.active) + len(group) + 1 > max(1, int(c)):
                break
            solo = not self.active and not group
            if nxt.swapped:
                if mgr is None or not mgr.swap_in(
                        nxt.req.req_id,
                        logical_tokens(nxt.req.input_len, nxt.quota,
                                       nxt.remaining),
                        solo=solo):
                    break                    # FCFS: no queue jumping
                kind = "swapin"
            elif mgr is not None and not mgr.admit(
                    nxt.req.req_id, nxt.req.input_len + 1, solo=solo,
                    prompt=nxt.req.prompt):
                break                        # FCFS: no queue jumping
            self.queue.pop(0)
            nxt.phase = Phase.PREFILL
            nxt.admission_index = self._admission_seq
            self._admission_seq += 1
            group.append(nxt)
            cap = c
        if not group:
            return None
        self.admission_log.append(tuple(s.req.req_id for s in group))
        return PendingEvent(kind, group, until=until)

    def _plan_decode(self, until: float = math.inf) -> PendingEvent:
        """Choose the next lockstep decode chunk: batch, step count (never
        overshooting the next queued arrival or ``until``), preempting when
        the chunk cannot fit the block pool, then reserving the growth."""
        mgr = self.executor.kv_manager(self.index)
        while True:
            batch = list(self.active)
            t_step = self.executor.step_time(self.index, batch)
            k = min(s.remaining for s in batch)
            k = min(k, self.executor.max_steps_per_event)
            if self.handoff_mgr is not None and self.role != "prefill":
                k = min(k, DECODE_HANDOFF_CHUNK_STEPS)
            if self.queue and t_step > 0:
                next_arrival = self.queue[0].ready_at
                if next_arrival > self.now:
                    k = max(1, min(k, int((next_arrival - self.now)
                                          / max(t_step, 1e-12)) + 1))
            if until < math.inf and t_step > 0:
                k = max(1, min(k, int((until - self.now)
                                      / max(t_step, 1e-12)) + 1))
            if k > 1 and t_step <= 0.0 and (
                    until < math.inf
                    or (self.queue
                        and self.queue[0].ready_at > self.now)):
                # No step-time estimate yet (a real engine's first chunk):
                # the arrival/barrier clamps above are inoperative, so a
                # fused chunk would blast past a pending arrival or replan
                # barrier.  Take one measured step instead; from the next
                # event the EMA drives the clamps.
                k = 1
            if mgr is None:
                break
            k_fit = mgr.feasible_steps(batch_tokens(batch), k)
            if k_fit >= 1:
                k = k_fit
                break
            if len(batch) == 1 or self.draining:
                break   # progress guarantee: overflow instead of starving
            self._preempt(self._pick_victim(batch))
        if mgr is not None:
            for s in batch:
                mgr.grow(s.req.req_id,
                         logical_tokens(s.req.input_len, s.quota,
                                        s.remaining) + k,
                         allow_overflow=True)
        return PendingEvent("decode", batch, k=k, t_step=t_step, until=until)

    def _plan_handoff(self, until: float = math.inf
                      ) -> Optional[PendingEvent]:
        """Plan the export of ready prefill-finished requests to decode
        replicas: the :class:`~repro.runtime.disagg.HandoffManager`
        reserves a target (or transfer-queue room) per request and prices
        the modeled transfer; requests that fit neither stay in
        ``handoff_ready`` (backpressure — the pump re-plans us when
        capacity frees).  Returns None when nothing can move."""
        group, t_model = self.handoff_mgr.plan(self)
        if not group:
            return None
        return PendingEvent("handoff", group, t_step=t_model, until=until)

    # ---------------------------------------------------------- completion

    def _complete_prefill(self, group: Sequence[RequestState],
                          offsets: Sequence[float]) -> None:
        start = self.now
        for s, off in zip(group, offsets):
            s.phase = Phase.DECODE
            s.admitted_at = start
            s.first_token_at = start + off
            s.quota = self.executor.decode_quota(s.req)
            s.remaining = s.quota
        self.now = start + offsets[-1]
        self.busy += offsets[-1]
        for s in group:
            if s.remaining <= 0:    # quota exhausted by the first token
                self._finish(s)
            elif self.role == "prefill" and self.handoff_mgr is not None:
                # Disaggregated: the first token is this replica's last
                # work for the request — its KV hands off to a decode
                # replica instead of decoding here.
                s.phase = Phase.QUEUED
                self.handoff_ready.append(s)
            else:
                self.active.append(s)
        if self.obs is not None:
            self.obs.on_admit(self, group, start, offsets)

    def _complete_swapin(self, group: Sequence[RequestState],
                         offsets: Sequence[float]) -> None:
        """Commit a swap-in readmission: the group resumes decoding at its
        preserved position — ``quota``/``remaining``/``first_token_at``
        are untouched, so the emitted token stream is byte-identical to
        the recompute path's tail."""
        start = self.now
        mgr = self.executor.kv_manager(self.index)
        blocks = 0
        for s in group:
            s.phase = Phase.DECODE
            s.admitted_at = start
            s.swapped = False
            s.swap_ins += 1
            if mgr is not None:
                blocks += mgr.held_blocks(s.req.req_id)
        self.now = start + offsets[-1]
        self.busy += offsets[-1]
        for s in group:
            if s.remaining <= 0:   # defensive: quota exhausted pre-swap
                self._finish(s)
            elif self.role == "prefill" and self.handoff_mgr is not None:
                # A swapped request landed on a prefill replica (fault
                # migration): restore, then hand off — prefill replicas
                # never decode.
                s.phase = Phase.QUEUED
                self.handoff_ready.append(s)
            else:
                self.active.append(s)
        if self.obs is not None:
            self.obs.on_swap_in(
                self, group, start, offsets,
                swap_bytes=blocks * self.executor.kv_block_bytes(self.index))

    def _complete_handoff(self, pending: PendingEvent, result) -> None:
        """Commit an executed handoff export.  The transfer rides the
        replica's interconnect *in parallel* with upcoming compute —
        successive exports serialize on the NIC timeline (``nic_free``),
        not on the compute clock — so the manager delivers each payload
        at the NIC completion time while this replica immediately plans
        its next prefill."""
        payloads, duration = result
        start = max(self.now, self.nic_free)
        self.nic_free = start + duration
        blocks = self.handoff_mgr.commit(self, pending.batch, payloads,
                                         done_at=self.nic_free)
        if self.obs is not None:
            self.obs.on_handoff(
                self, pending.batch, start, self.nic_free,
                blocks=blocks,
                n_bytes=blocks * self.executor.kv_block_bytes(self.index))

    def _complete_decode(self, pending: PendingEvent,
                         duration: float) -> None:
        start = self.now
        self.now += duration
        self.busy += duration
        still: List[RequestState] = []
        for s in pending.batch:
            s.remaining -= pending.k
            if s.remaining <= 0:
                self._finish(s)
            else:
                still.append(s)
        self.active = still
        if self.obs is not None:
            self.obs.on_decode_chunk(self, pending.batch, pending.k,
                                     start, self.now)

    # ------------------------------------------------- event-mode interface

    def next_event_time(self) -> float:
        """Earliest time this replica's next event can start (``inf`` when
        it has nothing to do).  The orchestrator's global heap is keyed on
        this."""
        if self.active or self.handoff_ready:
            return self.now
        if self.queue and not self.draining:
            return max(self.now, self.queue[0].ready_at)
        return math.inf

    def begin_step(self, until: float = math.inf) -> Optional[PendingEvent]:
        """Plan (but do not execute) the next event starting strictly
        before ``until``: all queue/KV bookkeeping happens here, on the
        orchestrator thread; the returned event's :meth:`PendingEvent.execute`
        is the only part that may run elsewhere.  Returns None when no
        event can start."""
        if self.now >= until:
            return None
        if self.handoff_ready:
            event = self._plan_handoff(until)
            if event is not None:
                return event
            if self.handoff_ready:
                return None   # stalled: the pump re-pushes us when a
                              # decode replica frees capacity
        if not self.active:
            if not self.queue or self.draining:
                return None
            if self.queue[0].ready_at >= until:
                return None
            event = self._plan_admission_event(until)
            if event is None:
                return None
            self._admit_turn = True
            return event
        if self._admit_turn:
            event = self._plan_admission_event(until)
            if event is not None:
                return event
            self._admit_turn = False
        return self._plan_decode(until)

    def complete_step(self, pending: PendingEvent, result) -> None:
        """Commit an executed event: advance the clock by the executor's
        measured/predicted duration and retire finished requests."""
        if pending.kind == "prefill":
            self._complete_prefill(pending.batch, result)
        elif pending.kind == "swapin":
            self._complete_swapin(pending.batch, result)
        elif pending.kind == "handoff":
            self._complete_handoff(pending, result)
        else:
            self._complete_decode(pending, result)
        # The sequential scheduler re-attempts admission right after every
        # event *only* while still inside the barrier; reproduce that gate
        # so both modes admit at identical clocks.
        self._admit_turn = self.now < pending.until

    def step_event(self, until: float = math.inf) -> bool:
        """Plan + execute + commit one event synchronously (the event-heap
        path for non-concurrent executors).  Returns False when no event
        can start strictly before ``until``."""
        pending = self.begin_step(until)
        if pending is None:
            return False
        self.complete_step(pending, pending.execute(self.executor,
                                                    self.index))
        return True

    # --------------------------------------------- sequential-mode interface

    def _admit(self, until: float = math.inf) -> bool:
        """Admit arrived requests in batched groups, paying each group's
        prefill (or swap-in restore); loops so arrivals landing during a
        prefill window are admitted before decode resumes.  Returns True
        when at least one group was admitted (throttled/blocked admission
        makes no progress — the sequential driver must not spin on it)."""
        admitted = False
        while True:
            event = self._plan_admission_event(until)
            if event is None:
                return admitted
            admitted = True
            result = event.execute(self.executor, self.index)
            if event.kind == "prefill":
                self._complete_prefill(event.batch, result)
            else:
                self._complete_swapin(event.batch, result)

    def step(self, until: float = math.inf) -> bool:
        """Advance one compound event (admission, handoff export, and/or
        lockstep decode).  Returns False when no event can start strictly
        before ``until`` — atomic events may still complete past it.
        This is the sequential drive mode; the event heap uses
        :meth:`begin_step` / :meth:`complete_step` instead."""
        if self.now >= until:
            return False
        if self.handoff_ready:
            event = self._plan_handoff(until)
            if event is not None:
                self._complete_handoff(
                    event, event.execute(self.executor, self.index))
                return True
            # Everything either degraded (progress: handoff_ready
            # drained without a transfer) or stalled on backpressure.
            return not self.handoff_ready
        if not self.active:
            if not self.queue or self.draining:
                return False
            if self.queue[0].ready_at >= until:
                return False
            progressed = self._admit(until)
            if not self.active:
                return progressed  # first-token completions / handoffs /
                                   # throttled admission (no progress)
            if self.now >= until:
                return True   # prefill crossed the barrier: decode may not
                              # *start* at/after until (event mode defers it
                              # identically, keeping the modes byte-equal)
        pending = self._plan_decode(until)
        self._complete_decode(pending, pending.execute(self.executor,
                                                       self.index))
        self._admit(until)
        return True
