"""Continuous-batching replica scheduler — the shared serving core.

One :class:`ReplicaRuntime` owns one replica's queue and active batch and
advances a local clock through admission (prefill) and lockstep decode
events.  The same loop drives both backends: with the
:class:`~repro.runtime.executor.CostModelExecutor` it *is* the cluster
simulator's inner loop; with the
:class:`~repro.runtime.executor.EngineExecutor` every event performs real
jit'd token generation and the clock advances by measured wall time.

Semantics (inherited from the validated simulator, now shared):

* admission groups every queued request that has arrived and fits under
  both the backend's concurrency cap and the replica's **KV block budget**
  (:class:`~repro.runtime.kvcache.KVCacheManager`): a request is admitted
  when its prompt (+ first token) blocks can be reserved, in FCFS order —
  memory, not a fixed ``max_batch``, is what bounds the batch;
* decode advances the whole active batch in lockstep steps; the scheduler
  fast-forwards at most ``executor.max_steps_per_event`` steps, never
  overshoots the next queued arrival (so admission happens mid-flight),
  and never outgrows the block pool: when the next step does not fit, the
  most-recently-admitted request is **preempted by recompute** — its
  blocks are freed and it re-enters the queue to prefill again later
  (recorded in ``RequestState.preemptions``);
* a ``draining`` replica (removed by a replan) finishes its active batch
  but admits nothing new — and never preempts, since its queue can no
  longer drain through admission;
* a replica always makes progress: a single active request may overflow
  the budget rather than starve (undersized replicas serve one request at
  a time, exactly like the legacy fixed-cap scheduler).
"""
from __future__ import annotations

import bisect
import math
from typing import List, Tuple

from repro.core.plan import Config

from repro.runtime.executor import Executor
from repro.runtime.kvcache.manager import batch_tokens, logical_tokens
from repro.runtime.lifecycle import Phase, RequestState


class ReplicaRuntime:
    """Event-driven continuous batching for one replica."""

    def __init__(self, index: int, config: Config, executor: Executor):
        self.index = index
        self.config = config
        self.executor = executor
        self.queue: List[RequestState] = []    # sorted by arrival
        self.active: List[RequestState] = []
        self.now = 0.0
        self.busy = 0.0
        self.completed = 0
        self.preempted = 0
        self.draining = False
        self._admission_seq = 0
        # one tuple of req_ids per prefill group, in admission order —
        # backend-independent, so tests can assert the cost-model and
        # engine backends make identical admission decisions
        self.admission_log: List[Tuple[int, ...]] = []

    def enqueue(self, state: RequestState) -> None:
        state.replica = self.index
        bisect.insort(self.queue, state, key=lambda s: s.req.arrival)

    def strip_queue(self) -> List[RequestState]:
        """Remove and return all not-yet-admitted requests (for migration)."""
        stripped, self.queue = self.queue, []
        return stripped

    def _finish(self, state: RequestState) -> None:
        state.phase = Phase.DONE
        state.finished_at = self.now
        self.completed += 1
        mgr = self.executor.kv_manager(self.index)
        if mgr is not None:
            mgr.free(state.req.req_id)
        self.executor.release(self.index, state)

    def _preempt(self, state: RequestState) -> None:
        """Evict one decoding request to recompute: free its KV blocks and
        send it back to the queue; it will prefill again when admitted."""
        self.active.remove(state)
        mgr = self.executor.kv_manager(self.index)
        if mgr is not None:
            mgr.free(state.req.req_id)
        self.executor.preempt(self.index, state)
        state.phase = Phase.QUEUED
        state.preemptions += 1
        state.remaining = 0
        self.preempted += 1
        bisect.insort(self.queue, state, key=lambda s: s.req.arrival)

    def _admit(self, until: float = math.inf) -> None:
        """Admit arrived requests in batched groups, paying each group's
        prefill; loops so arrivals landing during a prefill window are
        admitted before decode resumes.  Admission never *starts* at or
        after ``until`` (so a replan barrier sees a consistent queue)."""
        if self.draining:
            return
        mgr = self.executor.kv_manager(self.index)
        while self.queue and self.now < until:
            group: List[RequestState] = []
            cap = math.inf
            for s in self.active:
                cap = min(cap, self.executor.max_batch(self.index,
                                                       s.req.workload))
            while self.queue:
                nxt = self.queue[0]
                if nxt.req.arrival > self.now:
                    if self.active or group:
                        break
                    self.now = nxt.req.arrival   # idle: jump to next arrival
                c = min(cap, self.executor.max_batch(self.index,
                                                     nxt.req.workload))
                if len(self.active) + len(group) + 1 > max(1, int(c)):
                    break
                solo = not self.active and not group
                if mgr is not None and not mgr.admit(
                        nxt.req.req_id, nxt.req.input_len + 1, solo=solo):
                    break                        # FCFS: no queue jumping
                self.queue.pop(0)
                nxt.phase = Phase.PREFILL
                nxt.admission_index = self._admission_seq
                self._admission_seq += 1
                group.append(nxt)
                cap = c
            if not group:
                return
            self.admission_log.append(tuple(s.req.req_id for s in group))
            start = self.now
            offsets = self.executor.prefill(self.index, group)
            for s, off in zip(group, offsets):
                s.phase = Phase.DECODE
                s.admitted_at = start
                s.first_token_at = start + off
                s.quota = self.executor.decode_quota(s.req)
                s.remaining = s.quota
            self.now = start + offsets[-1]
            self.busy += offsets[-1]
            for s in group:
                if s.remaining <= 0:    # quota exhausted by the first token
                    self._finish(s)
                else:
                    self.active.append(s)

    def step(self, until: float = math.inf) -> bool:
        """Advance one event (admission and/or lockstep decode).  Returns
        False when no event can start strictly before ``until`` — atomic
        events may still complete past it."""
        if self.now >= until:
            return False
        if not self.active:
            if not self.queue or self.draining:
                return False
            if self.queue[0].req.arrival >= until:
                return False
            self._admit(until)
            if not self.active:
                return True   # admitted requests completed at the first token
        mgr = self.executor.kv_manager(self.index)
        while True:
            batch = list(self.active)
            t_step = self.executor.step_time(self.index, batch)
            k = min(s.remaining for s in batch)
            k = min(k, self.executor.max_steps_per_event)
            if self.queue and t_step > 0:
                next_arrival = self.queue[0].req.arrival
                if next_arrival > self.now:
                    k = max(1, min(k, int((next_arrival - self.now)
                                          / max(t_step, 1e-12)) + 1))
            if until < math.inf and t_step > 0:
                k = max(1, min(k, int((until - self.now)
                                      / max(t_step, 1e-12)) + 1))
            if mgr is None:
                break
            k_fit = mgr.feasible_steps(batch_tokens(batch), k)
            if k_fit >= 1:
                k = k_fit
                break
            if len(batch) == 1 or self.draining:
                break   # progress guarantee: overflow instead of starving
            self._preempt(max(batch, key=lambda s: s.admission_index))
        if mgr is not None:
            for s in batch:
                mgr.grow(s.req.req_id,
                         logical_tokens(s.req.input_len, s.quota,
                                        s.remaining) + k,
                         allow_overflow=True)
        duration = self.executor.decode(self.index, batch, k, t_step)
        self.now += duration
        self.busy += duration
        still: List[RequestState] = []
        for s in batch:
            s.remaining -= k
            if s.remaining <= 0:
                self._finish(s)
            else:
                still.append(s)
        self.active = still
        self._admit(until)
        return True
