"""Pluggable execution backends for the shared continuous-batching core.

The replica scheduler (``repro.runtime.replica``) owns *when* requests are
admitted, batched, and stepped; an :class:`Executor` owns *how long* (and,
for real backends, *actually doing*) each prefill / decode step takes:

* :class:`CostModelExecutor` — durations from ``repro.core.costmodel``;
  this is the simulator backend (what ``core.simulator.simulate`` runs on).
* :class:`EngineExecutor` — real token generation through
  ``repro.serving.engine.ReplicaEngine`` replicas; scheduling runs on the
  *measured* wall time of each jit'd prefill/decode call, at runtime scale
  (synthetic ``input_len``-token prompts, decode capped at ``max_new``).

Both backends sit behind the same admission/batching/routing code path, so
plan evaluation and plan execution cannot drift apart.
"""
from __future__ import annotations

import abc
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import costmodel
from repro.core.costmodel import ModelProfile
from repro.core.plan import Config, ServingPlan
from repro.core.workloads import WORKLOAD_TYPES, Request

from repro.runtime.lifecycle import RequestState


class Executor(abc.ABC):
    """Timing + side-effect backend for one pool of replicas.

    ``max_steps_per_event`` bounds how many lockstep decode steps the
    scheduler may fast-forward per event: unbounded for analytical backends
    (O(#requests) events), 1 for real engines (every token is a real call).
    """

    max_steps_per_event: int = 10**9

    @abc.abstractmethod
    def add_replica(self, config: Config) -> None:
        """Register one more replica (used by mid-trace replanning)."""

    @abc.abstractmethod
    def decode_quota(self, req: Request) -> int:
        """Decode steps this backend runs for ``req`` after the first token."""

    @abc.abstractmethod
    def max_batch(self, rep: int, workload_index: int) -> int:
        """Concurrent-batch cap of replica ``rep`` for one workload class."""

    @abc.abstractmethod
    def prefill(self, rep: int, states: Sequence[RequestState]
                ) -> Sequence[float]:
        """Admit a group: run/cost its prefill.  Returns each request's
        first-token completion offset from the call start (monotone; the
        last entry is the total duration charged to the replica clock)."""

    @abc.abstractmethod
    def step_time(self, rep: int, states: Sequence[RequestState]) -> float:
        """Predicted duration of one lockstep decode step (0 if unknown)."""

    @abc.abstractmethod
    def decode(self, rep: int, states: Sequence[RequestState], k: int,
               step_time: float) -> float:
        """Run/cost ``k`` lockstep decode steps for the batch; returns the
        elapsed duration.  ``step_time`` is the scheduler's value from
        :meth:`step_time` for this event (so analytical backends don't
        re-evaluate the cost model)."""

    def release(self, rep: int, state: RequestState) -> None:
        """A request finished on replica ``rep`` (free backend resources)."""


class CostModelExecutor(Executor):
    """Analytical backend: step durations from the paper's cost model.

    Replaces the guts of the old ``core/simulator.py`` replica loop —
    serialized per-request prefill on admission, memory-bound lockstep
    decode whose duration tracks batch size and mean context length.
    """

    def __init__(self, replicas: Sequence[Config] | ServingPlan,
                 models: Optional[Sequence[ModelProfile]] = None):
        if isinstance(replicas, ServingPlan):
            replicas = replicas.replicas
        self.configs: List[Config] = []
        self.models: List[ModelProfile] = []
        self._model_table = models
        for cfg in replicas:
            self.add_replica(cfg)

    def add_replica(self, config: Config) -> None:
        self.configs.append(config)
        if self._model_table is not None:
            self.models.append(self._model_table[config.model_index])
        else:
            self.models.append(config.model)

    def decode_quota(self, req: Request) -> int:
        return max(1, req.output_len)

    def max_batch(self, rep: int, workload_index: int) -> int:
        cfg, model = self.configs[rep], self.models[rep]
        return int(costmodel.max_batch_size(cfg.stages, model,
                                            WORKLOAD_TYPES[workload_index]))

    def prefill(self, rep: int, states: Sequence[RequestState]
                ) -> Sequence[float]:
        cfg, model = self.configs[rep], self.models[rep]
        offs, t = [], 0.0
        for s in states:
            t += max(costmodel._stage_prefill_time(st, model, s.req.input_len)
                     for st in cfg.stages)
            offs.append(t)
        return offs

    def step_time(self, rep: int, states: Sequence[RequestState]) -> float:
        cfg, model = self.configs[rep], self.models[rep]
        avg_ctx = float(np.mean([s.req.input_len + (s.quota - s.remaining)
                                 for s in states])) + 1.0
        return max(costmodel._stage_decode_step_time(st, model, len(states),
                                                     avg_ctx)
                   for st in cfg.stages)

    def decode(self, rep: int, states: Sequence[RequestState], k: int,
               step_time: float) -> float:
        return k * step_time


class _EngineGroup:
    """One admission cohort decoding together on a real engine (shared
    prompt shape -> shared cache tensors; lockstep position counter)."""

    def __init__(self, req_ids: List[int], caches, tok, pos: int):
        self.req_ids = set(req_ids)
        self.caches = caches
        self.tok = tok
        self.pos = pos


class EngineExecutor(Executor):
    """Real-token backend: one ``ReplicaEngine`` per plan replica.

    Trace token lengths are cost-model scale; real generation runs at
    runtime scale — synthetic prompts of ``input_len`` tokens and at most
    ``max_new`` generated tokens per request — exactly like the old
    ``HeterogeneousServer`` did, but now batch formation comes from the
    shared continuous-batching scheduler instead of fixed-size chunking.
    """

    max_steps_per_event = 1

    def __init__(self, plan: ServingPlan | Sequence[Config],
                 arch_cfgs: Sequence, *,
                 params_per_model: Optional[Dict[int, object]] = None,
                 max_batch: int = 8, input_len: int = 16, max_new: int = 8,
                 seed: int = 0):
        replicas = plan.replicas if isinstance(plan, ServingPlan) else plan
        self.arch_cfgs = list(arch_cfgs)
        self.params_per_model = params_per_model or {}
        self.max_batch_cap = max_batch
        self.input_len = input_len
        self.max_new = max_new
        self.engines: List = []
        self._groups: List[List[_EngineGroup]] = []
        for cfg in replicas:
            self.add_replica(cfg)
        self._base_replicas = len(self.engines)
        self.configure(seed=seed)

    def configure(self, *, input_len: Optional[int] = None,
                  max_new: Optional[int] = None, seed: int = 0) -> None:
        """Reset counters (and optionally the runtime scale) before a run."""
        if input_len is not None:
            self.input_len = input_len
        if max_new is not None:
            self.max_new = max_new
        self._rng = np.random.default_rng(seed)
        self.generated_tokens = 0
        self.compute_s = 0.0       # measured seconds inside jit'd calls
        # Engines appended by a previous run's replan belong to that run's
        # transient plan: drop them so replica indices line up with a fresh
        # ServingRuntime built over the base plan.
        del self.engines[self._base_replicas:]
        self._groups = [[] for _ in self.engines]

    def add_replica(self, config: Config) -> None:
        from repro.serving.engine import ReplicaEngine  # lazy: avoids cycle
        arch = self.arch_cfgs[config.model_index]
        self.engines.append(ReplicaEngine(
            arch, params=self.params_per_model.get(config.model_index),
            seed=config.model_index))
        self._groups.append([])

    def decode_quota(self, req: Request) -> int:
        return max(0, min(max(1, req.output_len), self.max_new) - 1)

    def max_batch(self, rep: int, workload_index: int) -> int:
        return self.max_batch_cap

    def prefill(self, rep: int, states: Sequence[RequestState]
                ) -> Sequence[float]:
        import jax
        import jax.numpy as jnp
        engine = self.engines[rep]
        arch = engine.cfg
        b = len(states)
        prompts = jnp.asarray(self._rng.integers(
            0, arch.vocab_size, size=(b, self.input_len)), jnp.int32)
        prefix = None
        n_prefix = 0
        if arch.frontend != "none":
            n_prefix = arch.num_patches
            prefix = jnp.asarray(self._rng.normal(
                0, 0.02, size=(b, n_prefix, arch.d_model)), jnp.bfloat16)
        t_max = self.input_len + n_prefix + self.max_new
        t0 = time.perf_counter()
        tok, caches = engine.prefill_batch(prompts, t_max,
                                           prefix_embeds=prefix)
        jax.block_until_ready(tok)
        elapsed = time.perf_counter() - t0
        self.generated_tokens += b
        self.compute_s += elapsed
        self._groups[rep].append(_EngineGroup(
            [s.req.req_id for s in states], caches, tok,
            self.input_len + n_prefix))
        return [elapsed] * b

    def step_time(self, rep: int, states: Sequence[RequestState]) -> float:
        return 0.0   # unknown ahead of time; max_steps_per_event=1 anyway

    def decode(self, rep: int, states: Sequence[RequestState], k: int,
               step_time: float) -> float:
        import jax
        del step_time     # unknown ahead of time; the clock uses wall time
        assert k == 1, "EngineExecutor decodes one real token per event"
        ids = {s.req.req_id for s in states}
        total = 0.0
        for g in self._groups[rep]:
            live = len(g.req_ids & ids)
            if not live:
                continue
            t0 = time.perf_counter()
            tok, caches = self.engines[rep].decode_batch(g.caches, g.tok,
                                                         g.pos)
            jax.block_until_ready(tok)
            elapsed = time.perf_counter() - t0
            g.tok, g.caches, g.pos = tok, caches, g.pos + 1
            self.generated_tokens += live
            self.compute_s += elapsed
            total += elapsed
        return total

    def release(self, rep: int, state: RequestState) -> None:
        groups = self._groups[rep]
        for g in groups:
            if state.req.req_id in g.req_ids:
                g.req_ids.discard(state.req.req_id)
                if not g.req_ids:
                    groups.remove(g)   # free the cohort's cache tensors
                return
