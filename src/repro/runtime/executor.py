"""Pluggable execution backends for the shared continuous-batching core.

The replica scheduler (``repro.runtime.replica``) owns *when* requests are
admitted, batched, stepped, and preempted; an :class:`Executor` owns *how
long* (and, for real backends, *actually doing*) each prefill / decode
step takes:

* :class:`CostModelExecutor` — durations from ``repro.core.costmodel``;
  this is the simulator backend (what ``core.simulator.simulate`` runs on).
* :class:`EngineExecutor` — real token generation through
  ``repro.serving.engine.ReplicaEngine`` replicas; scheduling runs on the
  *measured* wall time of each jit'd prefill/decode call, at runtime scale
  (synthetic ``input_len``-token prompts, decode capped at ``max_new``).

Both backends expose the same per-replica
:class:`~repro.runtime.kvcache.KVCacheManager`, sized from the identical
``core.costmodel.kv_free_bytes`` HBM budget, so admission (and
preemption) decisions are block accounting — the cost-model backend
accounts the blocks symbolically, while the engine backend additionally
backs them with real ``(num_blocks, block_size, KV, D)`` pool tensors
(:class:`~repro.runtime.kvcache.PagedEngineCache`) that its paged decode
gathers through per-sequence block tables.
"""
from __future__ import annotations

import abc
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core.costmodel import ModelProfile
from repro.core.plan import Config, ServingPlan
from repro.core.workloads import Request

from repro.runtime.kvcache.budget import (DEFAULT_BLOCK_SIZE, block_bytes,
                                          host_blocks_for, make_kv_manager)
from repro.runtime.kvcache.manager import KVCacheManager
from repro.runtime.kvcache.paged import (DEFAULT_ENGINE_BLOCK_SIZE,
                                         PagedEngineCache)
from repro.runtime.lifecycle import RequestState


class Executor(abc.ABC):
    """Timing + side-effect backend for one pool of replicas.

    ``max_steps_per_event`` bounds how many lockstep decode steps the
    scheduler may fast-forward per event: unbounded for analytical backends
    (O(#requests) events), ``fused_steps`` for real engines (the whole
    chunk executes as one horizon-fused device call — see
    :class:`EngineExecutor`).

    ``concurrent`` declares the backend's threading contract: when True the
    runtime may run :meth:`prefill` / :meth:`decode` on per-replica worker
    threads (calls for *one* replica are always serialized; calls for
    different replicas may overlap in wall time).  Every other method is
    only ever called from the orchestrator thread, and never while that
    replica has an executor call in flight.
    """

    max_steps_per_event: int = 10**9
    concurrent: bool = False
    # Cross-request prefix caching: when True the backend's KV managers
    # run content-hashed prefix sharing (and the engine backend backs the
    # sharing physically).  The runtime reads this to enable warm-prefix
    # routing affinity.
    prefix_cache: bool = False

    # Optional repro.obs.Observability (set by the runtime when tracing is
    # on).  Backends report each executor call's duration through
    # :meth:`_observe` *after* the duration is known — never inside their
    # timing brackets, so enabling observability cannot perturb measured
    # durations.
    obs = None

    # Optional per-chunk token stream: when set (the live Session does),
    # token-producing backends call ``token_sink(req_id, [tokens...])``
    # once per executed event, in token order, from whatever thread runs
    # the event (per-request calls never interleave: one replica owns a
    # request and its calls are serialized).  Analytical backends produce
    # no tokens and never call it.
    token_sink: Optional[Callable[[int, List[int]], None]] = None

    @abc.abstractmethod
    def add_replica(self, config: Config) -> None:
        """Register one more replica (used by mid-trace replanning)."""

    @abc.abstractmethod
    def decode_quota(self, req: Request) -> int:
        """Decode steps this backend runs for ``req`` after the first token."""

    @abc.abstractmethod
    def max_batch(self, rep: int, workload_index: int) -> int:
        """Concurrency cap of replica ``rep`` for one workload class (a
        count limit; *memory* limits live in :meth:`kv_manager`)."""

    def kv_manager(self, rep: int) -> Optional[KVCacheManager]:
        """Replica ``rep``'s KV block accounting, or None when the backend
        has no per-token KV growth (admission falls back to the count cap)."""
        return None

    @abc.abstractmethod
    def prefill(self, rep: int, states: Sequence[RequestState]
                ) -> Sequence[float]:
        """Admit a group: run/cost its prefill.  Returns each request's
        first-token completion offset from the call start (monotone; the
        last entry is the total duration charged to the replica clock)."""

    @abc.abstractmethod
    def step_time(self, rep: int, states: Sequence[RequestState]) -> float:
        """Predicted duration of one lockstep decode step (0 if unknown)."""

    def step_time_estimate(self, rep: int) -> float:
        """Batch-free decode-step estimate for observability and the
        autoscaler's :class:`~repro.core.scheduler.ReplicaSnapshot` (0 when
        the backend has no standing estimate)."""
        return 0.0

    @abc.abstractmethod
    def decode(self, rep: int, states: Sequence[RequestState], k: int,
               step_time: float) -> float:
        """Run/cost ``k`` lockstep decode steps for the batch; returns the
        elapsed duration.  ``step_time`` is the scheduler's value from
        :meth:`step_time` for this event (so analytical backends don't
        re-evaluate the cost model)."""

    def generated_tokens_for(self, rep: int) -> int:
        """Tokens replica ``rep`` has generated so far (0 for analytical
        backends, which produce none) — read by observability sampling."""
        return 0

    def _observe(self, rep: int, kind: str, seconds: float) -> None:
        """Report one executor call's duration (``kind``: ``"prefill"`` /
        ``"decode"``) to the attached observability, if any — *measured
        wall* seconds on real backends, *modeled* seconds on analytical
        ones."""
        obs = self.obs
        if obs is not None:
            obs.on_compute(rep, kind, seconds)

    def release(self, rep: int, state: RequestState) -> None:
        """A request finished on replica ``rep`` (free backend resources)."""

    def preempt(self, rep: int, state: RequestState) -> None:
        """A request was evicted mid-decode (recompute): drop its backend
        state; it re-enters through :meth:`prefill` when re-admitted."""
        self.release(rep, state)

    # ------------------------------------------------- swap-based preemption

    def kv_block_bytes(self, rep: int) -> float:
        """HBM bytes one trace-scale KV block occupies on replica ``rep``
        (0 when the backend has no block accounting) — the unit swap
        counters and the cost-aware preemption decision price bytes in."""
        return 0.0

    def can_swap(self, rep: int, state: RequestState) -> bool:
        """True when ``state`` could be preempted by swap-out right now
        (host tier configured, victim's block set fits the free host
        budget, and the backend can physically copy it)."""
        return False

    def preempt_costs(self, rep: int, state: RequestState
                      ) -> Tuple[float, float]:
        """(modeled swap seconds, modeled recompute seconds) for preempting
        ``state`` — both *analytical*, never measured, so the cost and
        engine backends make identical ``preempt_mode="auto"`` choices on
        the same trace.  Default: swapping is never cheaper."""
        return math.inf, 0.0

    def swap_out(self, rep: int, state: RequestState) -> None:
        """Copy a preemption victim's KV out to the host tier and release
        its device-side state (the symbolic manager bookkeeping is the
        replica scheduler's job).  Only called when :meth:`can_swap`."""
        raise NotImplementedError

    def swap_in(self, rep: int, states: Sequence[RequestState]
                ) -> Sequence[float]:
        """Readmit a group of swapped-out requests: restore their KV from
        the host tier.  Returns per-request completion offsets like
        :meth:`prefill` (monotone; last entry = total duration)."""
        raise NotImplementedError

    def drop_swapped(self, rep: int, state: RequestState) -> None:
        """Discard a swapped-out request's host copy (it migrated away and
        will recompute elsewhere)."""

    # ------------------------------------------- cross-replica swap restore

    def export_swapped(self, rep: int, state: RequestState):
        """Detach a swapped-out request's host-tier payload from replica
        ``rep`` so a *different* replica can restore it (graceful spot
        reclaim: the doomed replica's host copies migrate with their
        requests).  Returns an opaque payload for :meth:`import_swapped`,
        or None when the backend holds nothing to migrate — the caller
        then degrades the request to recompute."""
        return None

    def import_swapped(self, rep: int, state: RequestState,
                       payload) -> bool:
        """Adopt a payload from :meth:`export_swapped` into replica
        ``rep``'s host tier, so the request swap-readmits there as if it
        had been swapped out locally.  Returns False (state unchanged)
        when the payload cannot be adopted (shape mismatch across
        heterogeneous replicas, no paged storage, ...)."""
        return False

    # --------------------------------------------- prefill/decode handoff

    def handoff_out(self, rep: int, states: Sequence[RequestState],
                    t_model: float):
        """Export every state's KV off replica ``rep`` for migration to a
        decode-role replica (the source side of a disaggregated
        prefill→decode handoff): physical copy-out, then detach the
        payload — the same two moves as a cross-replica swap migration,
        minus the local host-tier charge (the symbolic side is
        ``KVCacheManager.handoff_out``, the caller's job at commit).
        Returns ``(payloads by req_id, duration)`` — ``t_model`` (the
        modeled transfer seconds) on analytical backends, measured wall
        seconds on real ones."""
        payloads = {}
        for s in states:
            self.swap_out(rep, s)
            payloads[s.req.req_id] = self.export_swapped(rep, s)
        self._observe(rep, "handoff", t_model)
        return payloads, t_model

    def teardown(self, rep: int) -> None:
        """Replica ``rep`` was torn down by a fault (spot reclaim /
        crash): drop whatever backend state only that replica's hardware
        held.  Called after the orchestrator has drained/exported every
        in-flight request; the replica is never executed again."""


class CostModelExecutor(Executor):
    """Analytical backend: step durations from the paper's cost model.

    Replaces the guts of the old ``core/simulator.py`` replica loop —
    serialized per-request prefill on admission, memory-bound lockstep
    decode whose duration tracks batch size and mean context length.
    Admission is block accounting against the replica's modeled HBM
    budget; ``max_batch`` only carries the global concurrency cap the
    paper's serving regime assumes (``costmodel.MAX_BATCH``).
    """

    def __init__(self, replicas: Sequence[Config] | ServingPlan,
                 models: Optional[Sequence[ModelProfile]] = None, *,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 prefix_cache: bool = False,
                 host_blocks: int = 0,
                 host_ram_bytes=None):
        if isinstance(replicas, ServingPlan):
            replicas = replicas.replicas
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self.host_blocks = max(0, int(host_blocks))
        # Host-RAM-derived two-tier sizing: a number (bytes per replica)
        # or "auto" (sum the catalog's per-device host_ram_bytes over the
        # replica's stages).  When set it supersedes the flat
        # ``host_blocks`` count; None keeps the legacy behavior.
        self.host_ram_bytes = host_ram_bytes
        self.configs: List[Config] = []
        self.models: List[ModelProfile] = []
        self.kv_managers: List[Optional[KVCacheManager]] = []
        self._model_table = models
        for cfg in replicas:
            self.add_replica(cfg)
        self._base_replicas = len(self.configs)

    def _host_blocks_for(self, config: Config, model: ModelProfile) -> int:
        return host_blocks_for(config, model, self.host_ram_bytes,
                               self.block_size, default=self.host_blocks)

    def configure(self) -> None:
        """Reset to the base plan before a reuse run (the session/server
        lifecycle): drop replicas appended by a previous run's
        replan/autoscale — so indices line up with a freshly-reset
        ``ServingRuntime`` — and rebuild the KV managers empty."""
        del self.configs[self._base_replicas:]
        del self.models[self._base_replicas:]
        del self.kv_managers[self._base_replicas:]
        for i, cfg in enumerate(self.configs):
            self.kv_managers[i] = make_kv_manager(
                cfg, self.models[i], self.block_size,
                prefix_cache=self.prefix_cache,
                host_blocks=self._host_blocks_for(cfg, self.models[i]))

    def add_replica(self, config: Config) -> None:
        self.configs.append(config)
        if self._model_table is not None:
            self.models.append(self._model_table[config.model_index])
        else:
            self.models.append(config.model)
        self.kv_managers.append(make_kv_manager(
            config, self.models[-1], self.block_size,
            prefix_cache=self.prefix_cache,
            host_blocks=self._host_blocks_for(config, self.models[-1])))

    def decode_quota(self, req: Request) -> int:
        return max(1, req.output_len)

    def max_batch(self, rep: int, workload_index: int) -> int:
        del rep, workload_index
        return costmodel.MAX_BATCH

    def kv_manager(self, rep: int) -> Optional[KVCacheManager]:
        return self.kv_managers[rep]

    def prefill(self, rep: int, states: Sequence[RequestState]
                ) -> Sequence[float]:
        cfg, model = self.configs[rep], self.models[rep]
        mgr = self.kv_managers[rep]
        offs, t = [], 0.0
        for s in states:
            # Warm-prefix admissions only recompute the unique suffix: the
            # KV manager records how many prompt tokens the prefix index
            # served, and the prefill charge shrinks accordingly (at least
            # one token always computes — the first logits need it).
            eff = s.req.input_len
            if mgr is not None:
                eff = max(1, eff - mgr.prefix_hit_tokens(s.req.req_id))
            t += max(costmodel._stage_prefill_time(st, model, eff)
                     for st in cfg.stages)
            if mgr is not None:
                # Hit blocks revived from the host tier cost a host-link
                # copy instead of prefill FLOPs.
                hb = mgr.host_hit_blocks(s.req.req_id)
                if hb:
                    t += costmodel.swap_time_s(
                        cfg.stages, hb * block_bytes(model, self.block_size))
            offs.append(t)
        self._observe(rep, "prefill", t)
        return offs

    def step_time(self, rep: int, states: Sequence[RequestState]) -> float:
        cfg, model = self.configs[rep], self.models[rep]
        avg_ctx = float(np.mean([s.req.input_len + (s.quota - s.remaining)
                                 for s in states])) + 1.0
        return max(costmodel._stage_decode_step_time(st, model, len(states),
                                                     avg_ctx)
                   for st in cfg.stages)

    def decode(self, rep: int, states: Sequence[RequestState], k: int,
               step_time: float) -> float:
        self._observe(rep, "decode", k * step_time)
        return k * step_time

    # ------------------------------------------------- swap-based preemption

    def kv_block_bytes(self, rep: int) -> float:
        return block_bytes(self.models[rep], self.block_size)

    def can_swap(self, rep: int, state: RequestState) -> bool:
        mgr = self.kv_managers[rep]
        return mgr is not None and mgr.can_swap_out(state.req.req_id)

    def preempt_costs(self, rep: int, state: RequestState
                      ) -> Tuple[float, float]:
        cfg, model = self.configs[rep], self.models[rep]
        mgr = self.kv_managers[rep]
        blocks = mgr.held_blocks(state.req.req_id) if mgr is not None else 0
        return costmodel.preempt_costs(
            cfg.stages, model,
            swap_bytes=blocks * block_bytes(model, self.block_size),
            prompt_tokens=state.req.input_len)

    def swap_out(self, rep: int, state: RequestState) -> None:
        pass          # symbolic backend: the manager's bookkeeping is all

    def swap_in(self, rep: int, states: Sequence[RequestState]
                ) -> Sequence[float]:
        cfg, model = self.configs[rep], self.models[rep]
        mgr = self.kv_managers[rep]
        bb = block_bytes(model, self.block_size)
        offs, t = [], 0.0
        for s in states:
            # Charged here, at readmission: copy-out + copy-in of the
            # blocks now restored (swap-out itself takes no event — it
            # mirrors recompute, where eviction is free and the cost lands
            # at re-prefill).
            blocks = mgr.held_blocks(s.req.req_id)
            t += costmodel.swap_time_s(cfg.stages, 2.0 * blocks * bb)
            offs.append(t)
        self._observe(rep, "swapin", t)
        return offs

    # ------------------------------------------- cross-replica swap restore

    def export_swapped(self, rep: int, state: RequestState):
        # Symbolic backend: the block accounting migrates through the KV
        # managers' own export/import (the orchestrator's job); a sentinel
        # marks "payload exists" so both backends walk the same branch.
        return ()

    def import_swapped(self, rep: int, state: RequestState,
                       payload) -> bool:
        return payload is not None


class _EngineGroup:
    """One admission cohort decoding together on a real engine (shared
    prompt shape -> shared cache tensors; lockstep position counter).
    Only used on archs the paged path does not cover (hybrid/recurrent
    mixers); pure-attention replicas decode through one shared
    ``PagedEngineCache`` instead."""

    def __init__(self, req_ids: List[int], caches, tok, pos: int):
        self.order = list(req_ids)     # lane -> req_id (fixed at prefill)
        self.req_ids = set(req_ids)
        self.caches = caches
        self.tok = tok
        self.pos = pos


class EngineExecutor(Executor):
    """Real-token backend: one ``ReplicaEngine`` per plan replica.

    Trace token lengths are cost-model scale; real generation runs at
    runtime scale — synthetic prompts of ``input_len`` tokens and at most
    ``max_new`` generated tokens per request.  Admission accounting runs at
    *trace* scale through the same :class:`KVCacheManager` budget the
    cost-model backend uses (so both make identical admission decisions);
    execution-side KV storage is *physically paged*: each pure-attention
    replica owns real block pools and per-sequence block tables
    (:class:`PagedEngineCache`) and decodes every live sequence — across
    admission cohorts — in one shape-stable lockstep call.

    Decode is **horizon-fused**: the scheduler may hand :meth:`decode` a
    chunk of up to ``fused_steps`` lockstep steps (it already clamps the
    chunk at arrivals, barriers, quotas, and the KV block budget — and
    pre-reserves the chunk's block growth, so preemption decisions are
    identical to stepwise execution).  The engine runs the whole chunk
    on-device via scan-based multi-step decode and the executor performs
    **one host sync and one ``(B, k)`` token transfer per event** instead
    of one per token; paged replicas additionally split the chunk at KV
    block boundaries (each fused scan keeps every slot's write block
    fixed).  ``fused_steps=1`` restores the legacy one-token-per-event
    behavior with byte-identical token streams and admission logs — the
    fused scan body is the same traced step, so fusion changes dispatch
    count, never tokens.
    """

    DEFAULT_FUSED_STEPS = 16

    def __init__(self, plan: ServingPlan | Sequence[Config],
                 arch_cfgs: Sequence, *,
                 params_per_model: Optional[Dict[int, object]] = None,
                 models: Optional[Sequence[ModelProfile]] = None,
                 max_batch: int = 8, input_len: int = 16, max_new: int = 8,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 engine_block_size: int = DEFAULT_ENGINE_BLOCK_SIZE,
                 paged: Optional[bool] = None, concurrent: bool = True,
                 fused_steps: Optional[int] = None,
                 prefix_cache: bool = False,
                 host_blocks: int = 0,
                 host_ram_bytes=None,
                 seed: int = 0,
                 clock: Optional[Callable[[], float]] = None):
        replicas = plan.replicas if isinstance(plan, ServingPlan) else plan
        # Injectable time source for the measured prefill/decode brackets
        # (``t0 = clock(); ...; elapsed = clock() - t0``).  Default is real
        # wall time; tests pin a deterministic repro.obs.TickClock so
        # schedules don't shift under machine load (see repro.obs.clock).
        self.clock: Callable[[], float] = clock or time.perf_counter
        self.arch_cfgs = list(arch_cfgs)
        self.params_per_model = params_per_model or {}
        self._model_table = models
        self.prefix_cache = prefix_cache
        self.host_blocks = max(0, int(host_blocks))
        # Same host-RAM sizing policy as CostModelExecutor (None / bytes /
        # "auto"): both backends derive identical trace-scale host tiers.
        self.host_ram_bytes = host_ram_bytes
        self.max_batch_cap = max_batch
        self.input_len = input_len
        self.max_new = max_new
        self.block_size = block_size
        self.engine_block_size = engine_block_size
        self.paged_enabled = paged
        self.concurrent = concurrent
        self.max_steps_per_event = max(1, int(
            self.DEFAULT_FUSED_STEPS if fused_steps is None else fused_steps))
        self.engines: List = []
        self.configs: List[Config] = []
        self.kv_managers: List[Optional[KVCacheManager]] = []
        self._groups: List[List[_EngineGroup]] = []
        self._paged: List[Optional[PagedEngineCache]] = []
        self._gen_tokens: List[int] = []
        self._compute_s: List[float] = []
        self._step_ema: List[float] = []
        for cfg in replicas:
            self.add_replica(cfg)
        self._base_replicas = len(self.engines)
        self.configure(seed=seed)

    def configure(self, *, input_len: Optional[int] = None,
                  max_new: Optional[int] = None,
                  seed: Optional[int] = None) -> None:
        """Reset counters (and optionally the runtime scale / prompt seed)
        before a run; omitted arguments keep their current values."""
        if input_len is not None:
            self.input_len = input_len
        if max_new is not None:
            self.max_new = max_new
        if seed is not None or not hasattr(self, "_seed"):
            self._seed = 0 if seed is None else seed
        # Per-request token trail (req_id -> every token emitted for it,
        # including recompute re-prefills) — interleaving-independent, so
        # concurrent and sequential runs must produce identical trails.
        self.token_log: Dict[int, List[int]] = {}
        # Live sessions: real prompt token ids per req_id (padded/truncated
        # to ``input_len`` at prefill); requests without an entry keep the
        # per-request synthetic RNG prompt.
        self.prompt_overrides: Dict[int, np.ndarray] = {}
        self.token_sink = None
        # Engines appended by a previous run's replan belong to that run's
        # transient plan: drop them so replica indices line up with a fresh
        # ServingRuntime built over the base plan.
        del self.engines[self._base_replicas:]
        del self.configs[self._base_replicas:]
        del self.kv_managers[self._base_replicas:]
        self._groups = [[] for _ in self.engines]
        self._paged = [None] * len(self.engines)   # rebuilt at first prefill
        self._gen_tokens = [0] * len(self.engines)
        self._compute_s = [0.0] * len(self.engines)
        self._step_ema = [0.0] * len(self.engines)
        for i, cfg in enumerate(self.configs):
            self.kv_managers[i] = make_kv_manager(
                cfg, self._model_of(cfg), self.block_size,
                prefix_cache=self.prefix_cache,
                host_blocks=host_blocks_for(
                    cfg, self._model_of(cfg), self.host_ram_bytes,
                    self.block_size, default=self.host_blocks))

    # Counters are kept per replica (each replica's executor calls are
    # serialized on its own worker thread, so no locks are needed) and
    # aggregated on demand.

    @property
    def generated_tokens(self) -> int:
        return sum(self._gen_tokens)

    @property
    def compute_s(self) -> float:
        """Total measured seconds inside jit'd calls, summed over replicas
        (under concurrent execution wall time can be well below this)."""
        return sum(self._compute_s)

    def _model_of(self, config: Config) -> ModelProfile:
        if self._model_table is not None:
            return self._model_table[config.model_index]
        return config.model

    def device_for(self, rep: int):
        """Device a concurrent replica worker should pin its calls to —
        round-robin over ``jax.devices()`` when more than one is visible
        (e.g. ``--xla_force_host_platform_device_count``), else None."""
        if not self.concurrent:
            return None
        import jax
        devices = jax.devices()
        if len(devices) <= 1:
            return None
        return devices[rep % len(devices)]

    def add_replica(self, config: Config) -> None:
        from repro.serving.engine import ReplicaEngine  # lazy: avoids cycle
        arch = self.arch_cfgs[config.model_index]
        index = len(self.engines)
        self.engines.append(ReplicaEngine(
            arch, params=self.params_per_model.get(config.model_index),
            seed=config.model_index, device=self.device_for(index)))
        self.configs.append(config)
        self.kv_managers.append(make_kv_manager(
            config, self._model_of(config), self.block_size,
            prefix_cache=self.prefix_cache,
            host_blocks=host_blocks_for(
                config, self._model_of(config), self.host_ram_bytes,
                self.block_size, default=self.host_blocks)))
        self._groups.append([])
        self._paged.append(None)
        self._gen_tokens.append(0)
        self._compute_s.append(0.0)
        self._step_ema.append(0.0)

    def decode_quota(self, req: Request) -> int:
        # min(output_len, max_new - 1) decode steps after the prefill token:
        # equals the cost-model backend's quota whenever the runtime budget
        # covers the trace (output_len < max_new), so both backends walk
        # identical token-growth curves through the KV manager.
        return max(0, min(max(1, req.output_len), self.max_new - 1))

    def max_batch(self, rep: int, workload_index: int) -> int:
        return self.max_batch_cap

    def kv_manager(self, rep: int) -> Optional[KVCacheManager]:
        return self.kv_managers[rep]

    def _paged_cache(self, rep: int) -> Optional[PagedEngineCache]:
        """Lazily build replica ``rep``'s physical block pools (sized for
        the current runtime scale); None when the arch is not paged-capable
        or paging was explicitly disabled."""
        if self._paged[rep] is None:
            engine = self.engines[rep]
            use = (engine.paged_supported if self.paged_enabled is None
                   else self.paged_enabled and engine.paged_supported)
            if not use:
                return None
            arch = engine.cfg
            n_prefix = arch.num_patches if arch.frontend != "none" else 0
            # Physical prefix matching hashes token rows, so it stays off
            # for multimodal archs whose prompts also carry patch embeds
            # (token ids alone would under-key the content hash).
            num_slots = max(1, self.max_batch_cap)
            t_max = self.input_len + n_prefix + self.max_new
            # The physical host tier keeps the same host:device proportion
            # as the symbolic manager's trace-scale budget (the two layers
            # run at different block scales, like the device pools do).
            mgr = self.kv_managers[rep]
            engine_host = 0
            if mgr is not None and mgr.host_blocks > 0 and mgr.num_blocks > 0:
                bps = max(1, math.ceil(t_max / self.engine_block_size))
                engine_host = math.ceil(num_slots * bps * mgr.host_blocks
                                        / mgr.num_blocks)
            self._paged[rep] = PagedEngineCache(
                arch, num_slots=num_slots, t_max=t_max,
                block_size=self.engine_block_size,
                prefix_cache=self.prefix_cache and n_prefix == 0,
                host_blocks=engine_host)
        return self._paged[rep]

    def _prompt_arrays(self, arch, states: Sequence[RequestState]):
        """Synthetic prompt (and optional multimodal prefix) for a cohort.
        Drawn from a *per-request* RNG keyed on (seed, req_id) so every
        request's tokens are independent of how executor calls interleave
        across replicas — concurrent and sequential runs generate
        identical prompts, hence identical outputs."""
        import jax.numpy as jnp
        rows, prefix_rows = [], []
        n_prefix = arch.num_patches if arch.frontend != "none" else 0
        for s in states:
            rng = np.random.default_rng((self._seed, s.req.req_id))
            override = self.prompt_overrides.get(s.req.req_id)
            if override is None and s.req.prompt is not None:
                # Trace-carried prompt ids (shared-prefix traces): same
                # pad/truncate treatment as live-session overrides.
                override = np.asarray(s.req.prompt, dtype=np.int64)
            if override is not None:
                # Real prompt (live submit): pad/truncate to the cohort's
                # uniform prompt shape.
                row = np.zeros(self.input_len, dtype=np.int64)
                n = min(len(override), self.input_len)
                row[:n] = np.asarray(override, dtype=np.int64)[:n] \
                    % arch.vocab_size
                rows.append(row)
            else:
                rows.append(rng.integers(0, arch.vocab_size,
                                         size=self.input_len))
            if n_prefix:
                prefix_rows.append(rng.normal(
                    0, 0.02, size=(n_prefix, arch.d_model)))
        rows = [np.asarray(r, dtype=np.int64) for r in rows]
        prompts = jnp.asarray(np.stack(rows), jnp.int32)
        prefix = (jnp.asarray(np.stack(prefix_rows), jnp.bfloat16)
                  if n_prefix else None)
        return prompts, prefix, n_prefix, rows

    def _log_tokens(self, req_id: int, tokens) -> None:
        """Append one event's token chunk to the request's trail and, when
        a live session attached a sink, stream the chunk to it (same order
        as the log, so handle streams replay ``token_log`` exactly)."""
        toks = [int(t) for t in tokens]
        self.token_log.setdefault(req_id, []).extend(toks)
        sink = self.token_sink
        if sink is not None:
            sink(req_id, toks)

    def prefill(self, rep: int, states: Sequence[RequestState]
                ) -> Sequence[float]:
        import jax
        import jax.numpy as jnp
        engine = self.engines[rep]
        arch = engine.cfg
        b = len(states)
        prompts, prefix, n_prefix, rows = self._prompt_arrays(arch, states)
        t_prompt = self.input_len + n_prefix
        paged = self._paged_cache(rep)
        use_prefix = paged is not None and paged.prefix_cache
        if not use_prefix:
            # Cold-only path (prefix caching off, multimodal, or dense
            # cohorts): one full-prompt prefill for the whole cohort.
            # Paged replicas only need the prompt's K/V from prefill
            # (decode tokens land in the block pools); dense cohorts carry
            # the full generation budget in their contiguous caches.
            t_max = t_prompt if paged is not None else t_prompt + self.max_new
            t0 = self.clock()
            tok, caches = engine.prefill_batch(prompts, t_max,
                                               prefix_embeds=prefix)
            jax.block_until_ready(tok)
            elapsed = self.clock() - t0
            self._gen_tokens[rep] += b
            self._compute_s[rep] += elapsed
            self._observe(rep, "prefill", elapsed)
            first = np.asarray(tok)
            for s, t in zip(states, first):
                self._log_tokens(s.req.req_id, [t])
            if paged is not None:
                paged.admit_cohort([s.req.req_id for s in states], caches,
                                   first, t_prompt)
            else:
                self._groups[rep].append(_EngineGroup(
                    [s.req.req_id for s in states], caches, tok, t_prompt))
            return [elapsed] * b
        # Prefix-cached path: split the cohort by matched-prefix length.
        # Cold requests run the full-prompt prefill; warm requests adopt
        # the matched blocks (refcounted aliases, no copy) and compute only
        # their unique suffix through the suffix-bucketed jit.
        hashes = [paged.block_hashes(rows[j], t_prompt) for j in range(b)]
        hits = [paged.match_len(h) for h in hashes]
        groups: Dict[int, List[int]] = {}
        for j, n_hit in enumerate(hits):
            groups.setdefault(n_hit, []).append(j)
        # Adopt every matched prefix up front: taking the references first
        # pins the matched blocks, so the cold group's allocations cannot
        # LRU-evict a block a warm group is about to alias.
        prefix_ids = {j: paged.adopt_prefix(hashes[j][:hits[j]])
                      for j in range(b) if hits[j]}
        total = 0.0
        first_all = np.zeros(b, dtype=np.int64)
        for n_hit in sorted(groups):
            idxs = groups[n_hit]
            rids = [states[j].req.req_id for j in idxs]
            sub_hashes = [hashes[j] for j in idxs]
            sub_prompts = (prompts if len(idxs) == b
                           else prompts[np.asarray(idxs)])
            t0 = self.clock()
            if n_hit == 0:
                tok, caches = engine.prefill_batch(sub_prompts, t_prompt)
                jax.block_until_ready(tok)
                elapsed = self.clock() - t0
                first = np.asarray(tok)
                paged.admit_cohort(rids, caches, first, t_prompt,
                                   block_hashes_per_req=sub_hashes)
            else:
                t_hit = n_hit * paged.block_size
                pref = [prefix_ids[j] for j in idxs]
                tables = jnp.asarray(np.asarray(pref, np.int32))
                tok, suf_caches = engine.prefill_suffix_batch(
                    sub_prompts[:, t_hit:], paged.pools, tables, t_hit)
                jax.block_until_ready(tok)
                elapsed = self.clock() - t0
                first = np.asarray(tok)
                paged.admit_prefixed(rids, pref, suf_caches, first,
                                     t_hit, t_prompt, sub_hashes)
            total += elapsed
            self._compute_s[rep] += elapsed
            self._observe(rep, "prefill", elapsed)
            for j, t in zip(idxs, first):
                first_all[j] = int(t)
        self._gen_tokens[rep] += b
        for s, t in zip(states, first_all):
            self._log_tokens(s.req.req_id, [int(t)])
        return [total] * b

    def step_time(self, rep: int, states: Sequence[RequestState]) -> float:
        """Per-step EMA of this replica's measured decode durations (0.0
        until the first decode): fused chunk durations are normalized by
        their step count before entering the EMA, so the scheduler's
        arrival/barrier clamps and the autoscaler's snapshots always see
        seconds *per token*, whatever the fusion factor."""
        return self._step_ema[rep]

    def step_time_estimate(self, rep: int) -> float:
        return self._step_ema[rep]

    def generated_tokens_for(self, rep: int) -> int:
        return self._gen_tokens[rep]

    EMA_ALPHA = 0.3

    def _record_step(self, rep: int, elapsed: float) -> None:
        ema = self._step_ema[rep]
        self._step_ema[rep] = (elapsed if ema == 0.0
                               else self.EMA_ALPHA * elapsed
                               + (1.0 - self.EMA_ALPHA) * ema)

    def decode(self, rep: int, states: Sequence[RequestState], k: int,
               step_time: float) -> float:
        """Run the scheduler's ``k``-step lockstep chunk fused on-device:
        one host sync and one ``(B, k)`` token transfer per event (per
        cohort on non-paged archs), with the measured chunk duration
        normalized to per-step before it feeds the EMA."""
        import jax
        import jax.numpy as jnp
        del step_time     # predicted (EMA); the clock uses measured wall time
        k = max(1, int(k))
        engine = self.engines[rep]
        paged = self._paged[rep]
        if paged is not None:
            assert {s.req.req_id for s in states} == set(paged._slot_of), \
                "paged decode expects the replica's full active set"
            pools, tables, lengths, toks = paged.step_args()
            t0 = self.clock()
            blocks = []
            done = 0
            while done < k:
                # each fused scan keeps every slot inside its current KV
                # block; chunks split at the earliest boundary crossing
                sub = min(k - done, paged.steps_to_boundary())
                tok_blk, pools = engine.paged_decode_k(
                    pools, tables, lengths, toks, sub)
                blocks.append(tok_blk)
                toks = tok_blk[:, -1]
                paged.advance(sub)
                lengths = jnp.asarray(paged.lengths)
                done += sub
            all_toks = (blocks[0] if len(blocks) == 1
                        else jnp.concatenate(blocks, axis=1))
            jax.block_until_ready(all_toks)
            elapsed = self.clock() - t0
            slot_tok = np.asarray(all_toks)        # one (S, k) transfer
            paged.commit_chunk(slot_tok[:, -1], pools)
            for s in states:
                self._log_tokens(s.req.req_id,
                                 slot_tok[paged.slot_of(s.req.req_id)])
            self._gen_tokens[rep] += len(states) * k
            self._compute_s[rep] += elapsed
            self._record_step(rep, elapsed / k)
            self._observe(rep, "decode", elapsed)
            return elapsed
        ids = {s.req.req_id for s in states}
        total = 0.0
        for g in self._groups[rep]:
            live = len(g.req_ids & ids)
            if not live:
                continue
            t0 = self.clock()
            toks, caches = engine.decode_batch_k(g.caches, g.tok, g.pos, k)
            jax.block_until_ready(toks)
            elapsed = self.clock() - t0
            g.tok, g.caches, g.pos = toks[:, -1], caches, g.pos + k
            lane_tok = np.asarray(toks)            # one (B, k) transfer
            for lane, rid in enumerate(g.order):
                if rid in g.req_ids and rid in ids:
                    self._log_tokens(rid, lane_tok[lane])
            self._gen_tokens[rep] += live * k
            self._compute_s[rep] += elapsed
            total += elapsed
        if total > 0:
            self._record_step(rep, total / k)
            self._observe(rep, "decode", total)
        return total

    def release(self, rep: int, state: RequestState) -> None:
        paged = self._paged[rep]
        if paged is not None:
            paged.release(state.req.req_id)
            return
        groups = self._groups[rep]
        for g in groups:
            if state.req.req_id in g.req_ids:
                g.req_ids.discard(state.req.req_id)
                if not g.req_ids:
                    groups.remove(g)   # free the cohort's cache tensors
                return

    # ------------------------------------------------- swap-based preemption

    def kv_block_bytes(self, rep: int) -> float:
        return block_bytes(self._model_of(self.configs[rep]),
                           self.block_size)

    def can_swap(self, rep: int, state: RequestState) -> bool:
        # Decision inputs are trace-scale (the shared manager), so both
        # backends agree; the engine additionally needs physical paged
        # storage to copy blocks from (dense cohort caches cannot swap —
        # "swap" mode degrades to recompute for them on both backends only
        # if neither can; mixed paged/dense plans should be driven with
        # recompute mode when cross-backend log equality matters).
        mgr = self.kv_managers[rep]
        return (mgr is not None and mgr.can_swap_out(state.req.req_id)
                and self._paged[rep] is not None)

    def preempt_costs(self, rep: int, state: RequestState
                      ) -> Tuple[float, float]:
        cfg = self.configs[rep]
        model = self._model_of(cfg)
        mgr = self.kv_managers[rep]
        blocks = mgr.held_blocks(state.req.req_id) if mgr is not None else 0
        return costmodel.preempt_costs(
            cfg.stages, model,
            swap_bytes=blocks * block_bytes(model, self.block_size),
            prompt_tokens=state.req.input_len)

    def swap_out(self, rep: int, state: RequestState) -> None:
        # Runs synchronously at preemption time on the planning thread (a
        # deliberate asymmetry with the cost backend, which charges both
        # copy directions at swap-in: eviction is free there exactly like
        # recompute's).  The measured swap-in event carries the timed part.
        self._paged[rep].swap_out_request(state.req.req_id)

    def swap_in(self, rep: int, states: Sequence[RequestState]
                ) -> Sequence[float]:
        import jax
        paged = self._paged[rep]
        t0 = self.clock()
        for s in states:
            paged.swap_in_request(s.req.req_id)
        jax.block_until_ready(paged.pools[0]["k"])
        elapsed = self.clock() - t0
        self._compute_s[rep] += elapsed
        self._observe(rep, "swapin", elapsed)
        return [elapsed] * len(states)

    def drop_swapped(self, rep: int, state: RequestState) -> None:
        paged = self._paged[rep]
        if paged is not None:
            paged.drop_swapped(state.req.req_id)

    # ------------------------------------------- cross-replica swap restore

    def export_swapped(self, rep: int, state: RequestState):
        paged = self._paged[rep]
        if paged is None:
            return None
        return paged.export_swapped(state.req.req_id)

    def import_swapped(self, rep: int, state: RequestState,
                       payload) -> bool:
        paged = self._paged_cache(rep)
        if paged is None or payload is None:
            return False
        return paged.import_swapped(state.req.req_id, payload)

    # --------------------------------------------- prefill/decode handoff

    def handoff_out(self, rep: int, states: Sequence[RequestState],
                    t_model: float):
        # Physical copy-out of each finished prefill's KV into detached
        # NumPy payloads.  Dense replicas (no paged storage) have nothing
        # exportable: None payloads make the delivery side degrade to
        # recompute on the decode replica — the same branch the cost
        # backend only takes when the symbolic import fails.
        del t_model       # scheduling already advanced by the modeled time
        paged = self._paged[rep]
        if paged is None:
            return {s.req.req_id: None for s in states}, 0.0
        t0 = self.clock()
        payloads = {}
        for s in states:
            paged.swap_out_request(s.req.req_id)
            payloads[s.req.req_id] = paged.export_swapped(s.req.req_id)
        elapsed = self.clock() - t0
        self._compute_s[rep] += elapsed
        self._observe(rep, "handoff", elapsed)
        return payloads, elapsed

    def teardown(self, rep: int) -> None:
        # The dead replica's paged KV pools (device arrays) and host-tier
        # slot accounting must not outlive the fault: exported payloads
        # are already detached NumPy, so dropping the cache frees the
        # rest.  The engine itself stays (its weights may be shared with
        # surviving replicas of the same model).
        paged = self._paged[rep]
        if paged is not None and paged._host_pool is not None:
            paged._host_pool.reset()
        self._paged[rep] = None
