"""Prefill/decode disaggregation: KV handoff between replica roles.

The ``"disagg"`` planner strategy (``repro.core.scheduler``) emits paired
pools of :class:`~repro.core.plan.Config` replicas with ``role="prefill"``
and ``role="decode"``.  At runtime a prefill-role replica runs admission +
prefill only; when a request's first token lands, its paged KV blocks
migrate to a decode-role replica over the cross-replica swap path
(``export_swapped`` / ``import_swapped``) instead of decoding locally.
This module owns the cluster-level half of that flow:

* :class:`HandoffManager` — plans each prefill replica's handoff event
  (target selection + symbolic host-tier reservation on the target),
  commits the source-side export, and delivers payloads by enqueueing the
  request on its decode target, where it readmits through the ordinary
  swap-in admission path — so the resumed decode is byte-identical to a
  colocated run (the same invariant the swap/migration subsystem keeps).
* :class:`TransferQueue` — the bounded park for handoffs no decode
  replica can currently accept.  While a prefill replica has parked
  transfers, its admission throttles (backpressure): prefill capacity
  stops outrunning decode capacity instead of piling staged KV without
  bound.

Target choice prefers warm-prefix then least-loaded decode replicas and
breaks ties by replica index; capacity gating is the target manager's
``import_swapped`` (symbolic host-tier blocks), so the cost-model and
engine backends accept/refuse identically.  A payload that *no* live
decode replica could ever hold (host tier too small, or no paged
storage) degrades to recompute on the least-loaded target — the request
still migrates, it just re-prefills there.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Dict, List, Sequence, Tuple

from repro.core import costmodel

from repro.runtime.kvcache.manager import logical_tokens
from repro.runtime.lifecycle import Phase, RequestState


class _Handoff:
    """One in-flight KV migration (planned, then exported, then delivered)."""

    __slots__ = ("state", "src", "blocks", "dst", "payload", "done_at")

    def __init__(self, state: RequestState, src, blocks: int, dst):
        self.state = state
        self.src = src          # source ReplicaRuntime
        self.blocks = blocks    # symbolic (trace-scale) block count
        self.dst = dst          # reserved target ReplicaRuntime, or None
        self.payload = None     # backend payload once exported
        self.done_at = 0.0      # NIC completion time of the export


class TransferQueue:
    """Bounded FIFO of exported-but-undelivered handoffs."""

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._q: deque = deque()
        self.peak = 0

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self):
        return iter(self._q)

    @property
    def room(self) -> int:
        return max(0, self.capacity - len(self._q))

    def append(self, rec: _Handoff) -> None:
        self._q.append(rec)
        self.peak = max(self.peak, len(self._q))

    def peek(self) -> _Handoff:
        return self._q[0]

    def popleft(self) -> _Handoff:
        return self._q.popleft()

    def parked_from(self, index: int) -> bool:
        return any(rec.src.index == index for rec in self._q)

    def drain(self) -> List[_Handoff]:
        out, self._q = list(self._q), deque()
        return out


class HandoffManager:
    """Cluster-level coordinator for prefill→decode KV handoffs.

    The orchestrator wires one manager per run (when the plan carries
    role-split replicas) and injects it into every
    :class:`~repro.runtime.replica.ReplicaRuntime`; all methods run on
    the orchestrator thread (planning and commit are replica bookkeeping,
    never executor calls).  :attr:`touched` accumulates replica indices
    whose runnable state changed (a delivery landed, a source
    unthrottled) so the event loop can re-push them onto its heap; the
    orchestrator drains it after every pump.
    """

    def __init__(self, executor, replicas: Callable[[], Sequence], *,
                 queue_capacity: int = 8, obs=None):
        self.executor = executor
        self._replicas = replicas       # () -> live ReplicaRuntime list
        self.queue = TransferQueue(queue_capacity)
        self.obs = obs
        # rid -> _Handoff for planned-but-uncommitted handoff events
        self._planned: Dict[int, _Handoff] = {}
        # dst index -> reserved-but-undelivered handoffs: the load the
        # target picker must see *now* (its active/queue lengths only
        # update at delivery, so without this every request planned in
        # one event would pile onto the same least-loaded target).
        self._inflight: Dict[int, int] = {}
        self.touched: set = set()
        self.delivered = 0              # payload adopted by the target
        self.degraded = 0               # migrated by recompute instead
        self.parked_total = 0           # times a handoff entered the queue

    # --------------------------------------------------------- target choice

    def _warmth(self, rep, state: RequestState) -> int:
        if (not getattr(self.executor, "prefix_cache", False)
                or state.req.prompt is None):
            return 0
        mgr = self.executor.kv_manager(rep.index)
        if mgr is None:
            return 0
        return mgr.cached_prefix_tokens(state.req.prompt,
                                        state.req.input_len + 1)

    def _candidates(self, src, state: RequestState) -> List:
        """Live decode-capable targets for ``state``, preferred order:
        warm-prefix desc, then least loaded, then lowest index (the
        deterministic tie-break both backends share).  Load counts
        reserved-but-undelivered handoffs (``_inflight``) on top of the
        target's admitted + queued requests — without that term every
        request planned in one event would pile onto the same
        instantaneously-least-loaded target."""
        reps = [r for r in self._replicas()
                if r.index != src.index and not r.dead and not r.draining
                and r.config.role != "prefill"
                and r.config.model_index == src.config.model_index]
        reps.sort(key=lambda r: (-self._warmth(r, state),
                                 len(r.active) + len(r.queue)
                                 + self._inflight.get(r.index, 0),
                                 r.index))
        return reps

    def _reserve(self, src, state: RequestState, blocks: int):
        """Pick a target and reserve its symbolic host-tier blocks; None
        when no candidate can accept right now."""
        rid = state.req.req_id
        for r in self._candidates(src, state):
            mgr = self.executor.kv_manager(r.index)
            if mgr is None or blocks > mgr.host_blocks:
                continue
            if mgr.import_swapped(rid, blocks):
                self._inflight[r.index] = self._inflight.get(r.index, 0) + 1
                return r
        return None

    def _release(self, index: int) -> None:
        """One reservation on ``index`` resolved (delivered or returned)."""
        left = self._inflight.get(index, 0) - 1
        if left > 0:
            self._inflight[index] = left
        else:
            self._inflight.pop(index, None)

    def _fits_somewhere(self, src, state: RequestState, blocks: int) -> bool:
        """Could any live candidate *ever* hold this payload?  Static in
        the host-tier sizes, so both backends answer identically."""
        for r in self._candidates(src, state):
            mgr = self.executor.kv_manager(r.index)
            if mgr is not None and blocks <= mgr.host_blocks:
                return True
        return False

    # -------------------------------------------------------------- planning

    def plan(self, rep) -> Tuple[List[RequestState], float]:
        """Plan replica ``rep``'s next handoff event: reserve a target (or
        transfer-queue room) for each ready request, in admission order.
        Requests that fit neither stay in ``rep.handoff_ready`` — the
        hard-stall backpressure tier.  Requests no target could ever hold
        migrate by recompute immediately (no transfer to pay).  Returns
        ``(event batch, modeled transfer seconds)``."""
        mgr = self.executor.kv_manager(rep.index)
        bb = self.executor.kv_block_bytes(rep.index)
        group: List[RequestState] = []
        t_model = 0.0
        room = self.queue.room
        for s in list(rep.handoff_ready):
            rid = s.req.req_id
            blocks = (mgr.blocks_for(logical_tokens(
                s.req.input_len, s.quota, s.remaining))
                if mgr is not None else 0)
            dst = self._reserve(rep, s, blocks)
            if dst is None:
                if not self._fits_somewhere(rep, s, blocks):
                    tgt = self._pick_degrade(rep, s)
                    if tgt is None:
                        continue    # no decode pool at all: wait for one
                    rep.handoff_ready.remove(s)
                    self._drop_source(rep, s, mgr)
                    self._finish_degrade(rep, s, tgt, planned=True)
                    continue
                if room <= 0:
                    continue        # queue full: hard backpressure stall
                room -= 1
            rep.handoff_ready.remove(s)
            self._planned[rid] = _Handoff(s, rep, blocks, dst)
            group.append(s)
            dst_stages = (dst.config.stages if dst is not None
                          else rep.config.stages)
            t_model += costmodel.handoff_time_s(rep.config.stages,
                                                dst_stages, blocks * bb)
        return group, t_model

    def _pick_degrade(self, src, state: RequestState):
        cands = self._candidates(src, state)
        return cands[0] if cands else None

    def _drop_source(self, rep, state: RequestState, mgr) -> None:
        """Release the source's device blocks + backend state without a
        transfer (the degrade path: nothing can adopt the payload)."""
        if mgr is not None:
            mgr.free(state.req.req_id)
        self.executor.preempt(rep.index, state)

    def _finish_degrade(self, rep, state: RequestState, tgt, *,
                        planned: bool) -> None:
        """Deliver a handoff as recompute migration: the request moves to
        the decode target with no KV and re-prefills there.  ``planned``
        marks the no-transfer path (counted as a zero-block handoff on
        the source's log; pump-side degrades were already logged at
        export time)."""
        state.swapped = False
        state.remaining = 0
        state.phase = Phase.QUEUED
        # The request leaves the source at its current clock; the target
        # must not re-prefill it earlier (its own clock may lag).
        state.visible_at = max(state.visible_at, rep.now)
        self.degraded += 1
        if planned:
            state.handoffs += 1
            rep.handoffs += 1
            rep.handoff_log.append((state.req.req_id, tgt.index, 0))
        tgt.enqueue(state)
        self.touched.add(tgt.index)
        self.touched.add(rep.index)

    # ---------------------------------------------------------------- commit

    def commit(self, rep, states: Sequence[RequestState],
               payloads: Dict[int, object], *, done_at: float = 0.0) -> int:
        """Commit an executed handoff event on its source replica: free
        the source's symbolic blocks (``handoff_out`` — the payload left
        the machine, nothing lands in the local host tier), mark each
        request in-transit, and deliver (or park) its payload.
        ``done_at`` is the NIC completion time of the export (the
        earliest instant the payload exists on a target).  Returns the
        total blocks handed off (for the observability hook)."""
        mgr = self.executor.kv_manager(rep.index)
        total = 0
        for s in states:
            rid = s.req.req_id
            rec = self._planned.pop(rid)
            rec.payload = payloads.get(rid)
            rec.done_at = max(done_at, rep.now)
            blocks = mgr.handoff_out(rid) if mgr is not None else rec.blocks
            total += blocks
            s.swapped = True
            s.phase = Phase.QUEUED
            s.handoffs += 1
            rep.handoffs += 1
            rep.handoff_blocks += blocks
            rep.handoff_log.append(
                (rid, rec.dst.index if rec.dst is not None else -1, blocks))
            if rec.dst is not None:
                self._deliver(rec)
            else:
                self.queue.append(rec)
                self.parked_total += 1
        return total

    def _deliver(self, rec: _Handoff) -> None:
        """Land one exported payload on its reserved target: physical
        import (a no-op sentinel on the cost backend), then enqueue — the
        request readmits through the target's ordinary swap-in path.  A
        refused import (shape mismatch, no paged storage) degrades to
        recompute on the same target."""
        s, dst = rec.state, rec.dst
        rid = s.req.req_id
        dmgr = self.executor.kv_manager(dst.index)
        self._release(dst.index)
        if dst.dead or dst.draining:
            # The target died between reservation and delivery: return the
            # reservation and re-queue the payload (bound softened — this
            # only happens under faults).
            if dmgr is not None:
                dmgr.drop_swapped(rid)
            rec.dst = None
            self.queue.append(rec)
            self.parked_total += 1
            return
        if self.executor.import_swapped(dst.index, s, rec.payload):
            self.delivered += 1
        else:
            if dmgr is not None:
                dmgr.drop_swapped(rid)
            s.swapped = False
            s.remaining = 0
            self.degraded += 1
        s.phase = Phase.QUEUED
        # Causality: the payload exists on the target only once its NIC
        # transfer finished — a lagging target clock must not admit it
        # earlier.
        s.visible_at = max(s.visible_at, rec.done_at)
        dst.enqueue(s)
        self.touched.add(dst.index)

    # ------------------------------------------------------------------ pump

    def pump(self) -> bool:
        """Retry parked transfers (FIFO — head-of-line keeps ordering
        deterministic) and wake stalled sources.  Called by the
        orchestrator after every committed event, when target capacity
        may have freed.  Returns True when anything was delivered."""
        progressed = False
        while self.queue:
            rec = self.queue.peek()
            dst = self._reserve(rec.src, rec.state, rec.blocks)
            if dst is None:
                if self._fits_somewhere(rec.src, rec.state, rec.blocks):
                    break           # head waits for capacity, FIFO
                tgt = self._pick_degrade(rec.src, rec.state)
                if tgt is None:
                    break           # no decode pool: keep waiting
                self.queue.popleft()
                self._finish_degrade(rec.src, rec.state, tgt, planned=False)
                progressed = True
                continue
            self.queue.popleft()
            rec.dst = dst
            self._deliver(rec)
            self.touched.add(rec.src.index)   # source may unthrottle
            progressed = True
        for r in self._replicas():
            if r.handoff_ready and not r.dead:
                self.touched.add(r.index)     # stalled source: re-plan
        return progressed

    def drain_touched(self) -> List[int]:
        out, self.touched = sorted(self.touched), set()
        return out

    # ---------------------------------------------------------------- faults

    def abort_source(self, index: int) -> None:
        """A replica died with planned-but-uncommitted handoffs: return
        every reserved target block (the export never happened; the
        source's own device blocks are handled by its force-drain)."""
        for rid in [rid for rid, rec in self._planned.items()
                    if rec.src.index == index]:
            rec = self._planned.pop(rid)
            if rec.dst is not None:
                self._release(rec.dst.index)
                dmgr = self.executor.kv_manager(rec.dst.index)
                if dmgr is not None:
                    dmgr.drop_swapped(rid)

    # ------------------------------------------------------------- accounting

    def stats(self) -> Dict[str, float]:
        return {
            "handoff_delivered": float(self.delivered),
            "handoff_degraded": float(self.degraded),
            "handoff_parked_total": float(self.parked_total),
            "handoff_queue_peak": float(self.queue.peak),
        }
