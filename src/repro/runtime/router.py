"""Workload-assignment router: dispatches requests to replicas according to
the plan's fractional assignment x_{c,w} (§4.3), with deterministic
low-discrepancy (deficit-round-robin) rounding so realized fractions track
the plan to within one request.

When a request's (model, workload) demand column is missing from the plan
or carries zero mass, the router falls back to round-robin **only among
replicas serving the same model** — never to a replica loaded with a
different model.  If no replica serves the request's model, ``route``
returns ``None`` and the runtime records the request as dropped.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.plan import ServingPlan
from repro.core.workloads import Request


class AssignmentRouter:
    """Routes each request to a replica index per the plan's x matrix."""

    def __init__(self, plan: ServingPlan):
        self.plan = plan
        self._index = {(m, w): d for d, (m, w, _) in enumerate(plan.demands)}
        # deficit-round-robin credit per (replica, demand)
        self._credit = np.zeros_like(plan.assignment)
        # round-robin cursors for the model-matched fallback path
        self._fallback: Dict[int, int] = {}
        self._by_model: Dict[int, List[int]] = {}
        for i, cfg in enumerate(plan.replicas):
            self._by_model.setdefault(cfg.model_index, []).append(i)

    def route(self, req: Request) -> Optional[int]:
        d = self._index.get((req.model, req.workload))
        if d is not None:
            probs = np.clip(self.plan.assignment[:, d], 0, None)
            total = probs.sum()
            if total > 0:
                self._credit[:, d] += probs / total
                i = int(np.argmax(self._credit[:, d]))
                self._credit[i, d] -= 1.0
                return i
        # demand not covered by the plan: round-robin among same-model
        # replicas only (a wrong-model replica cannot serve the request)
        matching = self._by_model.get(req.model)
        if not matching:
            return None
        k = self._fallback.get(req.model, 0)
        self._fallback[req.model] = k + 1
        return matching[k % len(matching)]

    def realized_fractions(self) -> np.ndarray:
        """How far realized routing drifted from the plan (for tests)."""
        return self._credit
