"""Workload-assignment router: dispatches requests to replicas according to
the plan's fractional assignment x_{c,w} (§4.3), with deterministic
low-discrepancy (deficit-round-robin) rounding so realized fractions track
the plan to within one request.

When a request's (model, workload) demand column is missing from the plan
or carries zero mass, the router falls back to round-robin **only among
replicas serving the same model** — never to a replica loaded with a
different model.  If no replica serves the request's model, ``route``
returns ``None`` and the runtime records the request as dropped.

With prefix caching enabled the runtime additionally supplies a
``prefix_affinity`` probe: among the plan's positive-mass candidate
replicas for a demand, the router prefers the one holding the longest
cached prefix of the request's prompt (warm-prefix affinity), breaking
ties by deficit-round-robin credit so routing still tracks the plan's
fractions whenever no replica is warm.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.plan import ServingPlan
from repro.core.workloads import Request


class AssignmentRouter:
    """Routes each request to a replica index per the plan's x matrix."""

    def __init__(self, plan: ServingPlan,
                 prefix_affinity: Optional[
                     Callable[[int, Request], int]] = None):
        self.plan = plan
        # (replica_index, request) -> cached prefix tokens on that replica
        self.prefix_affinity = prefix_affinity
        self._index = {(m, w): d for d, (m, w, _) in enumerate(plan.demands)}
        # deficit-round-robin credit per (replica, demand)
        self._credit = np.zeros_like(plan.assignment)
        # round-robin cursors for the model-matched fallback path
        self._fallback: Dict[int, int] = {}
        self._by_model: Dict[int, List[int]] = {}
        for i, cfg in enumerate(plan.replicas):
            # Phase-aware routing: arrivals never land on a decode-role
            # replica directly — decode pools are fed by KV handoff (the
            # planner's disagg strategy gives them zero assignment mass,
            # which already keeps them off the demand path; this keeps
            # them off the fallback path too).
            if getattr(cfg, "role", "both") == "decode":
                continue
            self._by_model.setdefault(cfg.model_index, []).append(i)
        # (prefix_warmth_of_choice | None, used_fallback) for the most
        # recent route() call — read by the runtime's observability hook
        self.last_pick = (None, False)

    def route(self, req: Request) -> Optional[int]:
        self.last_pick = (None, False)
        d = self._index.get((req.model, req.workload))
        if d is not None:
            probs = np.clip(self.plan.assignment[:, d], 0, None)
            total = probs.sum()
            if total > 0:
                self._credit[:, d] += probs / total
                i = int(np.argmax(self._credit[:, d]))
                if self.prefix_affinity is not None:
                    # Warm-prefix affinity: steer to the plan-eligible
                    # replica holding the longest cached prefix; on an
                    # all-cold tie (warmth 0 everywhere) this reduces to
                    # the pure DRR pick.  The credit debit still lands on
                    # the chosen replica, so plan tracking self-corrects.
                    cands = np.flatnonzero(probs > 0)
                    warmth = {int(c): self.prefix_affinity(int(c), req)
                              for c in cands}
                    i = int(max(cands, key=lambda c: (
                        warmth[int(c)], self._credit[int(c), d],
                        -int(c))))
                    self.last_pick = (warmth[i], False)
                self._credit[i, d] -= 1.0
                return i
        # demand not covered by the plan: round-robin among same-model
        # replicas only (a wrong-model replica cannot serve the request)
        matching = self._by_model.get(req.model)
        if not matching:
            return None
        k = self._fallback.get(req.model, 0)
        self._fallback[req.model] = k + 1
        if self.prefix_affinity is not None:
            # Warm-prefix affinity on the fallback path: rotate the
            # candidate order to the round-robin cursor so an all-cold
            # pick is exactly the legacy round-robin choice, then let
            # the warmest replica win.
            order = [matching[(k + j) % len(matching)]
                     for j in range(len(matching))]
            warm = {c: self.prefix_affinity(c, req) for c in order}
            pick = max(order, key=lambda c: warm[c])
            self.last_pick = (warm[pick], True)
            return pick
        self.last_pick = (None, True)
        return matching[k % len(matching)]

    def realized_fractions(self) -> np.ndarray:
        """How far realized routing drifted from the plan (for tests)."""
        return self._credit
