"""Unified event-driven serving runtime.

One continuous-batching core (request lifecycle, admission, batching,
streaming dispatch, online replanning) behind two executor backends:

* ``CostModelExecutor`` — analytical step times from ``repro.core.costmodel``
  (drives ``repro.core.simulator.simulate``), and
* ``EngineExecutor`` — real token generation via JAX ``ReplicaEngine``
  replicas (drives ``repro.serving.HeterogeneousServer``), executed
  concurrently across replicas on actor-style workers.

Time is modeled as a single global event heap (the orchestrator always
fires the earliest event across replicas); pass a
``repro.core.scheduler.ScalePolicy`` to ``ServingRuntime.run`` for
utilization-driven online autoscaling, and a ``repro.obs.Observability``
as ``ServingRuntime(..., obs=...)`` for request-lifecycle tracing and
live metrics (``export_trace(path)`` writes Perfetto-loadable Chrome
trace JSON).
"""
from repro.runtime.actor import ReplicaWorker, WorkerTimeout
from repro.runtime.disagg import HandoffManager, TransferQueue
from repro.runtime.executor import (CostModelExecutor, EngineExecutor,
                                    Executor)
from repro.runtime.faults import (AvailabilityWatcher, FaultEvent,
                                  FaultInjector, FaultPlan, spot_schedule)
from repro.runtime.kvcache import (BlockAllocator, KVCacheManager,
                                   PagedEngineCache, make_kv_manager,
                                   num_kv_blocks)
from repro.runtime.lifecycle import (Phase, RequestState, RuntimeResult, SLO)
from repro.runtime.orchestrator import (ArrivalSource, LiveSource,
                                        ReplanEvent, ServingRuntime,
                                        TraceSource)
from repro.runtime.replica import PendingEvent, ReplicaRuntime
from repro.runtime.router import AssignmentRouter

__all__ = [
    "ArrivalSource", "AssignmentRouter", "AvailabilityWatcher",
    "BlockAllocator", "CostModelExecutor", "EngineExecutor", "Executor",
    "FaultEvent", "FaultInjector", "FaultPlan", "HandoffManager",
    "KVCacheManager", "LiveSource", "PagedEngineCache", "PendingEvent",
    "Phase", "ReplanEvent", "ReplicaRuntime", "ReplicaWorker",
    "RequestState", "RuntimeResult", "SLO", "ServingRuntime",
    "TraceSource", "TransferQueue", "WorkerTimeout", "make_kv_manager",
    "num_kv_blocks", "spot_schedule",
]
