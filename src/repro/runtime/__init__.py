"""Unified event-driven serving runtime.

One continuous-batching core (request lifecycle, admission, batching,
streaming dispatch, online replanning) behind two executor backends:

* ``CostModelExecutor`` — analytical step times from ``repro.core.costmodel``
  (drives ``repro.core.simulator.simulate``), and
* ``EngineExecutor`` — real token generation via JAX ``ReplicaEngine``
  replicas (drives ``repro.serving.HeterogeneousServer``).
"""
from repro.runtime.executor import (CostModelExecutor, EngineExecutor,
                                    Executor)
from repro.runtime.kvcache import (BlockAllocator, KVCacheManager,
                                   PagedEngineCache, make_kv_manager,
                                   num_kv_blocks)
from repro.runtime.lifecycle import (Phase, RequestState, RuntimeResult, SLO)
from repro.runtime.orchestrator import ReplanEvent, ServingRuntime
from repro.runtime.replica import ReplicaRuntime
from repro.runtime.router import AssignmentRouter

__all__ = [
    "AssignmentRouter", "BlockAllocator", "CostModelExecutor",
    "EngineExecutor", "Executor", "KVCacheManager", "PagedEngineCache",
    "Phase", "ReplanEvent", "ReplicaRuntime", "RequestState",
    "RuntimeResult", "SLO", "ServingRuntime", "make_kv_manager",
    "num_kv_blocks",
]
