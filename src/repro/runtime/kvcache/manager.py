"""Block-table KV-cache accounting for one replica.

:class:`KVCacheManager` is the *admission-side* view of a replica's KV
memory: it tracks how many fixed-size token blocks each in-flight request
holds and answers the three questions the continuous-batching scheduler
asks —

* **admit**: can a queued request's prompt (+ first token) be allocated
  right now?  (A small watermark is held back so a freshly admitted
  request cannot immediately force a preemption.)
* **grow**: how many lockstep decode steps can the whole active batch
  advance before the pool is exhausted?
* **free**: a request finished / was preempted — return its blocks.

Token counts are *logical* (trace-scale) tokens; sliding-window models
stop growing at ``window`` tokens (the ring buffer reuses its own blocks)
and recurrent state costs a constant ``state_blocks`` per sequence.  Both
executor backends size their manager from the same
``repro.core.costmodel.kv_free_bytes`` budget, so prediction and execution
make identical admission decisions on the same trace.

One deliberate safety valve: a request admitted *solo* (empty replica) is
always accepted even if it overflows the budget — the legacy scheduler
guaranteed one-at-a-time progress on undersized replicas, and starving a
replica would deadlock the trace.  Overflow is recorded in
``overflow_admissions`` so results stay auditable.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Sequence, Tuple


def blocks_for_tokens(tokens: int, block_size: int, *,
                      window: int = 0) -> int:
    """Blocks needed to hold ``tokens`` logical tokens of KV history.
    ``block_size == 0`` means the model appends no per-token KV (pure
    recurrent stacks): history costs nothing, only ``state_blocks`` do."""
    if block_size <= 0:
        return 0
    held = min(tokens, window) if window > 0 else tokens
    return max(0, math.ceil(held / block_size))


class KVCacheManager:
    """Per-replica block accounting (symbolic: counts, not tensors)."""

    def __init__(self, num_blocks: int, block_size: int, *,
                 window: int = 0, state_blocks: int = 0,
                 watermark_frac: float = 0.01):
        if block_size < 0:
            raise ValueError(f"block_size must be >= 0, got {block_size}")
        if block_size == 0 and state_blocks <= 0:
            raise ValueError("state-only accounting needs state_blocks > 0")
        self.num_blocks = max(0, int(num_blocks))
        self.block_size = int(block_size)
        self.window = int(window)
        self.state_blocks = int(state_blocks)
        # Held-back slack for admission only (vLLM's watermark): growth of
        # the already-running batch may still use it.
        self.watermark = max(1, math.ceil(watermark_frac * self.num_blocks))
        self._held: Dict[int, int] = {}     # req_id -> blocks held
        self.used_blocks = 0
        self.peak_used = 0
        self.overflow_admissions = 0
        self.admitted = 0
        self.freed = 0

    # ------------------------------------------------------------ queries

    @property
    def free_blocks(self) -> int:
        return self.num_blocks - self.used_blocks

    def blocks_for(self, tokens: int) -> int:
        return blocks_for_tokens(tokens, self.block_size,
                                 window=self.window) + self.state_blocks

    def holds(self, req_id: int) -> bool:
        return req_id in self._held

    def held_blocks(self, req_id: int) -> int:
        """Blocks currently reserved by ``req_id`` (0 when not held) — the
        recompute cost a ``fewest-blocks`` preemption victim would free."""
        return self._held.get(req_id, 0)

    # ---------------------------------------------------------- admission

    def admit(self, req_id: int, tokens: int, *, solo: bool = False) -> bool:
        """Reserve blocks for a request entering prefill with ``tokens``
        logical tokens (prompt + first output token).  ``solo`` marks the
        only-request-on-the-replica case, which is always admitted."""
        assert req_id not in self._held, f"request {req_id} already held"
        need = self.blocks_for(tokens)
        if not solo and self.used_blocks + need + self.watermark > self.num_blocks:
            return False
        if solo and self.used_blocks + need > self.num_blocks:
            self.overflow_admissions += 1
        self._held[req_id] = need
        self.used_blocks += need
        self.peak_used = max(self.peak_used, self.used_blocks)
        self.admitted += 1
        return True

    # ------------------------------------------------------------- growth

    def feasible_steps(self, batch: Sequence[Tuple[int, int]],
                       k: int) -> int:
        """Largest ``k' <= k`` such that every ``(req_id, tokens)`` in the
        lockstep batch can grow by ``k'`` tokens within the pool.  Returns 0
        when not even one step fits (caller preempts or overflows)."""
        def fits(step: int) -> bool:
            need = sum(self.blocks_for(tok + step) - self._held[rid]
                       for rid, tok in batch)
            return self.used_blocks + need <= self.num_blocks

        if fits(k):
            return k
        lo, hi = 0, k - 1          # need(step) is monotone: binary search
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if fits(mid):
                lo = mid
            else:
                hi = mid - 1
        return lo

    def grow(self, req_id: int, tokens: int, *,
             allow_overflow: bool = False) -> bool:
        """Ensure ``req_id`` holds enough blocks for ``tokens`` logical
        tokens.  Returns False (state unchanged) when the pool is exhausted
        and overflow is not allowed."""
        need = self.blocks_for(tokens) - self._held[req_id]
        if need <= 0:
            return True
        if self.used_blocks + need > self.num_blocks and not allow_overflow:
            return False
        self._held[req_id] += need
        self.used_blocks += need
        self.peak_used = max(self.peak_used, self.used_blocks)
        return True

    # ------------------------------------------------------------ release

    def free(self, req_id: int) -> None:
        held = self._held.pop(req_id, 0)
        self.used_blocks -= held
        if held:
            self.freed += 1

    def reset(self) -> None:
        self._held.clear()
        self.used_blocks = 0
        self.peak_used = 0
        self.overflow_admissions = 0
        self.admitted = 0
        self.freed = 0


def logical_tokens(input_len: int, quota: int, remaining: int) -> int:
    """Logical KV tokens a request holds mid-decode: the prompt, the first
    token from prefill, and every decode step taken so far."""
    return input_len + 1 + (quota - remaining)


def batch_tokens(states: Iterable) -> Sequence[Tuple[int, int]]:
    """(req_id, logical tokens) pairs for a batch of RequestStates."""
    return [(s.req.req_id,
             logical_tokens(s.req.input_len, s.quota, s.remaining))
            for s in states]
