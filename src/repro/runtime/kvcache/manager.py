"""Block-table KV-cache accounting for one replica.

:class:`KVCacheManager` is the *admission-side* view of a replica's KV
memory: it tracks how many fixed-size token blocks each in-flight request
holds and answers the three questions the continuous-batching scheduler
asks —

* **admit**: can a queued request's prompt (+ first token) be allocated
  right now?  (A small watermark is held back so a freshly admitted
  request cannot immediately force a preemption.)
* **grow**: how many lockstep decode steps can the whole active batch
  advance before the pool is exhausted?
* **free**: a request finished / was preempted — return its blocks.

Token counts are *logical* (trace-scale) tokens; sliding-window models
stop growing at ``window`` tokens (the ring buffer reuses its own blocks)
and recurrent state costs a constant ``state_blocks`` per sequence.  Both
executor backends size their manager from the same
``repro.core.costmodel.kv_free_bytes`` budget, so prediction and execution
make identical admission decisions on the same trace.

**Prefix caching** (``prefix_cache=True``): when admission sees the
request's prompt token ids, the full blocks of the prompt are content-
hashed (:func:`~repro.runtime.kvcache.allocator.hash_blocks`) and matched
against an index of blocks other requests already prefilled.  Matched
blocks are *shared* — refcounted, counted once in ``used_blocks`` however
many requests alias them — so admission only reserves the unique suffix,
and a freed request's hashed blocks park in an LRU cached pool (evicted
only under allocation pressure) instead of vanishing.  The accounting here
is symbolic; the engine backend mirrors it physically in
:class:`~repro.runtime.kvcache.paged.PagedEngineCache`.  Both backends run
this same logic on the same trace-scale prompts, so admission stays
backend-identical with the cache on or off.  With the cache off (the
default) every code path below degenerates to the legacy count-only
arithmetic, byte for byte.

One deliberate safety valve: a request admitted *solo* (empty replica) is
always accepted even if it overflows the budget — the legacy scheduler
guaranteed one-at-a-time progress on undersized replicas, and starving a
replica would deadlock the trace.  Overflow is recorded in
``overflow_admissions`` so results stay auditable.

**Host tier** (``host_blocks > 0``): a second, host-memory block budget
under the device pool.  It serves two customers sharing one bound:

* *spilled prefixes* — LRU blocks evicted by :meth:`_reclaim` move to the
  host tier instead of vanishing, and the admission walk transparently
  revives host-resident hashes (charged like an LRU revival: one device
  block each, plus host-link copy time the cost model accounts
  separately via :meth:`host_hit_blocks`);
* *swapped requests* — :meth:`swap_out` moves a preemption victim's whole
  block set to the host tier so :meth:`swap_in` can readmit it without
  re-running prefill.  Swapped copies are private (never matched by other
  requests), which makes readmission independent of whatever happens to
  the shared index in between.

With ``host_blocks=0`` (default) every path degenerates to the
single-tier behavior, byte for byte.
"""
from __future__ import annotations

import collections
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.runtime.kvcache.allocator import hash_blocks


def blocks_for_tokens(tokens: int, block_size: int, *,
                      window: int = 0) -> int:
    """Blocks needed to hold ``tokens`` logical tokens of KV history.
    ``block_size == 0`` means the model appends no per-token KV (pure
    recurrent stacks): history costs nothing, only ``state_blocks`` do."""
    if block_size <= 0:
        return 0
    held = min(tokens, window) if window > 0 else tokens
    return max(0, math.ceil(held / block_size))


class _SharedBlock:
    """One content-addressed prompt block in the symbolic index."""

    __slots__ = ("hash", "refs")

    def __init__(self, h: int):
        self.hash = h
        self.refs = 1


class KVCacheManager:
    """Per-replica block accounting (symbolic: counts, not tensors)."""

    def __init__(self, num_blocks: int, block_size: int, *,
                 window: int = 0, state_blocks: int = 0,
                 watermark_frac: float = 0.01,
                 prefix_cache: bool = False,
                 host_blocks: int = 0):
        if block_size < 0:
            raise ValueError(f"block_size must be >= 0, got {block_size}")
        if block_size == 0 and state_blocks <= 0:
            raise ValueError("state-only accounting needs state_blocks > 0")
        if host_blocks < 0:
            raise ValueError(f"host_blocks must be >= 0, got {host_blocks}")
        self.num_blocks = max(0, int(num_blocks))
        self.block_size = int(block_size)
        self.window = int(window)
        self.state_blocks = int(state_blocks)
        # Prefix matching needs full immutable blocks: a sliding-window
        # ring rewrites its own blocks and a state-only model has none.
        self.prefix_cache = bool(prefix_cache) and self.block_size > 0 \
            and self.window == 0
        # Host tier: block-granular swap needs per-token KV blocks (a
        # recurrent state tensor has no block identity to copy).
        self.host_blocks = int(host_blocks) if self.block_size > 0 else 0
        # Held-back slack for admission only (vLLM's watermark): growth of
        # the already-running batch may still use it.
        self.watermark = max(1, math.ceil(watermark_frac * self.num_blocks))
        self._held: Dict[int, int] = {}     # req_id -> total blocks held
        # prefix-cache bookkeeping (all empty when the cache is off)
        self._index: Dict[int, _SharedBlock] = {}
        self._lru: "collections.OrderedDict[int, _SharedBlock]" = \
            collections.OrderedDict()       # hash -> refcount-0 block
        self._prefix_of: Dict[int, List[_SharedBlock]] = {}
        self._private: Dict[int, int] = {}  # req_id -> non-shared blocks
        self._hit_tokens: Dict[int, int] = {}
        # host-tier bookkeeping (all empty when host_blocks == 0)
        self._host: "collections.OrderedDict[int, _SharedBlock]" = \
            collections.OrderedDict()       # spilled hash -> block
        self._swapped: Dict[int, int] = {}  # req_id -> host blocks held
        self._host_hit_blocks: Dict[int, int] = {}
        self.used_blocks = 0
        self.peak_used = 0
        self.overflow_admissions = 0
        self.admitted = 0
        self.freed = 0
        self.prefix_queries = 0             # admissions that attempted a match
        self.prefix_hits = 0                # admissions with >= 1 shared block
        self.prefix_hit_tokens_total = 0
        self.prefix_prompt_tokens_total = 0
        self.prefix_evictions = 0
        self.spilled_blocks = 0             # LRU evictions kept on host
        self.host_evictions = 0             # spilled blocks dropped from host
        self.host_hits = 0                  # blocks revived host -> device
        self.swap_outs = 0
        self.swap_ins = 0
        self.swapped_out_blocks = 0
        self.swapped_in_blocks = 0
        self.swap_drops = 0                 # swapped state discarded (migration)
        self.swap_exports = 0               # swapped state migrated out (faults)
        self.swap_imports = 0               # swapped state adopted from a peer
        self.handoff_outs = 0               # prefill->decode migrations out
        self.handoff_out_blocks = 0

    # ------------------------------------------------------------ queries

    @property
    def free_blocks(self) -> int:
        return self.num_blocks - self.used_blocks

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks parked for reuse (not counted in used)."""
        return len(self._lru)

    @property
    def host_used_blocks(self) -> int:
        """Host-tier blocks in use: spilled prefixes + swapped requests."""
        return len(self._host) + sum(self._swapped.values())

    @property
    def host_free_blocks(self) -> int:
        return max(0, self.host_blocks - self.host_used_blocks)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of eligible prompt tokens served from the cache."""
        if self.prefix_prompt_tokens_total <= 0:
            return 0.0
        return self.prefix_hit_tokens_total / self.prefix_prompt_tokens_total

    def blocks_for(self, tokens: int) -> int:
        return blocks_for_tokens(tokens, self.block_size,
                                 window=self.window) + self.state_blocks

    def stats(self) -> Dict[str, object]:
        """Occupancy snapshot for observability sampling (pure read)."""
        return {
            "num_blocks": self.num_blocks,
            "used_blocks": self.used_blocks,
            "used_frac": (self.used_blocks / self.num_blocks
                          if self.num_blocks > 0 else 0.0),
            "peak_used": self.peak_used,
            "watermark": self.watermark,
            "cached_blocks": self.cached_blocks,
            "overflow_admissions": self.overflow_admissions,
            "prefix_cache": self.prefix_cache,
            "prefix_hit_rate": self.prefix_hit_rate,
            "host_blocks": self.host_blocks,
            "host_used_blocks": self.host_used_blocks,
            "spilled_blocks": self.spilled_blocks,
            "host_hits": self.host_hits,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "swapped_out_blocks": self.swapped_out_blocks,
            "swapped_in_blocks": self.swapped_in_blocks,
            "handoff_outs": self.handoff_outs,
            "handoff_out_blocks": self.handoff_out_blocks,
        }

    def holds(self, req_id: int) -> bool:
        return req_id in self._held

    def held_blocks(self, req_id: int) -> int:
        """Blocks preempting ``req_id`` would actually reclaim (0 when not
        held) — the recompute cost a ``fewest-blocks`` preemption victim
        would free.  With prefix caching on, blocks shared with other live
        requests are excluded: evicting this request cannot release them."""
        held = self._held.get(req_id, 0)
        if not held or not self.prefix_cache:
            return held
        shared_elsewhere = sum(1 for b in self._prefix_of.get(req_id, ())
                               if b.refs > 1)
        return held - shared_elsewhere

    def prefix_hit_tokens(self, req_id: int) -> int:
        """Prompt tokens of ``req_id`` served from the prefix cache at its
        most recent admission (0 when cold / cache off)."""
        return self._hit_tokens.get(req_id, 0)

    def host_hit_blocks(self, req_id: int) -> int:
        """Blocks of ``req_id``'s most recent admission revived from the
        host tier (each one costs a host-link copy, not prefill FLOPs)."""
        return self._host_hit_blocks.get(req_id, 0)

    def _prompt_hashes(self, prompt: Optional[Sequence[int]],
                       tokens: int) -> List[int]:
        """Content hashes of the matchable full blocks of ``prompt`` for an
        admission of ``tokens`` logical tokens (prompt + first output).
        Matching is capped below the prompt length so at least one suffix
        token always remains to prefill."""
        if not self.prefix_cache or prompt is None or len(prompt) == 0:
            return []
        input_len = tokens - 1          # admissions pass prompt + 1
        return hash_blocks(prompt, self.block_size,
                           max_match_tokens=min(len(prompt), input_len) - 1)

    def cached_prefix_tokens(self, prompt: Optional[Sequence[int]],
                             tokens: int) -> int:
        """Peek (no state change): how many leading prompt tokens an
        admission of ``tokens`` logical tokens would reuse right now.
        The router's warm-prefix affinity reads this."""
        n = 0
        for h in self._prompt_hashes(prompt, tokens):
            if h not in self._index and h not in self._host:
                break
            n += 1
        return n * self.block_size

    # ---------------------------------------------------------- admission

    def admit(self, req_id: int, tokens: int, *, solo: bool = False,
              prompt: Optional[Sequence[int]] = None) -> bool:
        """Reserve blocks for a request entering prefill with ``tokens``
        logical tokens (prompt + first output token).  ``solo`` marks the
        only-request-on-the-replica case, which is always admitted.

        With prefix caching on and ``prompt`` given, leading full prompt
        blocks already in the index are aliased (shared refs — possibly
        revived from the LRU cached pool) instead of reserved anew, and
        this request's own full prompt blocks are published for the next
        request; the matched token count is retrievable via
        :meth:`prefix_hit_tokens` until the request is freed.
        """
        assert req_id not in self._held, f"request {req_id} already held"
        need = self.blocks_for(tokens)
        hashes = self._prompt_hashes(prompt, tokens)
        hit: List[_SharedBlock] = []
        host_hit: set = set()
        for h in hashes:
            blk = self._index.get(h)
            if blk is None and self.host_blocks > 0:
                blk = self._host.get(h)    # revivable from the host tier
                if blk is not None:
                    host_hit.add(h)
            if blk is None:
                break
            hit.append(blk)
        # Charge only what this admission adds to the pool: new blocks
        # plus cache revivals (LRU or host — either way one device block
        # comes into use); blocks shared with live requests are free.
        revived = sum(1 for b in hit if b.refs == 0)
        delta = need - (len(hit) - revived)
        if not solo and self.used_blocks + delta + self.watermark \
                > self.num_blocks:
            return False
        if solo and self.used_blocks + delta > self.num_blocks:
            self.overflow_admissions += 1
        for b in hit:
            if b.refs == 0:
                if b.hash in host_hit:     # revive host -> device
                    del self._host[b.hash]
                    self._index[b.hash] = b
                    self.host_hits += 1
                else:
                    del self._lru[b.hash]  # revive from the cached pool
            b.refs += 1
        if self.host_blocks > 0:
            self._host_hit_blocks[req_id] = len(host_hit)
        # new blocks (shared-to-be + private) may need LRU evictions so the
        # physical pool (used + cached) stays within num_blocks
        self._reclaim(delta)
        shared = list(hit)
        for h in hashes[len(hit):]:
            blk = _SharedBlock(h)
            self._index[h] = blk
            shared.append(blk)
        if self.prefix_cache:
            self._prefix_of[req_id] = shared
            self._private[req_id] = need - len(shared)
            self._hit_tokens[req_id] = len(hit) * self.block_size
            if hashes:
                self.prefix_queries += 1
                self.prefix_prompt_tokens_total += tokens - 1
                self.prefix_hit_tokens_total += len(hit) * self.block_size
                if hit:
                    self.prefix_hits += 1
        self._held[req_id] = need
        self.used_blocks += delta
        self.peak_used = max(self.peak_used, self.used_blocks)
        self.admitted += 1
        return True

    def _reclaim(self, new_blocks: int) -> None:
        """Evict LRU cached blocks until ``new_blocks`` more fit the
        physical pool alongside everything live + cached.  With a host
        tier, evicted blocks spill there (bounded — the oldest spilled
        block is dropped first) instead of vanishing."""
        while (self._lru
               and self.used_blocks + len(self._lru) + new_blocks
               > self.num_blocks):
            _, blk = self._lru.popitem(last=False)
            self._index.pop(blk.hash, None)
            self.prefix_evictions += 1
            if self.host_blocks > 0:
                while self.host_free_blocks < 1 and self._host:
                    self._host.popitem(last=False)
                    self.host_evictions += 1
                if self.host_free_blocks >= 1:
                    self._host[blk.hash] = blk
                    self._host.move_to_end(blk.hash)
                    self.spilled_blocks += 1

    # ------------------------------------------------------------- growth

    def feasible_steps(self, batch: Sequence[Tuple[int, int]],
                       k: int) -> int:
        """Largest ``k' <= k`` such that every ``(req_id, tokens)`` in the
        lockstep batch can grow by ``k'`` tokens within the pool.  Returns 0
        when not even one step fits (caller preempts or overflows)."""
        def fits(step: int) -> bool:
            need = sum(self.blocks_for(tok + step) - self._held[rid]
                       for rid, tok in batch)
            return self.used_blocks + need <= self.num_blocks

        if fits(k):
            return k
        lo, hi = 0, k - 1          # need(step) is monotone: binary search
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if fits(mid):
                lo = mid
            else:
                hi = mid - 1
        return lo

    def grow(self, req_id: int, tokens: int, *,
             allow_overflow: bool = False) -> bool:
        """Ensure ``req_id`` holds enough blocks for ``tokens`` logical
        tokens.  Returns False (state unchanged) when the pool is exhausted
        and overflow is not allowed.  Growth blocks are always private
        (decode tokens land past the shared prompt prefix)."""
        need = self.blocks_for(tokens) - self._held[req_id]
        if need <= 0:
            return True
        if self.used_blocks + need > self.num_blocks and not allow_overflow:
            return False
        self._reclaim(need)
        self._held[req_id] += need
        if self.prefix_cache and req_id in self._private:
            self._private[req_id] += need
        self.used_blocks += need
        self.peak_used = max(self.peak_used, self.used_blocks)
        return True

    # ------------------------------------------------------------ release

    def free(self, req_id: int) -> None:
        """Release a finished or preempted request.  Private blocks return
        to the pool immediately; shared prompt blocks are decref'd — blocks
        still aliased by live requests stay used, last-holder blocks park
        in the LRU cached pool (still indexed, free to re-admit)."""
        held = self._held.pop(req_id, 0)
        if not held:
            return
        released = held
        for blk in self._prefix_of.pop(req_id, ()):
            blk.refs -= 1
            if blk.refs > 0:
                released -= 1          # another live request still holds it
            else:
                self._lru[blk.hash] = blk
                self._lru.move_to_end(blk.hash)
        self._private.pop(req_id, None)
        self._hit_tokens.pop(req_id, None)
        self._host_hit_blocks.pop(req_id, None)
        self.used_blocks -= released
        self.freed += 1

    # ---------------------------------------------------- swap (host tier)

    def can_swap_out(self, req_id: int) -> bool:
        """True when ``req_id``'s whole block set fits in the free host
        tier right now.  The *whole* set — shared prompt blocks included —
        goes to host, so readmission never depends on what the shared
        index looks like after arbitrary churn in between."""
        held = self._held.get(req_id, 0)
        return 0 < held <= self.host_free_blocks

    def swap_out(self, req_id: int) -> int:
        """Move a preemption victim's blocks to the host tier.  Device-side
        bookkeeping is exactly a :meth:`free` (shared blocks decref and may
        park in the LRU for *other* requests); the victim's own copy is
        accounted against the host budget until :meth:`swap_in` or
        :meth:`drop_swapped`.  Returns the host blocks charged."""
        held = self._held.get(req_id, 0)
        assert held > 0, f"swap_out of request {req_id} holding no blocks"
        assert req_id not in self._swapped, f"request {req_id} already swapped"
        self.free(req_id)
        self.freed -= 1                    # it is swapped, not freed
        self._swapped[req_id] = held
        self.swap_outs += 1
        self.swapped_out_blocks += held
        return held

    def swapped_blocks(self, req_id: int) -> int:
        """Host blocks a swapped-out request holds (0 when not swapped)."""
        return self._swapped.get(req_id, 0)

    def swap_in(self, req_id: int, tokens: int, *, solo: bool = False) -> bool:
        """Readmit a swapped-out request: reserve device blocks for its
        ``tokens`` logical tokens under the same watermark / solo-overflow
        rules as :meth:`admit`, releasing the host-tier copy.  No prefix
        matching — restored blocks are private.  Returns False (state
        unchanged) when the device pool cannot take it yet."""
        assert req_id in self._swapped, f"request {req_id} not swapped out"
        assert req_id not in self._held, f"request {req_id} already held"
        need = self.blocks_for(tokens)
        if not solo and self.used_blocks + need + self.watermark \
                > self.num_blocks:
            return False
        if solo and self.used_blocks + need > self.num_blocks:
            self.overflow_admissions += 1
        self._reclaim(need)
        restored = self._swapped.pop(req_id)
        self._held[req_id] = need
        if self.prefix_cache:
            self._private[req_id] = need
        self.used_blocks += need
        self.peak_used = max(self.peak_used, self.used_blocks)
        self.swap_ins += 1
        self.swapped_in_blocks += restored
        return True

    def handoff_out(self, req_id: int) -> int:
        """Release a prefill-finished request's device blocks for
        migration to a decode replica (prefill/decode disaggregation).

        Device-side bookkeeping is exactly a :meth:`free`, but — unlike
        :meth:`swap_out` — nothing is charged to the *local* host tier:
        the KV copy leaves this machine with the request, landing in the
        target's tier via its :meth:`import_swapped`.  Returns the block
        count to offer the target."""
        held = self._held.get(req_id, 0)
        assert held > 0, f"handoff_out of request {req_id} holding no blocks"
        assert req_id not in self._swapped, \
            f"request {req_id} is swapped, not handoff-ready"
        self.free(req_id)
        self.freed -= 1                    # it migrated, it did not finish
        self.handoff_outs += 1
        self.handoff_out_blocks += held
        return held

    def drop_swapped(self, req_id: int) -> None:
        """Discard a swapped-out request's host copy (e.g. it migrated to
        another replica and must recompute there)."""
        if self._swapped.pop(req_id, None) is not None:
            self.swap_drops += 1

    def export_swapped(self, req_id: int) -> int:
        """Detach a swapped-out request's host charge for cross-replica
        migration (graceful spot-reclaim drain: the request's host copy
        leaves with the request, not with the dying machine).  Returns the
        block count to hand :meth:`import_swapped` on the target; 0 when
        the request holds no swapped state here."""
        held = self._swapped.pop(req_id, None)
        if held is None:
            return 0
        self.swap_exports += 1
        return held

    def import_swapped(self, req_id: int, blocks: int) -> bool:
        """Adopt a migrated request's swapped block set into *this*
        replica's host tier (the receiving half of :meth:`export_swapped`).
        Charged against the local host budget like any swapped copy, so a
        full tier rejects the import and the request degrades to recompute.
        Returns False (state unchanged) when it does not fit."""
        if blocks <= 0 or req_id in self._swapped or req_id in self._held:
            return False
        if self.host_free_blocks < blocks:
            return False
        self._swapped[req_id] = int(blocks)
        self.swap_imports += 1
        return True

    def reset(self) -> None:
        self._held.clear()
        self._index.clear()
        self._lru.clear()
        self._prefix_of.clear()
        self._private.clear()
        self._hit_tokens.clear()
        self._host.clear()
        self._swapped.clear()
        self._host_hit_blocks.clear()
        self.used_blocks = 0
        self.peak_used = 0
        self.overflow_admissions = 0
        self.admitted = 0
        self.freed = 0
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens_total = 0
        self.prefix_prompt_tokens_total = 0
        self.prefix_evictions = 0
        self.spilled_blocks = 0
        self.host_evictions = 0
        self.host_hits = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.swapped_out_blocks = 0
        self.swapped_in_blocks = 0
        self.swap_drops = 0
        self.swap_exports = 0
        self.swap_imports = 0
        self.handoff_outs = 0
        self.handoff_out_blocks = 0


def logical_tokens(input_len: int, quota: int, remaining: int) -> int:
    """Logical KV tokens a request holds mid-decode: the prompt, the first
    token from prefill, and every decode step taken so far."""
    return input_len + 1 + (quota - remaining)


def batch_tokens(states: Iterable) -> Sequence[Tuple[int, int]]:
    """(req_id, logical tokens) pairs for a batch of RequestStates."""
    return [(s.req.req_id,
             logical_tokens(s.req.input_len, s.quota, s.remaining))
            for s in states]
