"""Paged KV-cache subsystem: the memory model both executors admit by.

* :mod:`allocator` — fixed-size block ids from a free list.
* :mod:`manager` — symbolic per-replica block accounting (admission,
  lockstep growth, preemption feasibility) at trace-scale tokens.
* :mod:`budget` — per-replica block budgets from the hardware catalog and
  cost model (``kv_free_bytes``: HBM minus weights minus overhead).
* :mod:`paged` — real block-backed ``(num_blocks, block_size, KV, D)``
  pools + block tables for the engine backend's paged decode.
"""
from repro.runtime.kvcache.allocator import BlockAllocator, hash_blocks
from repro.runtime.kvcache.budget import (DEFAULT_BLOCK_SIZE, block_bytes,
                                          make_kv_manager, num_kv_blocks,
                                          state_overhead_blocks)
from repro.runtime.kvcache.manager import (KVCacheManager, batch_tokens,
                                           blocks_for_tokens, logical_tokens)
from repro.runtime.kvcache.paged import (DEFAULT_ENGINE_BLOCK_SIZE,
                                         PagedEngineCache)

__all__ = [
    "BlockAllocator", "DEFAULT_BLOCK_SIZE", "DEFAULT_ENGINE_BLOCK_SIZE",
    "KVCacheManager", "PagedEngineCache", "batch_tokens", "block_bytes",
    "blocks_for_tokens", "hash_blocks", "logical_tokens", "make_kv_manager",
    "num_kv_blocks", "state_overhead_blocks",
]
