"""Physical block-backed KV cache for one real engine replica.

Where :class:`~repro.runtime.kvcache.manager.KVCacheManager` does symbolic
trace-scale accounting for admission, :class:`PagedEngineCache` owns the
*actual tensors* behind a replica's decode: per-layer K/V pools shaped
``(n_periods, num_blocks, block_size, KV, D)``, one shared
:class:`~repro.runtime.kvcache.allocator.BlockAllocator`, and per-slot
block tables.  Prefill still runs contiguous (one cohort shares a prompt
shape), then the cohort's prompt K/V is scattered into freshly allocated
blocks; from then on every sequence on the replica decodes through the
block table in one shape-stable lockstep call — continuous batching across
admission cohorts at the *tensor* level, not just the scheduler level.

Physical block id 0 is a reserved scratch block: empty slots' tables point
at it, so the writes of inactive lanes land somewhere harmless and the
decode step never needs a gather-free special case.  (The decode core
zeroes dead lanes' K/V before the scatter — colliding scratch writes all
write the same value, keeping pool contents deterministic whatever scatter
order XLA picks; see ``transformer._paged_decode_core``.)

With ``prefix_cache=True`` the pool gains cross-request prefix reuse: each
*full* block of a prompt is published under its chained content hash
(:func:`~repro.runtime.kvcache.allocator.hash_blocks`, at engine-scale
token ids), a later request whose prompt matches aliases the cached block
ids straight into its block table (:meth:`admit_prefixed`) and only its
unique suffix is prefilled and scattered, and :meth:`release` decrefs
instead of freeing — a released request's hashed blocks park in the
allocator's LRU cached pool, contents intact, until evicted under
allocation pressure.  Aliased blocks are read-shared only: decode writes
always land past ``t_prompt``, i.e. in blocks this request allocated
privately, so sharing never needs a copy on the hot path
(:meth:`ensure_writable` provides the defensive copy-on-write used if a
caller ever must mutate a shared block).

Slots are runtime-scale (``t_max`` = prompt + generated tokens on this
container), so the pool is sized to hold every slot at full length —
admission control (and therefore preemption) is the symbolic manager's
job; this layer proves the plan executes through real paged storage.

**Host tier** (``host_blocks > 0``): the physical counterpart of the
manager's symbolic tier.  Evicted-but-hashed blocks spill into a bounded
:class:`~repro.serving.engine.HostBlockPool` — preallocated NumPy storage
with block-granular device_get/device_put copies — driven by the
allocator's spill/evict/revive callbacks, so ``adopt`` transparently
revives a host-resident prefix block bitwise-identical into a fresh
device block.  Swap-based preemption rides the same copy machinery:
:meth:`swap_out_request` lands a victim's occupied blocks in transient
host buffers (bounded by the symbolic manager's host budget, which gates
every swap) and :meth:`swap_in_request` scatters them back and rebinds
the slot, so decode resumes exactly where it stopped.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.runtime.kvcache.allocator import BlockAllocator, hash_blocks

DEFAULT_ENGINE_BLOCK_SIZE = 8


class PagedEngineCache:
    """Block pools + tables + slot bookkeeping for one ReplicaEngine."""

    def __init__(self, cfg, num_slots: int, t_max: int,
                 block_size: int = DEFAULT_ENGINE_BLOCK_SIZE, *,
                 prefix_cache: bool = False, host_blocks: int = 0):
        import jax.numpy as jnp
        self.cfg = cfg
        self.block_size = block_size
        self.num_slots = max(1, num_slots)
        self.t_max = t_max
        self.prefix_cache = bool(prefix_cache)
        self.blocks_per_seq = max(1, math.ceil(t_max / block_size))
        # +1 for the reserved scratch block at id 0
        self.num_blocks = 1 + self.num_slots * self.blocks_per_seq
        np_, kv, dh = cfg.n_periods, cfg.n_kv_heads, cfg.head_dim
        self.pools = [
            {"k": jnp.zeros((np_, self.num_blocks, block_size, kv, dh),
                            jnp.bfloat16),
             "v": jnp.zeros((np_, self.num_blocks, block_size, kv, dh),
                            jnp.bfloat16)}
            for _ in cfg.period]
        self.host_blocks = max(0, int(host_blocks))
        self.allocator = BlockAllocator(
            self.num_blocks - 1, first_id=1,
            host_blocks=self.host_blocks,
            on_spill=self._spill_block if self.host_blocks else None,
            on_host_evict=self._drop_host_hash if self.host_blocks else None,
            on_revive=self._revive_block if self.host_blocks else None)
        self.tables = np.zeros((self.num_slots, self.blocks_per_seq),
                               np.int32)
        self.lengths = np.zeros(self.num_slots, np.int32)
        self.tokens = np.zeros(self.num_slots, np.int32)
        self._free_slots: List[int] = list(range(self.num_slots - 1, -1, -1))
        self._slot_of: Dict[int, int] = {}
        self._blocks_of: Dict[int, List[int]] = {}
        # host tier (all idle when host_blocks == 0)
        self._host_pool = None               # lazy HostBlockPool
        self._host_slot_of_hash: Dict[int, int] = {}
        self._host_swapped: Dict[int, tuple] = {}
        self.physical_hit_blocks = 0     # aliased instead of prefilled
        self.physical_hit_requests = 0
        self.host_spill_bytes = 0
        self.host_revive_bytes = 0
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0

    @property
    def active_slots(self) -> int:
        return len(self._slot_of)

    def slot_of(self, req_id: int) -> int:
        return self._slot_of[req_id]

    # ------------------------------------------------------ prefix matching

    def block_hashes(self, row: Sequence[int], t_prompt: int) -> List[int]:
        """Engine-scale chained content hashes of ``row``'s matchable full
        blocks — capped below ``t_prompt`` so a fully-cached prompt still
        prefills at least its last token (the first logits must come from
        somewhere)."""
        if not self.prefix_cache:
            return []
        return hash_blocks(row, self.block_size,
                           max_match_tokens=min(len(row), t_prompt) - 1)

    def match_len(self, hashes: Sequence[int]) -> int:
        """Longest matchable prefix of ``hashes`` (no state change):
        device-indexed blocks plus host-resident spilled blocks, which
        :meth:`adopt_prefix` revives on adoption."""
        n = 0
        for h in hashes:
            if (self.allocator.lookup(h) is None
                    and not self.allocator.host_contains(h)):
                break
            n += 1
        return n

    # ----------------------------------------------------------- host tier

    def _ensure_host_pool(self):
        if self._host_pool is None:
            from repro.serving.engine import HostBlockPool
            cfg = self.cfg
            # +1 slot of slack: during a host revive the incoming block's
            # copy is still resident while the device alloc it triggers may
            # spill one more block out (see BlockAllocator._revive_from_host).
            self._host_pool = HostBlockPool(
                len(cfg.period), cfg.n_periods, self.host_blocks + 1,
                self.block_size, cfg.n_kv_heads, cfg.head_dim,
                self.pools[0]["k"].dtype)
        return self._host_pool

    def _spill_block(self, block_id: int, h: int) -> None:
        """Allocator callback: copy an evicted device block out to host
        before its id is recycled."""
        pool = self._ensure_host_pool()
        stale = self._host_slot_of_hash.pop(h, None)
        if stale is not None:            # re-spill of a hash we still hold
            pool.free([stale])
        slot = pool.alloc(1)[0]
        self.host_spill_bytes += pool.put([slot], self.pools, [block_id])
        self._host_slot_of_hash[h] = slot

    def _drop_host_hash(self, h: int) -> None:
        """Allocator callback: the host tier evicted a spilled hash."""
        slot = self._host_slot_of_hash.pop(h, None)
        if slot is not None:
            self._host_pool.free([slot])

    def _revive_block(self, block_id: int, h: int) -> None:
        """Allocator callback: copy a host-resident hash back into a fresh
        device block (bitwise-identical contents)."""
        slot = self._host_slot_of_hash.pop(h)
        self.pools, moved = self._host_pool.get([slot], self.pools,
                                                [block_id])
        self.host_revive_bytes += moved
        self._host_pool.free([slot])

    # ---------------------------------------------------------- admission

    def admit_cohort(self, req_ids: Sequence[int], prompt_caches,
                     first_tokens, t_prompt: int,
                     block_hashes_per_req: Optional[Sequence[Sequence[int]]]
                     = None) -> None:
        """Bind one cold-prefilled cohort to slots: allocate each
        sequence's blocks, scatter the cohort's contiguous prompt K/V into
        them, and record lengths/last-tokens.  ``prompt_caches`` is the
        engine's per-layer list of ``{"k","v"}`` with leaves
        ``(n_periods, b, t_cache, KV, D)`` where ``t_cache >= t_prompt``.
        ``block_hashes_per_req`` (prefix caching) publishes each request's
        full prompt blocks in the content index after the scatter."""
        import jax.numpy as jnp
        b = len(req_ids)
        if b > len(self._free_slots):
            raise MemoryError(f"{b} sequences for {len(self._free_slots)} "
                              f"free slots")
        bs = self.block_size
        nb = math.ceil(t_prompt / bs)
        slots = [self._free_slots.pop() for _ in range(b)]
        flat_ids: List[int] = []
        for rid, slot in zip(req_ids, slots):
            ids = self.allocator.alloc(self.blocks_per_seq)
            self._slot_of[rid] = slot
            self._blocks_of[rid] = ids
            self.tables[slot, :] = ids
            flat_ids.extend(ids[:nb])
        idx = jnp.asarray(flat_ids, jnp.int32)
        for i, cache in enumerate(prompt_caches):
            for key in ("k", "v"):
                leaf = cache[key][:, :, :t_prompt]          # (np, b, t_p, ...)
                pad = nb * bs - t_prompt
                if pad:
                    leaf = jnp.pad(leaf, ((0, 0), (0, 0), (0, pad),
                                          (0, 0), (0, 0)))
                np_, _, _, kv, dh = leaf.shape
                leaf = leaf.reshape(np_, b * nb, bs, kv, dh)
                self.pools[i][key] = self.pools[i][key].at[:, idx].set(
                    leaf.astype(self.pools[i][key].dtype))
        toks = np.asarray(first_tokens, np.int32)
        for j, (rid, slot) in enumerate(zip(req_ids, slots)):
            self.lengths[slot] = t_prompt
            self.tokens[slot] = toks[j]
        if block_hashes_per_req is not None:
            for rid, hashes in zip(req_ids, block_hashes_per_req):
                self._commit_blocks(rid, hashes)

    def adopt_prefix(self, hashes: Sequence[int]) -> List[int]:
        """Take references on the cached blocks for ``hashes`` (all must be
        indexed — pair with :meth:`match_len`); returns their block ids in
        prefix order."""
        ids: List[int] = []
        for h in hashes:
            block_id = self.allocator.adopt(h)
            assert block_id is not None, "adopt_prefix on unmatched hash"
            ids.append(block_id)
        return ids

    def admit_prefixed(self, req_ids: Sequence[int],
                       prefix_ids_per_req: Sequence[Sequence[int]],
                       suffix_caches, first_tokens, t_hit: int,
                       t_prompt: int,
                       block_hashes_per_req: Sequence[Sequence[int]]
                       ) -> None:
        """Bind one *warm* cohort (every request matched ``t_hit`` prompt
        tokens = ``t_hit / block_size`` whole cached blocks): alias the
        adopted prefix block ids into each slot's table, allocate only the
        remaining blocks, scatter the cohort's *suffix* K/V
        (``suffix_caches`` leaves ``(n_periods, b, t_suf_cache, KV, D)``
        covering positions ``t_hit..t_prompt``), then publish the newly
        full prompt blocks under their hashes."""
        import jax.numpy as jnp
        b = len(req_ids)
        if b > len(self._free_slots):
            raise MemoryError(f"{b} sequences for {len(self._free_slots)} "
                              f"free slots")
        bs = self.block_size
        assert t_hit % bs == 0 and 0 < t_hit < t_prompt
        n_hit = t_hit // bs
        s_suffix = t_prompt - t_hit
        nb_suf = math.ceil(s_suffix / bs)
        slots = [self._free_slots.pop() for _ in range(b)]
        flat_ids: List[int] = []
        for rid, slot, pref in zip(req_ids, slots, prefix_ids_per_req):
            assert len(pref) == n_hit
            ids = list(pref) + self.allocator.alloc(
                self.blocks_per_seq - n_hit)
            self._slot_of[rid] = slot
            self._blocks_of[rid] = ids
            self.tables[slot, :] = ids
            flat_ids.extend(ids[n_hit:n_hit + nb_suf])
        idx = jnp.asarray(flat_ids, jnp.int32)
        for i, cache in enumerate(suffix_caches):
            for key in ("k", "v"):
                leaf = cache[key][:, :, :s_suffix]        # (np, b, s_suf, ..)
                pad = nb_suf * bs - s_suffix
                if pad:
                    leaf = jnp.pad(leaf, ((0, 0), (0, 0), (0, pad),
                                          (0, 0), (0, 0)))
                np_, _, _, kv, dh = leaf.shape
                leaf = leaf.reshape(np_, b * nb_suf, bs, kv, dh)
                self.pools[i][key] = self.pools[i][key].at[:, idx].set(
                    leaf.astype(self.pools[i][key].dtype))
        toks = np.asarray(first_tokens, np.int32)
        for j, (rid, slot) in enumerate(zip(req_ids, slots)):
            self.lengths[slot] = t_prompt
            self.tokens[slot] = toks[j]
        for rid, hashes in zip(req_ids, block_hashes_per_req):
            self._commit_blocks(rid, hashes)
        self.physical_hit_blocks += b * n_hit
        self.physical_hit_requests += b

    def _commit_blocks(self, req_id: int, hashes: Sequence[int]) -> None:
        """Publish a request's full prompt blocks under their content
        hashes.  A hash already naming another block keeps its canonical
        owner (this request's copy stays private and unshared)."""
        if not self.prefix_cache:
            return
        ids = self._blocks_of[req_id]
        for j, h in enumerate(hashes):
            if self.allocator.block_hash(ids[j]) is None:
                self.allocator.commit(ids[j], h)

    # --------------------------------------------------------------- step

    def step_args(self):
        """(pools, tables, lengths, tokens) for one lockstep decode call."""
        import jax.numpy as jnp
        return (self.pools, jnp.asarray(self.tables),
                jnp.asarray(self.lengths), jnp.asarray(self.tokens))

    def steps_to_boundary(self) -> int:
        """Lockstep steps until the first occupied slot crosses into its
        next block — the fused-decode chunk cap: within one fused chunk
        every slot's write block stays fixed, so the scan only advances
        the in-block offset (``transformer.paged_decode_steps`` contract).
        Empty slots sit at length 0 (a full block of scratch headroom), so
        the cap is never below 1 and never above ``block_size``."""
        bs = self.block_size
        dists = [bs - int(self.lengths[slot]) % bs
                 for slot in self._slot_of.values()]
        return min(dists, default=bs)

    def advance(self, k: int) -> None:
        """Every occupied slot consumed ``k`` more cache positions (called
        per fused sub-chunk, *before* the tokens are ever read back)."""
        for slot in self._slot_of.values():
            self.lengths[slot] += k

    def commit_chunk(self, last_tokens, new_pools) -> None:
        """Record a fused chunk's results — lengths were already advanced
        via :meth:`advance`; adopt the pools and each occupied slot's
        newest token (``last_tokens`` is host-side, (S,))."""
        self.pools = new_pools
        toks = np.asarray(last_tokens)
        for slot in self._slot_of.values():
            self.tokens[slot] = toks[slot]

    def commit_step(self, new_tokens, new_pools) -> None:
        """Record one decode step's results: every *occupied* slot consumed
        one cache position and produced one token."""
        self.advance(1)
        self.commit_chunk(new_tokens, new_pools)

    # --------------------------------------------------------------- cow

    def ensure_writable(self, req_id: int, block_index: int) -> int:
        """Copy-on-write guard: make ``req_id``'s table entry at
        ``block_index`` safe to mutate, physically copying the block's
        pool rows to a private id when it is shared or published.  The
        decode path never needs this (writes land past the shared prompt
        by construction); it exists for correctness under any future
        mutation of shared blocks and for the property tests."""
        old = self._blocks_of[req_id][block_index]
        new, copied = self.allocator.cow(old)
        if not copied:
            return old
        for i in range(len(self.pools)):
            for key in ("k", "v"):
                pool = self.pools[i][key]
                self.pools[i][key] = pool.at[:, new].set(pool[:, old])
        self._blocks_of[req_id][block_index] = new
        self.tables[self._slot_of[req_id], block_index] = new
        return new

    # ------------------------------------------------------------ release

    def release(self, req_id: int) -> None:
        """Free a finished/preempted request's slot.  Block references are
        dropped, not zeroed: blocks shared with live requests survive, and
        this request's published blocks park in the allocator's LRU cached
        pool — the next request with the same prefix aliases them back."""
        slot = self._slot_of.pop(req_id, None)
        if slot is None:
            return
        self.allocator.free(self._blocks_of.pop(req_id))
        self.tables[slot, :] = 0          # scratch block: writes are inert
        self.lengths[slot] = 0
        self.tokens[slot] = 0
        self._free_slots.append(slot)

    # ------------------------------------------------------ swap preemption

    def swap_out_request(self, req_id: int) -> int:
        """Copy a preemption victim's occupied blocks to host and release
        its slot.  The *whole* occupied block set goes out — shared prefix
        blocks included (they are read-shared, so copying is safe) — making
        the saved state independent of index churn before readmission.
        Capacity is the symbolic manager's host budget (it gates every
        swap); the copies here are transient per-request host buffers.
        Returns bytes moved."""
        slot = self._slot_of[req_id]
        length = int(self.lengths[slot])
        nb = max(1, min(self.blocks_per_seq,
                        math.ceil(length / self.block_size)))
        idx = np.asarray(self._blocks_of[req_id][:nb], np.int32)
        saved = []
        moved = 0
        for pool in self.pools:
            entry = {}
            for key in ("k", "v"):
                rows = np.asarray(pool[key][:, idx])   # device_get
                entry[key] = rows
                moved += rows.nbytes
            saved.append(entry)
        self._host_swapped[req_id] = (saved, length, int(self.tokens[slot]))
        self.swap_out_bytes += moved
        self.release(req_id)
        return moved

    def swap_in_request(self, req_id: int) -> int:
        """Rebind a swapped-out request: allocate a slot + fresh blocks,
        scatter the saved host copy back (device_put), and restore length
        and last token so decode resumes mid-stream.  Restored blocks stay
        private and unhashed — no index pollution.  Returns bytes moved."""
        import jax.numpy as jnp
        saved, length, last_token = self._host_swapped.pop(req_id)
        if not self._free_slots:
            raise MemoryError(f"swap_in of request {req_id} with no free slot")
        slot = self._free_slots.pop()
        ids = self.allocator.alloc(self.blocks_per_seq)
        self._slot_of[req_id] = slot
        self._blocks_of[req_id] = ids
        self.tables[slot, :] = ids
        nb = saved[0]["k"].shape[1]
        idx = jnp.asarray(np.asarray(ids[:nb], np.int32))
        moved = 0
        for i, entry in enumerate(saved):
            for key in ("k", "v"):
                rows = entry[key]
                self.pools[i][key] = self.pools[i][key].at[:, idx].set(
                    jnp.asarray(rows).astype(self.pools[i][key].dtype))
                moved += rows.nbytes
        self.lengths[slot] = length
        self.tokens[slot] = last_token
        self.swap_in_bytes += moved
        return moved

    def has_swapped(self, req_id: int) -> bool:
        return req_id in self._host_swapped

    def drop_swapped(self, req_id: int) -> None:
        """Discard a swapped-out request's host copy (migration path)."""
        self._host_swapped.pop(req_id, None)

    # ----------------------------------------------- cross-replica migration

    def export_swapped(self, req_id: int) -> Optional[tuple]:
        """Detach a swapped-out request's saved host buffers so they can
        migrate to another replica of the same model (graceful spot-reclaim
        drain).  The payload is pure host-side NumPy — ``(per-layer k/v
        rows, length, last token)`` — so it survives this replica's device
        state being torn down.  Returns None when nothing is swapped."""
        return self._host_swapped.pop(req_id, None)

    def import_swapped(self, req_id: int, payload: tuple) -> bool:
        """Adopt a migrated request's saved buffers into *this* replica's
        swap staging area (the receiving half of :meth:`export_swapped`);
        a later :meth:`swap_in_request` restores them exactly like a local
        swap.  The payload's row shapes must match this pool's layout
        (same arch / block size) — a mismatched import is rejected and the
        caller degrades the request to recompute.  Returns success."""
        if payload is None or req_id in self._host_swapped \
                or req_id in self._slot_of:
            return False
        saved, length, _last = payload
        if len(saved) != len(self.pools):
            return False
        np_, nb, bs, kv, dh = saved[0]["k"].shape
        pool_shape = self.pools[0]["k"].shape
        if (np_, bs, kv, dh) != (pool_shape[0], pool_shape[2],
                                 pool_shape[3], pool_shape[4]):
            return False
        if nb > self.blocks_per_seq or length > self.t_max:
            return False
        self._host_swapped[req_id] = payload
        return True
