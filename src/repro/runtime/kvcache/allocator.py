"""Fixed-size KV block allocator.

The unit of KV-cache memory is a *block* of ``block_size`` token slots
(vLLM's PagedAttention unit).  :class:`BlockAllocator` hands out block ids
from a free list; the engine backend uses the ids to index real
``(num_blocks, block_size, KV, D)`` pool tensors, while the admission-side
:class:`~repro.runtime.kvcache.manager.KVCacheManager` only needs the
counts.  Block id 0 is reserved by callers that need a scratch target for
masked writes (see ``paged.py``); the allocator itself is id-agnostic.
"""
from __future__ import annotations

from typing import List


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` block ids.

    Ids run ``first_id .. first_id + num_blocks - 1``; allocation is LIFO
    (most-recently-freed first) so a steady-state workload keeps touching
    the same hot blocks.
    """

    def __init__(self, num_blocks: int, *, first_id: int = 0):
        if num_blocks < 0:
            raise ValueError(f"num_blocks must be >= 0, got {num_blocks}")
        self.num_blocks = num_blocks
        self.first_id = first_id
        self._free: List[int] = list(range(first_id + num_blocks - 1,
                                           first_id - 1, -1))
        self._allocated: set = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._allocated)

    def alloc(self, n: int) -> List[int]:
        """Allocate ``n`` block ids; raises ``MemoryError`` if unavailable
        (callers must check ``free_blocks`` / go through the manager)."""
        if n > len(self._free):
            raise MemoryError(
                f"requested {n} blocks, {len(self._free)} free")
        ids = [self._free.pop() for _ in range(n)]
        self._allocated.update(ids)
        return ids

    def free(self, ids: List[int]) -> None:
        for i in ids:
            if i not in self._allocated:
                raise ValueError(f"double free / unknown block id {i}")
            self._allocated.discard(i)
            self._free.append(i)
