"""Fixed-size KV block allocator with cross-request prefix sharing.

The unit of KV-cache memory is a *block* of ``block_size`` token slots
(vLLM's PagedAttention unit).  :class:`BlockAllocator` hands out block ids
from a free list; the engine backend uses the ids to index real
``(num_blocks, block_size, KV, D)`` pool tensors, while the admission-side
:class:`~repro.runtime.kvcache.manager.KVCacheManager` only needs the
counts.  Block id 0 is reserved by callers that need a scratch target for
masked writes (see ``paged.py``); the allocator itself is id-agnostic.

Prefix caching (vLLM-style) adds three ideas on top of the free list:

* **content hashes** — a *full* block of prompt tokens is immutable once
  written, so it can be named by the chained hash
  ``h_i = hash((h_{i-1}, tokens_i))`` (:func:`hash_blocks`) and published
  in an index via :meth:`commit`;
* **reference counting** — :meth:`adopt` lets a later request alias an
  indexed block instead of recomputing it; :meth:`free` becomes a decref
  that only reclaims a block when its last holder leaves;
* **an LRU cached pool** — a committed block whose refcount drops to zero
  is *not* returned to the free list (its contents stay valid); it parks
  in an LRU from which :meth:`adopt` can revive it for free, and
  :meth:`alloc` evicts oldest-first only when the free list runs dry.

Writability is the copy-on-write rule: a block is safe to mutate only
while it has exactly one holder *and* no published hash
(:meth:`writable`); :meth:`cow` hands a caller a private replacement id
for a shared block (the physical copy is the pool owner's job — this
layer only does the id bookkeeping).

A second, host-memory tier (``host_blocks > 0``) sits under the LRU:
instead of vanishing, an evicted block's hash *spills* to a bounded host
pool (``on_spill`` copies the physical contents out before the device id
is recycled) and :meth:`adopt` transparently *revives* host-resident
hashes — allocating a fresh device id and asking ``on_revive`` to copy
the contents back in.  The host pool is itself LRU-bounded
(``on_host_evict`` drops the oldest spilled hash).  With
``host_blocks=0`` every code path is byte-identical to the single-tier
allocator.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# Root of every chained block hash.  Python's hash of int tuples is
# deterministic (PYTHONHASHSEED only salts str/bytes), so hashes agree
# across processes for the same token ids and block size.
_HASH_ROOT = 0x9E3779B9


def hash_blocks(tokens: Sequence[int], block_size: int,
                max_match_tokens: Optional[int] = None) -> List[int]:
    """Chained content hashes of the *full* blocks covering ``tokens``.

    ``h_i = hash((h_{i-1}, block_i_tokens))`` — a block's name commits to
    the whole prefix in front of it, so equal hashes imply equal logical
    KV content.  ``max_match_tokens`` caps how many leading tokens may be
    matched (callers pass ``prompt_len - 1`` so a full-prompt match always
    leaves at least one suffix token to prefill for the first logits).
    """
    if block_size <= 0:
        return []
    limit = len(tokens)
    if max_match_tokens is not None:
        limit = min(limit, max_match_tokens)
    out: List[int] = []
    h = _HASH_ROOT ^ block_size
    for start in range(0, limit - block_size + 1, block_size):
        h = hash((h, tuple(int(t) for t in tokens[start:start + block_size])))
        out.append(h)
    return out


class BlockAllocator:
    """Free-list + refcount + content-hash allocator over block ids.

    Ids run ``first_id .. first_id + num_blocks - 1``; allocation is LIFO
    (most-recently-freed first) so a steady-state workload keeps touching
    the same hot blocks.  Blocks come back through :meth:`free` with
    refcount semantics: unhashed blocks return to the free list, hashed
    blocks park in the LRU cached pool until evicted or revived.
    """

    def __init__(self, num_blocks: int, *, first_id: int = 0,
                 host_blocks: int = 0,
                 on_spill: Optional[Callable[[int, int], None]] = None,
                 on_host_evict: Optional[Callable[[int], None]] = None,
                 on_revive: Optional[Callable[[int, int], None]] = None):
        if num_blocks < 0:
            raise ValueError(f"num_blocks must be >= 0, got {num_blocks}")
        if host_blocks < 0:
            raise ValueError(f"host_blocks must be >= 0, got {host_blocks}")
        self.num_blocks = num_blocks
        self.first_id = first_id
        self._free: List[int] = list(range(first_id + num_blocks - 1,
                                           first_id - 1, -1))
        self._refs: Dict[int, int] = {}           # live blocks -> refcount
        self._hash_of: Dict[int, int] = {}        # committed id -> hash
        self._index: Dict[int, int] = {}          # hash -> canonical id
        # refcount-0 committed blocks, oldest first (eviction order)
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        # Host tier: spilled hashes, oldest first.  Physical storage is the
        # pool owner's job, driven by the three callbacks:
        #   on_spill(device_id, h)   copy device block out, *before* the id
        #                            is recycled;
        #   on_host_evict(h)         drop a spilled hash's host copy;
        #   on_revive(device_id, h)  copy a spilled hash back into a freshly
        #                            allocated device block.
        self.host_blocks = host_blocks
        self._host: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._on_spill = on_spill
        self._on_host_evict = on_host_evict
        self._on_revive = on_revive
        self.evictions = 0
        self.cache_hits = 0       # adopt() calls that found a block
        self.cow_copies = 0
        self.spilled_blocks = 0   # device LRU evictions that went to host
        self.host_evictions = 0   # spilled hashes dropped from the host tier
        self.host_revives = 0     # adopt() hits served from the host tier

    # ------------------------------------------------------------- queries

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        """Blocks holding reusable prefix KV (refcount 0, still indexed)."""
        return len(self._lru)

    @property
    def available_blocks(self) -> int:
        """Blocks :meth:`alloc` can satisfy (free + evictable cached)."""
        return len(self._free) + len(self._lru)

    @property
    def used_blocks(self) -> int:
        """Blocks held by at least one live sequence."""
        return len(self._refs)

    @property
    def host_used_blocks(self) -> int:
        """Spilled hashes currently resident in the host tier."""
        return len(self._host)

    def host_contains(self, h: int) -> bool:
        """True when content hash ``h`` can be revived from the host tier
        (device index takes precedence: a device-resident hash is never
        reported as host-resident)."""
        return h not in self._index and h in self._host

    def ref_count(self, block_id: int) -> int:
        return self._refs.get(block_id, 0)

    def block_hash(self, block_id: int) -> Optional[int]:
        return self._hash_of.get(block_id)

    def writable(self, block_id: int) -> bool:
        """True when mutating ``block_id`` in place cannot corrupt another
        holder: exactly one reference and no published hash (a committed
        block may be adopted at any time, so it is immutable even at one
        reference)."""
        return (self._refs.get(block_id) == 1
                and block_id not in self._hash_of)

    # ---------------------------------------------------------- allocation

    def alloc(self, n: int) -> List[int]:
        """Allocate ``n`` private (refcount-1) block ids, evicting LRU
        cached blocks when the free list runs dry; raises ``MemoryError``
        if even the cached pool cannot cover the request (callers must
        check ``available_blocks`` / go through the manager)."""
        if n > self.available_blocks:
            raise MemoryError(
                f"requested {n} blocks, {len(self._free)} free "
                f"+ {len(self._lru)} cached")
        while len(self._free) < n:
            self._evict_lru()
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._refs[i] = 1
        return ids

    def _evict_lru(self) -> int:
        """Evict the oldest cached block from the device.  Without a host
        tier its hash simply leaves the index (future lookups miss); with
        one, the hash spills to the bounded host pool — contents copied out
        via ``on_spill`` *before* the device id returns to the free list —
        from which :meth:`adopt` can still revive it."""
        block_id, _ = self._lru.popitem(last=False)
        h = self._hash_of.pop(block_id)
        if self._index.get(h) == block_id:
            del self._index[h]
        if self.host_blocks > 0:
            # Make room first so the pool owner never holds more than
            # ``host_blocks`` spilled copies (+1 transient during a revive).
            while len(self._host) >= self.host_blocks and h not in self._host:
                old_h, _ = self._host.popitem(last=False)
                if self._on_host_evict is not None:
                    self._on_host_evict(old_h)
                self.host_evictions += 1
            if self._on_spill is not None:
                self._on_spill(block_id, h)
            self._host[h] = None
            self._host.move_to_end(h)
            self.spilled_blocks += 1
        self._free.append(block_id)
        self.evictions += 1
        return block_id

    # ------------------------------------------------------------- sharing

    def lookup(self, h: int) -> Optional[int]:
        """The canonical block id for content hash ``h`` (no state change)."""
        return self._index.get(h)

    def adopt(self, h: int) -> Optional[int]:
        """Take one reference on the block holding content hash ``h``:
        a live block gains a holder; a cached block leaves the LRU and
        revives; a host-resident hash revives into a freshly allocated
        device block (``on_revive`` copies the contents back).  Returns
        None on a miss."""
        block_id = self._index.get(h)
        if block_id is None:
            if h in self._host:
                return self._revive_from_host(h)
            return None
        if block_id in self._lru:           # revive from the cached pool
            del self._lru[block_id]
            self._refs[block_id] = 1
        else:
            self._refs[block_id] += 1
        self.cache_hits += 1
        return block_id

    def _revive_from_host(self, h: int) -> Optional[int]:
        if self.available_blocks < 1:
            return None                     # no device block to land in
        # Pop the host entry *first*: the alloc below may itself evict and
        # spill another block, and must not count ``h`` against the host
        # bound (its physical slot is released by ``on_revive``, so the
        # pool owner briefly holds host_blocks + 1 copies).
        del self._host[h]
        block_id = self.alloc(1)[0]
        self._index[h] = block_id
        self._hash_of[block_id] = h
        if self._on_revive is not None:
            self._on_revive(block_id, h)
        self.host_revives += 1
        self.cache_hits += 1
        return block_id

    def incref(self, block_id: int) -> None:
        if block_id not in self._refs:
            raise ValueError(f"incref on non-live block id {block_id}")
        self._refs[block_id] += 1

    def commit(self, block_id: int, h: int) -> int:
        """Publish a live block under content hash ``h``.  If the index
        already names another block for ``h`` (two requests prefilled the
        same prefix concurrently), the existing block stays canonical and
        ``block_id`` remains an unhashed private copy; returns the
        canonical id either way."""
        if block_id not in self._refs:
            raise ValueError(f"commit on non-live block id {block_id}")
        existing = self._index.get(h)
        if existing is not None and existing != block_id:
            return existing
        self._index[h] = block_id
        self._hash_of[block_id] = h
        return block_id

    def cow(self, block_id: int) -> Tuple[int, bool]:
        """Copy-on-write: a holder about to mutate ``block_id`` gets a
        block id that is safe to write.  Already-writable blocks are
        returned as-is; otherwise one reference moves to a freshly
        allocated private id (the caller copies the physical contents).
        Returns ``(writable_id, copied)``."""
        if self.writable(block_id):
            return block_id, False
        if block_id not in self._refs:
            raise ValueError(f"cow on non-live block id {block_id}")
        new_id = self.alloc(1)[0]
        self._decref(block_id)
        self.cow_copies += 1
        return new_id, True

    # ------------------------------------------------------------- release

    def _decref(self, block_id: int) -> None:
        refs = self._refs.get(block_id)
        if refs is None:
            raise ValueError(f"double free / unknown block id {block_id}")
        if refs > 1:
            self._refs[block_id] = refs - 1
            return
        del self._refs[block_id]
        if block_id in self._hash_of:
            # contents stay valid: park in the cached pool, newest last
            self._lru[block_id] = None
            self._lru.move_to_end(block_id)
        else:
            self._free.append(block_id)

    def free(self, ids: Sequence[int]) -> None:
        """Drop one reference per id.  A block is reclaimed only when its
        last holder leaves; committed blocks go to the LRU cached pool
        (contents preserved for future :meth:`adopt`), unhashed blocks to
        the free list."""
        for i in ids:
            self._decref(i)
