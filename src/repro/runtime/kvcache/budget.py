"""Per-replica KV block budgets derived from the hardware catalog.

The paper's thesis is that GPU types differ most in *memory*, so the
resource the scheduler optimizes — KV-cache capacity — must be modeled the
same way at prediction and execution time.  This module turns a replica
:class:`~repro.core.plan.Config` (devices x TP x PP from ``core.catalog``)
plus a :class:`~repro.core.costmodel.ModelProfile` into a concrete block
budget using the identical ``kv_free_bytes`` formula the planner's batch
cap uses: usable HBM minus weights minus runtime overhead.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.core.costmodel import ModelProfile, kv_free_bytes
from repro.core.plan import Config

from repro.runtime.kvcache.manager import KVCacheManager

# Logical (trace-scale) tokens per KV block.  16 matches vLLM's default and
# keeps per-request rounding waste under one percent at paper-scale context
# lengths (~500..3000 tokens).
DEFAULT_BLOCK_SIZE = 16


def block_bytes(model: ModelProfile, block_size: int) -> float:
    """HBM bytes one block of ``block_size`` token slots occupies (all
    attention layers of the model)."""
    return block_size * model.kv_bytes_per_token


def num_kv_blocks(config: Config, model: ModelProfile,
                  block_size: int = DEFAULT_BLOCK_SIZE) -> int:
    """How many KV blocks this replica's free HBM holds (0 if the weights
    alone do not fit)."""
    bb = block_bytes(model, block_size)
    if bb <= 0:
        return 0
    free = kv_free_bytes(config.stages, model)
    return max(0, int(free // bb))


def host_ram_blocks(ram_bytes: float, model: ModelProfile,
                    block_size: int = DEFAULT_BLOCK_SIZE) -> int:
    """How many trace-scale KV blocks a host-RAM budget of ``ram_bytes``
    holds for ``model`` — the two-tier cache's host side, sized from the
    catalog's per-device ``host_ram_bytes`` instead of a hand-picked block
    count."""
    bb = block_bytes(model, block_size)
    if bb <= 0 or ram_bytes <= 0:
        return 0
    return max(0, int(ram_bytes // bb))


def host_blocks_for(config: Config, model: ModelProfile,
                    host_ram_bytes, block_size: int = DEFAULT_BLOCK_SIZE,
                    *, default: int = 0) -> int:
    """Resolve an executor's host-tier sizing policy to a block count.

    ``host_ram_bytes`` is None (keep the flat ``default`` count), a number
    (bytes per replica), or ``"auto"`` (sum the catalog's per-device
    ``host_ram_bytes`` over the replica's stages — each GPU contributes its
    host's RAM share)."""
    if host_ram_bytes is None:
        return default
    if host_ram_bytes == "auto":
        ram = sum(st.tp * st.device.host_ram_bytes for st in config.stages)
    else:
        ram = float(host_ram_bytes)
    return host_ram_blocks(ram, model, block_size)


def state_overhead_blocks(model: ModelProfile, block_size: int) -> int:
    """Constant per-sequence recurrent-state cost (SSM/xLSTM), expressed in
    blocks so the manager can charge it at admission."""
    if model.state_bytes_per_seq <= 0:
        return 0
    bb = block_bytes(model, block_size)
    if bb <= 0:
        return 0
    return math.ceil(model.state_bytes_per_seq / bb)


def make_kv_manager(config: Config, model: ModelProfile,
                    block_size: int = DEFAULT_BLOCK_SIZE, *,
                    prefix_cache: bool = False,
                    host_blocks: int = 0
                    ) -> Optional[KVCacheManager]:
    """Build the admission-side manager for one replica.

    Models with no per-token KV growth but constant recurrent state
    (pure SSM/xLSTM stacks) get *state-only* accounting: one block per
    sequence, the pool sized by how many sequences' state the free HBM
    holds.  Only models with no KV *and* no state return None (nothing to
    account — the concurrency cap alone governs them).  ``prefix_cache``
    turns on cross-request prefix sharing (the manager itself gates it off
    for sliding-window and state-only models, whose blocks are mutable or
    absent); ``host_blocks`` sizes the host-memory tier evicted prefix
    blocks spill to and swapped preemption victims park in (0 = off)."""
    if block_bytes(model, block_size) > 0:
        return KVCacheManager(
            num_kv_blocks(config, model, block_size), block_size,
            window=model.window,
            state_blocks=state_overhead_blocks(model, block_size),
            prefix_cache=prefix_cache,
            host_blocks=host_blocks)
    if model.state_bytes_per_seq > 0:
        free = kv_free_bytes(config.stages, model)
        return KVCacheManager(
            max(0, int(free // model.state_bytes_per_seq)), 0,
            state_blocks=1)
    return None
