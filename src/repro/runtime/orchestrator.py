"""The unified event-driven serving runtime.

:class:`ServingRuntime` executes a trace against a ``ServingPlan`` with
**streaming dispatch** — each request is routed at its arrival time through
the plan's :class:`~repro.runtime.router.AssignmentRouter`, never upfront —
and per-replica continuous batching
(:class:`~repro.runtime.replica.ReplicaRuntime`).  The pluggable
:class:`~repro.runtime.executor.Executor` decides whether the run is a
cost-model *prediction* (``CostModelExecutor``) or real token *execution*
(``EngineExecutor``); both travel the identical admission/batching/routing
code path and report the same TTFT/TPOT/goodput metrics.

Time model — one **global event heap**: every replica is an event
generator (:meth:`~repro.runtime.replica.ReplicaRuntime.next_event_time` /
``begin_step``/``complete_step``) and the runtime always pops the
globally-earliest event, so arrivals, admissions, decode steps, replans,
and autoscale decisions interleave in true time order across replicas.
When the executor is concurrent (``EngineExecutor``), popped events are
*executed* on per-replica actor workers
(:class:`~repro.runtime.actor.ReplicaWorker`) so prefill/decode calls of
different replicas overlap in wall time, their futures resolving back
into the heap.  ``mode="sequential"`` keeps the legacy
replica-at-a-time loop as the equivalence baseline (byte-identical
schedules on the cost-model backend, asserted in ``tests/test_runtime``).

Arrivals come from an :class:`ArrivalSource`: :class:`TraceSource` replays
a recorded trace (``run(trace)`` is a thin wrapper over it, byte-identical
to the historical trace loop), while :class:`LiveSource` is a thread-safe
queue fed by online ``submit()`` calls — the serving loop drains it
between events, blocks on it while idle, and runs on a **wall-clock time
base** (arrival stamps are seconds since the run started) next to the
replicas' virtual clocks.  ``repro.serving.Session`` is the user-facing
façade over a live run.

Online replanning: pass :class:`ReplanEvent` s (e.g. the output of
``repro.core.replan`` when a spot pool is reclaimed).  At each
event time the runtime matches the new plan's replicas against the live
pool by config key — survivors keep their clock, queue, and active batch;
removed replicas drain their active batch but their *queued* requests
migrate through the new plan's router to surviving/new replicas; arrivals
after the event are routed by the new plan.

Autoscaling: pass a :class:`~repro.core.scheduler.ScalePolicy` as
``autoscale`` — the runtime samples per-replica queue depth and KV
watermark every ``policy.interval`` seconds of serving time and applies
the policy's add/drain decisions as online replans (with queue
rebalancing, so a scale-up immediately relieves a backlogged survivor).
Decisions are recorded in :attr:`scale_log` and counted in
``result.info``.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.plan import ServingPlan
from repro.core.workloads import Trace

from repro.runtime.actor import ReplicaWorker
from repro.runtime.disagg import HandoffManager
from repro.runtime.executor import Executor
from repro.runtime.faults import FaultEvent, FaultInjector, as_injector
from repro.runtime.lifecycle import RequestState, RuntimeResult
from repro.runtime.replica import ReplicaRuntime
from repro.runtime.router import AssignmentRouter

MODES = ("events", "sequential")


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """Switch to ``plan`` at runtime time ``time`` (seconds)."""

    time: float
    plan: ServingPlan


# ----------------------------------------------------------- arrival sources

class ArrivalSource:
    """Where requests enter the runtime.

    The serving loop only ever asks a source five questions: pop every
    arrival due by a barrier (:meth:`take_until`), when the first arrival
    happens (:meth:`first_arrival`, seeds the autoscale tick), whether
    more can ever come (:meth:`exhausted`), and — for ``live`` sources —
    what time it is (:meth:`now`, the wall-clock base) and to sleep until
    something changes (:meth:`wait`).  :meth:`records` returns every
    :class:`RequestState` the source ever produced, in arrival order;
    they become ``RuntimeResult.records``.
    """

    live: bool = False

    def start(self) -> None:
        """Called once when the serving loop begins consuming."""

    def records(self) -> List[RequestState]:
        raise NotImplementedError

    def take_until(self, barrier: float) -> List[RequestState]:
        """Pop (without blocking) every pending arrival with
        ``arrival <= barrier``, in arrival order."""
        raise NotImplementedError

    def first_arrival(self) -> float:
        return 0.0

    def exhausted(self) -> bool:
        """True when no arrival is pending and none can ever come."""
        raise NotImplementedError

    # -- live extras (wall-clock sources only) ------------------------------

    def now(self) -> float:
        raise NotImplementedError

    def version(self) -> int:
        """Monotone change counter (new submission / close / kick)."""
        return 0

    def wait(self, seen: int, timeout: Optional[float] = None) -> bool:
        """Block until the version moves past ``seen`` (or timeout)."""
        return False

    def kick(self) -> None:
        """Wake any :meth:`wait` er (e.g. from a future's done-callback)."""


class TraceSource(ArrivalSource):
    """Replays a recorded :class:`~repro.core.workloads.Trace`: every
    arrival is known up front, so the loop dispatches all requests due by
    each barrier and fast-forwards virtual time — byte-identical to the
    historical ``run(trace)`` behavior (asserted in ``tests/test_runtime``
    and ``tests/test_session``)."""

    def __init__(self, trace: Trace):
        self.trace = trace
        self._order = sorted(trace.requests, key=lambda q: q.arrival)
        self._states = [RequestState(req=req) for req in self._order]
        self._pos = 0

    def records(self) -> List[RequestState]:
        return self._states

    def take_until(self, barrier: float) -> List[RequestState]:
        out: List[RequestState] = []
        while (self._pos < len(self._states)
               and self._order[self._pos].arrival <= barrier):
            out.append(self._states[self._pos])
            self._pos += 1
        return out

    def first_arrival(self) -> float:
        return self._order[0].arrival if self._order else 0.0

    def exhausted(self) -> bool:
        return self._pos >= len(self._states)


class LiveSource(ArrivalSource):
    """A thread-safe online arrival queue (the ``submit()`` path).

    Producers (any thread) call :meth:`submit` with a builder that is
    handed the **wall-clock arrival stamp** — seconds since the run
    started — under the source lock, so arrivals are monotone.  The
    serving loop drains the queue between events and blocks in
    :meth:`wait` while idle until a new submission, a :meth:`kick` (an
    executor future completing), or :meth:`close`; ``close()`` lets the
    loop drain what's left and finish.
    """

    live = True

    def __init__(self, *, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._t0: Optional[float] = None
        self._cond = threading.Condition()
        self._pending: List[RequestState] = []
        self._all: List[RequestState] = []
        self._closed = False
        self._version = 0

    def start(self) -> None:
        with self._cond:
            if self._t0 is None:
                self._t0 = self._clock()

    def now(self) -> float:
        return 0.0 if self._t0 is None else self._clock() - self._t0

    def submit(self, build: Callable[[float], RequestState]) -> RequestState:
        """Enqueue ``build(arrival_stamp)``; returns the built state."""
        with self._cond:
            if self._closed:
                raise RuntimeError("cannot submit to a closed LiveSource")
            state = build(self.now())
            self._pending.append(state)
            self._all.append(state)
            self._version += 1
            self._cond.notify_all()
        return state

    def close(self) -> None:
        """No further submissions; the serving loop drains and returns."""
        with self._cond:
            self._closed = True
            self._version += 1
            self._cond.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    def kick(self) -> None:
        with self._cond:
            self._version += 1
            self._cond.notify_all()

    def version(self) -> int:
        with self._cond:
            return self._version

    def wait(self, seen: int, timeout: Optional[float] = None) -> bool:
        with self._cond:
            if self._version != seen:
                return True
            return self._cond.wait_for(lambda: self._version != seen,
                                       timeout)

    def records(self) -> List[RequestState]:
        with self._cond:
            return list(self._all)

    def take_until(self, barrier: float) -> List[RequestState]:
        with self._cond:
            out: List[RequestState] = []
            while self._pending and self._pending[0].req.arrival <= barrier:
                out.append(self._pending.pop(0))
            return out

    def exhausted(self) -> bool:
        with self._cond:
            return self._closed and not self._pending


class ServingRuntime:
    """One continuous-batching core behind both prediction and execution."""

    def __init__(self, plan: ServingPlan, executor: Executor, *,
                 mode: str = "events", preempt_policy: str = "latest",
                 preempt_mode: str = "recompute",
                 on_done: Optional[Callable[[RequestState], None]] = None,
                 obs=None, clock: Optional[Callable[[], float]] = None,
                 retry_budget: int = 2,
                 worker_timeout: Optional[float] = None,
                 handoff_queue: int = 8):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if retry_budget < 0:
            raise ValueError(f"retry_budget must be >= 0, "
                             f"got {retry_budget}")
        self.plan = plan
        self.executor = executor
        self.mode = mode
        self.preempt_policy = preempt_policy
        self.preempt_mode = preempt_mode
        # Fault tolerance: how many fault-forced re-serves one request may
        # pay before the runtime gives up on it (``RequestState.failed``),
        # and the wall-clock bound on each worker call (None = unbounded;
        # see repro.runtime.actor.WorkerTimeout).
        self.retry_budget = int(retry_budget)
        self.worker_timeout = worker_timeout
        # Disaggregation: bound on exported-but-undelivered KV handoffs
        # (the TransferQueue capacity; see repro.runtime.disagg).
        self.handoff_queue = int(handoff_queue)
        self.on_done = on_done    # fired (orchestrator thread) per finished
        # Optional repro.obs.Observability — a pure observer: every hook
        # below is behind `is not None` (the disabled fast path) and only
        # records already-known timestamps.
        self.obs = obs
        if obs is not None:
            executor.obs = obs    # backends report compute durations
        if clock is not None:
            # Injectable time source for executors that *measure* (the
            # engine backend); tests pin a deterministic
            # repro.obs.TickClock here (see repro.obs.clock).
            executor.clock = clock
        self._workers: Dict[int, ReplicaWorker] = {}   # or dropped request
        self.reset()

    def reset(self) -> None:
        """Rebuild all serving state over the base plan so the same
        runtime can serve again (the session/server lifecycle: one
        long-lived runtime, many runs).  Executor-side state is reset
        separately (e.g. ``EngineExecutor.configure``)."""
        self._close_workers()
        self.replicas: List[ReplicaRuntime] = [
            ReplicaRuntime(i, cfg, self.executor,
                           preempt_policy=self.preempt_policy,
                           preempt_mode=self.preempt_mode,
                           on_done=self.on_done, obs=self.obs)
            for i, cfg in enumerate(self.plan.replicas)]
        if self.obs is not None:
            for r in self.replicas:
                self.obs.register_replica(r.index, r.config)
        # Disaggregation: one cluster-level HandoffManager when the plan
        # carries role-split replicas (a pure-"both" plan keeps the
        # colocated fast path: no manager, no pump, byte-identical
        # schedules to pre-disaggregation runs).
        self._handoffs: Optional[HandoffManager] = None
        self._wire_handoffs()
        # router's plan-local replica j -> global ReplicaRuntime
        self._route_map: List[ReplicaRuntime] = list(self.replicas)
        self.router = self._make_router(self.plan, self._route_map)
        self.info: Dict[str, object] = {}
        self.scale_log: List[object] = []     # ScaleDecision records
        # Fault recovery: requests displaced with nowhere to go wait here
        # for capacity to recover (re-dispatched after every fault/replan;
        # failed if the run ends first), and exported host-tier payloads
        # ride along keyed by req_id until their request lands somewhere.
        self._orphans: List[RequestState] = []
        self._swap_payloads: Dict[int, tuple] = {}

    def _wire_handoffs(self) -> None:
        """Create the :class:`HandoffManager` the first time a role-split
        replica appears (reset, or a replan that introduces roles) and
        inject it into every replica — a prefill-role replica only hands
        off when ``handoff_mgr`` is wired."""
        if self._handoffs is None and any(
                getattr(r.config, "role", "both") != "both"
                for r in self.replicas):
            self._handoffs = HandoffManager(
                self.executor, lambda: self.replicas,
                queue_capacity=self.handoff_queue, obs=self.obs)
        if self._handoffs is not None:
            for r in self.replicas:
                r.handoff_mgr = self._handoffs

    def _pump_handoffs(self, heap: Optional[List], until: float) -> None:
        """Retry parked/stalled handoffs after a committed event (target
        capacity may have freed) and re-push every replica whose runnable
        state changed onto the event heap (None in sequential/live mode,
        where the caller's own loop re-polls)."""
        hm = self._handoffs
        if hm is None:
            return
        hm.pump()
        touched = hm.drain_touched()
        if heap is None:
            return
        for i in touched:
            rep = self.replicas[i]
            t = rep.next_event_time()
            if t < until:
                heapq.heappush(heap, (t, i))

    def _handoff_stalled(self, rep: ReplicaRuntime) -> bool:
        """True when ``rep`` reports a startable event time but is really
        blocked on handoff backpressure (exports that fit nowhere, or
        parked transfers throttling its admission) — only a pump after
        someone else's progress can unblock it, so idleness checks must
        not treat it as runnable."""
        hm = self._handoffs
        return hm is not None and bool(
            rep.handoff_ready or hm.queue.parked_from(rep.index))

    def _make_router(self, plan: ServingPlan,
                     route_map: List[ReplicaRuntime]) -> AssignmentRouter:
        """Build the plan's router; when the executor runs prefix caching,
        attach a warm-prefix affinity probe that asks each candidate
        replica's KV manager how many prompt tokens its prefix index
        already holds (see ``AssignmentRouter``)."""
        if not getattr(self.executor, "prefix_cache", False):
            return AssignmentRouter(plan)

        def affinity(j: int, req) -> int:
            if req.prompt is None or j >= len(route_map):
                return 0
            mgr = self.executor.kv_manager(route_map[j].index)
            if mgr is None:
                return 0
            return mgr.cached_prefix_tokens(req.prompt, req.input_len + 1)

        return AssignmentRouter(plan, prefix_affinity=affinity)

    # ------------------------------------------------------------- dispatch

    def _dispatch(self, state: RequestState,
                  at: Optional[float] = None) -> None:
        j = self.router.route(state.req)
        t = state.req.arrival if at is None else at
        if j is None:
            state.replica = -1     # unroutable: no replica serves this model
            if self.obs is not None:
                self.obs.on_route(t, state.req, None, None, False)
            if self.on_done is not None:
                self.on_done(state)    # unblock any waiting handle
            return
        target = self._route_map[j]
        if target.dead:
            # Routed onto a faulted replica (no watcher replanned around
            # it): park until capacity recovers instead of queueing on a
            # corpse — at run end still-parked requests fail.
            self._orphans.append(state)
            self._bump("requests_orphaned", 1)
            return
        state.routed_at = t
        if self.obs is not None:
            warmth, fallback = self.router.last_pick
            self.obs.on_route(t, state.req, target.index,
                              warmth, fallback)
        target.enqueue(state)

    # -------------------------------------------------------------- replan

    def _apply_replan(self, event: ReplanEvent, *,
                      rebalance: bool = False) -> None:
        """Switch the live pool to ``event.plan``.  ``rebalance`` (used by
        the autoscaler) additionally re-routes every *queued* request of
        surviving replicas through the new plan's router, so an added
        replica immediately shares a survivor's backlog."""
        new_plan = event.plan
        live = [r for r in self.replicas if not r.draining]
        before_keys = [r.config.key for r in live]
        claimed: set = set()
        kept = 0
        new_map: List[ReplicaRuntime] = []
        for cfg in new_plan.replicas:
            # Among same-key candidates, keep the one with the most
            # outstanding work (ties: lowest index, the legacy order) —
            # so when the autoscaler drains one of several identical
            # replicas, the *idle* instance is the one released.
            candidates = [r for r in live if r.config.key == cfg.key
                          and r.index not in claimed]
            match = max(candidates,
                        key=lambda r: (len(r.active) + len(r.queue),
                                       -r.index)) if candidates else None
            if match is not None:
                claimed.add(match.index)
                # An idle survivor's clock may lag the replan point; clamp so
                # migrated requests cannot be admitted before the event that
                # moved them (busy survivors are already past event.time).
                match.now = max(match.now, event.time)
                new_map.append(match)
                kept += 1
            else:
                idx = len(self.replicas)
                self.executor.add_replica(cfg)
                rep = ReplicaRuntime(idx, cfg, self.executor,
                                     preempt_policy=self.preempt_policy,
                                     preempt_mode=self.preempt_mode,
                                     on_done=self.on_done, obs=self.obs)
                rep.now = event.time          # spun up at the replan point
                if self.obs is not None:
                    self.obs.register_replica(rep.index, rep.config)
                self.replicas.append(rep)
                new_map.append(rep)
        migrated: List[RequestState] = []
        for r in live:
            if r.index not in claimed:
                r.draining = True             # finish active, admit nothing
                migrated.extend(r.strip_queue())
        if rebalance:
            for r in new_map:
                migrated.extend(r.strip_queue())
        self._wire_handoffs()   # replan-added replicas join the handoff flow
        self.router = self._make_router(new_plan, new_map)
        self._route_map = new_map
        for state in sorted(migrated, key=lambda s: s.req.arrival):
            self._dispatch(state, at=event.time)   # rerouted now, not on arrival
        self._bump("replicas_kept", kept)
        self._bump("replicas_added", len(new_plan.replicas) - kept)
        self._bump("replicas_drained", len(live) - kept)
        self._bump("requests_migrated", len(migrated))
        if self.obs is not None:
            self.obs.on_replan(event.time, before_keys,
                               [c.key for c in new_plan.replicas],
                               migrated=len(migrated), kept=kept)

    def _bump(self, key: str, n: float) -> None:
        self.info[key] = float(self.info.get(key, 0)) + n

    # ------------------------------------------------- measured hit rates

    def _measured_hit_rates(self) -> Optional[Dict[int, float]]:
        """The prefix hit rate actually observed so far, summed over every
        replica's KV manager and broadcast to all workload classes (the
        managers don't track hits per workload) — the feedback signal
        replan/autoscale fold back into the analytical throughput model.
        None when the executor runs no prefix cache or no prompt token has
        been admitted yet."""
        if not getattr(self.executor, "prefix_cache", False):
            return None
        hit = prompt = 0
        for r in self.replicas:
            mgr = self.executor.kv_manager(r.index)
            if mgr is not None:
                hit += mgr.prefix_hit_tokens_total
                prompt += mgr.prefix_prompt_tokens_total
        if prompt <= 0:
            return None
        from repro.core.workloads import WORKLOAD_TYPES
        rate = hit / prompt
        return {w: rate for w in range(len(WORKLOAD_TYPES))}

    # --------------------------------------------------------------- faults

    def _fault_victims(self, event: FaultEvent) -> List[ReplicaRuntime]:
        """Deterministic victim choice for a capacity-loss event: live
        replicas whose config uses the faulted GPU type, highest index
        first, until ``event.count`` devices are reclaimed.  Depends only
        on plan structure (device counts and replica indices) — never on
        load or backend timing — so the cost and engine backends kill
        identical replicas for the same schedule."""
        victims: List[ReplicaRuntime] = []
        need = event.count
        for rep in sorted(self.replicas, key=lambda r: -r.index):
            if rep.dead or rep.draining:
                continue
            used = rep.config.device_counts().get(event.gpu_type, 0)
            if used <= 0:
                continue
            victims.append(rep)
            need -= used
            if need <= 0:
                break
        return victims

    def _fail_request(self, state: RequestState, t: float) -> None:
        """Give up on a request (retry budget exhausted, or the run ended
        with it still orphaned): terminal for its handle, never served."""
        state.failed = True
        self._bump("requests_failed", 1)
        if self.obs is not None:
            self.obs.on_request_failed(t, state.req, state.retries)
        if self.on_done is not None:
            self.on_done(state)      # unblock any waiting handle

    def _kill_replica(self, rep: ReplicaRuntime, t: float, *,
                      grace: float = 0.0,
                      extra: Sequence[RequestState] = ()
                      ) -> List[RequestState]:
        """Tear one replica down (fault or wedged worker) and sort its
        requests into migrate / requeue / fail; returns everything that
        still needs a new home."""
        displaced, lost, payloads = rep.force_drain(t, grace=grace,
                                                    extra=extra)
        if self._handoffs is not None:
            # Planned-but-unexported handoffs die with the replica: return
            # their reserved target blocks (the states themselves came
            # back through force_drain's ``extra``).
            self._handoffs.abort_source(rep.index)
        self._swap_payloads.update(payloads)
        self._bump("replicas_lost", 1)
        if self.obs is not None:
            self.obs.on_replica_dead(rep.index, t)
        worker = self._workers.pop(rep.index, None)
        if worker is not None:
            worker.close(timeout=0.1)   # its thread may be wedged: don't
                                        # block the serving loop on it
        self.executor.teardown(rep.index)   # payloads are already detached
        out: List[RequestState] = []
        for s in displaced:
            if s.retries > self.retry_budget:
                self._swap_payloads.pop(s.req.req_id, None)
                self._fail_request(s, t)
            else:
                out.append(s)
        self._bump("requests_requeued",
                   sum(1 for s in lost if not s.failed))
        return out

    def _dispatch_fault(self, state: RequestState, t: float) -> None:
        """Re-route a fault-displaced request.  A swap-migrated request
        adopts its exported host payload on the target (symbolic blocks
        first, then the physical rows; either refusing degrades it to
        recompute).  With no live target it parks in the orphan pen."""
        j = self.router.route(state.req)
        target = self._route_map[j] if j is not None else None
        if target is None or target.dead or target.draining:
            self._orphans.append(state)
            self._bump("requests_orphaned", 1)
            return
        rid = state.req.req_id
        payload = self._swap_payloads.pop(rid, None)
        if state.swapped:
            ok = False
            if payload is not None:
                sym, phys = payload
                mgr = self.executor.kv_manager(target.index)
                if mgr is not None and mgr.import_swapped(rid, sym):
                    ok = self.executor.import_swapped(target.index, state,
                                                      phys)
                    if not ok:
                        mgr.drop_swapped(rid)
            if ok:
                self._bump("swap_migrations", 1)
            else:
                state.swapped = False
                state.remaining = 0
                self._bump("swap_migrations_failed", 1)
        state.routed_at = t
        if self.obs is not None:
            warmth, fallback = self.router.last_pick
            self.obs.on_route(t, state.req, target.index, warmth, fallback)
        target.enqueue(state)

    def _apply_fault(self, event: FaultEvent,
                     injector: FaultInjector) -> None:
        """Fold one fault event into the live pool: kill victims (with
        grace-window swap draining on a reclaim), let the attached
        watcher replan under the new availability, then re-dispatch the
        displaced requests and any parked orphans."""
        t = event.time
        victims = ([] if event.kind == "recover"
                   else self._fault_victims(event))
        injector.log.append((t, event.kind, event.gpu_type,
                             tuple(r.index for r in victims)))
        self._bump("faults_injected", 1)
        self._bump(f"fault_{event.kind}s", 1)
        if self.obs is not None:
            self.obs.on_fault(t, event.kind, event.gpu_type,
                              [r.index for r in victims])
        displaced: List[RequestState] = []
        grace = event.grace if event.kind == "reclaim" else 0.0
        for rep in victims:
            displaced.extend(self._kill_replica(rep, t, grace=grace))
        watcher = injector.watcher
        if watcher is not None:
            watcher.observe(event)
            try:
                new_plan = watcher.replan(
                    self.router.plan, hit_rates=self._measured_hit_rates())
            except Exception:
                # Infeasible under the new snapshot (e.g. the pool went
                # to zero): keep serving on what's left; orphans wait.
                new_plan = None
                self._bump("fault_replan_failures", 1)
            if new_plan is not None:
                self._apply_replan(ReplanEvent(time=t, plan=new_plan))
                self._bump("fault_replans", 1)
        parked, self._orphans = self._orphans, []
        for state in sorted(parked + displaced,
                            key=lambda s: s.req.arrival):
            self._dispatch_fault(state, t)

    def _worker_failure(self, rep: ReplicaRuntime, pending,
                        exc: BaseException) -> None:
        """An executor call failed (worker exception or
        :class:`~repro.runtime.actor.WorkerTimeout`): structured failure
        — the replica is treated as crashed and its requests requeue —
        instead of a corrupted or hung event heap."""
        self._bump("worker_failures", 1)
        if self.obs is not None:
            self.obs.on_worker_failure(rep.index, rep.now, repr(exc))
        displaced = self._kill_replica(rep, rep.now, grace=0.0,
                                       extra=pending.batch)
        for state in sorted(displaced, key=lambda s: s.req.arrival):
            self._dispatch_fault(state, rep.now)

    # ---------------------------------------------------------- autoscaling

    def _snapshot(self):
        """Per-replica load observations for the scale policy."""
        from repro.core.scheduler import ReplicaSnapshot
        snaps = []
        for r in self.replicas:
            mgr = self.executor.kv_manager(r.index)
            kv = 0.0
            if mgr is not None and mgr.num_blocks > 0:
                kv = mgr.used_blocks / mgr.num_blocks
            snaps.append(ReplicaSnapshot(
                index=r.index, config=r.config, queue_len=len(r.queue),
                active=len(r.active), kv_used_frac=float(kv),
                draining=r.draining, dead=r.dead,
                step_time_s=self.executor.step_time_estimate(r.index)))
        return snaps

    def _autoscale_tick(self, t: float, policy) -> None:
        before_keys = [c.key for c in self.router.plan.replicas]
        if getattr(policy, "hit_rate_feedback", False):
            rates = self._measured_hit_rates()
            if rates:
                from repro.core.scheduler import _hit_rate_throughput_fn
                policy.throughput_fn = _hit_rate_throughput_fn(rates)
        decision = policy.update(t, self._snapshot(), self.router.plan)
        if decision is None:
            return
        self.scale_log.append(decision)
        if self.obs is not None:
            self.obs.on_scale_decision(t, decision, before_keys)
        self._bump("autoscale_adds" if decision.action == "add"
                   else "autoscale_drains", 1)
        self._apply_replan(ReplanEvent(time=t, plan=decision.plan),
                           rebalance=True)

    # ----------------------------------------------------------------- run

    def run(self, trace: Trace, *,
            replan: Union[ReplanEvent, Sequence[ReplanEvent], None] = None,
            autoscale=None, faults=None) -> RuntimeResult:
        """Serve a recorded trace (thin wrapper over :meth:`run_source`
        with a :class:`TraceSource`; byte-identical to the historical
        trace loop)."""
        return self.run_source(TraceSource(trace), replan=replan,
                               autoscale=autoscale, faults=faults)

    def run_source(self, source: ArrivalSource, *,
                   replan: Union[ReplanEvent, Sequence[ReplanEvent],
                                 None] = None,
                   autoscale=None, faults=None) -> RuntimeResult:
        """Serve every arrival the source produces; returns per-request
        records + aggregate metrics.

        ``replan`` passes pre-planned :class:`ReplanEvent` s; ``autoscale``
        optionally passes a :class:`~repro.core.scheduler.ScalePolicy`
        that emits further replans online from observed load; ``faults``
        passes a :class:`~repro.runtime.faults.FaultInjector` (or a
        :class:`~repro.runtime.faults.FaultPlan` / plain event list) whose
        schedule is folded into the barrier computation exactly like
        scheduled replans.  With a ``live`` source, replan/tick/fault
        times are wall-clock offsets from the run start and the loop
        blocks while idle instead of returning.
        """
        events: List[ReplanEvent] = (
            [replan] if isinstance(replan, ReplanEvent)
            else sorted(replan, key=lambda e: e.time) if replan else [])
        injector: Optional[FaultInjector] = None
        if faults is not None:
            injector = as_injector(faults)
            injector.reset()
        source.start()
        if self.obs is not None:
            self.obs.begin_run(self.plan, live=source.live)
        ei = 0
        tick = math.inf
        if autoscale is not None:
            autoscale.reset()
            autoscale.obs = self.obs
            tick = source.first_arrival() + autoscale.interval
        try:
            while True:
                next_replan = (events[ei].time if ei < len(events)
                               else math.inf)
                next_fault = (injector.next_time() if injector is not None
                              else math.inf)
                barrier = min(next_replan, tick, next_fault)
                for state in source.take_until(barrier):
                    self._dispatch(state)
                if source.live:
                    self._advance_live(source, until=barrier)
                else:
                    self._advance_all(until=barrier)
                if barrier == math.inf:
                    break
                if next_fault <= barrier:
                    # fault first on ties: a simultaneous replan then sees
                    # the post-fault pool, like a real availability feed
                    self._apply_fault(injector.pop(), injector)
                elif next_replan <= tick:
                    self._apply_replan(events[ei])
                    ei += 1
                else:
                    self._autoscale_tick(tick, autoscale)
                    tick += autoscale.interval
                    if (source.exhausted() and ei >= len(events)
                            and (injector is None or injector.exhausted)
                            and all(r.next_event_time() == math.inf
                                    or self._handoff_stalled(r)
                                    for r in self.replicas)):
                        break     # fully served and closed: stop ticking
        finally:
            self._close_workers()
        if self._handoffs is not None:
            # Handoffs the run ended around: parked transfers nothing ever
            # absorbed and exports that never got to start — terminal,
            # like orphans (their device/host KV is released so the leak
            # accounting stays clean).
            t_end = max([r.now for r in self.replicas] or [0.0])
            for rec in self._handoffs.queue.drain():
                rec.state.swapped = False
                rec.state.remaining = 0
                self._fail_request(rec.state, t_end)
                self._bump("handoffs_stranded", 1)
            for rep in self.replicas:
                if not rep.handoff_ready:
                    continue
                mgr = self.executor.kv_manager(rep.index)
                for s in rep.handoff_ready:
                    if mgr is not None:
                        mgr.free(s.req.req_id)
                    self.executor.preempt(rep.index, s)
                    s.remaining = 0
                    self._fail_request(s, t_end)
                    self._bump("handoffs_stranded", 1)
                rep.handoff_ready = []
        if self._orphans:
            # the schedule never brought capacity back for these
            parked, self._orphans = self._orphans, []
            t_end = max([r.now for r in self.replicas] or [0.0])
            for state in parked:
                self._fail_request(state, t_end)
        states = source.records()
        busy = np.array([r.busy for r in self.replicas])
        info = dict(self.info)
        info["preemptions"] = float(sum(r.preempted for r in self.replicas))
        per_replica: List[Dict[str, object]] = []
        kv_peaks: List[float] = []
        hit_tok, prompt_tok = 0, 0
        swap_outs = swap_ins = 0
        swap_out_bytes = swap_in_bytes = spilled = 0.0
        for r in self.replicas:
            mgr = self.executor.kv_manager(r.index)
            entry = {
                "replica": r.index,
                "config": r.config.key,
                "role": getattr(r.config, "role", "both"),
                "busy_s": float(r.busy),
                "completed": r.completed,
                "preemptions": r.preempted,
                "draining": r.draining,
                "dead": r.dead,
                "dead_at": r.dead_at,
                "kv_peak_blocks": mgr.peak_used if mgr is not None else None,
                "kv_blocks": mgr.num_blocks if mgr is not None else None,
                "prefix_hit_rate": (mgr.prefix_hit_rate
                                    if mgr is not None and mgr.prefix_cache
                                    else None),
                "step_time_s": self.executor.step_time_estimate(r.index),
            }
            if mgr is not None:
                kv_peaks.append(mgr.peak_used)
                hit_tok += mgr.prefix_hit_tokens_total
                prompt_tok += mgr.prefix_prompt_tokens_total
                if mgr.host_blocks > 0:
                    bb = self.executor.kv_block_bytes(r.index)
                    entry["swap_outs"] = mgr.swap_outs
                    entry["swap_ins"] = mgr.swap_ins
                    entry["swapped_out_bytes"] = mgr.swapped_out_blocks * bb
                    entry["swapped_in_bytes"] = mgr.swapped_in_blocks * bb
                    swap_outs += mgr.swap_outs
                    swap_ins += mgr.swap_ins
                    swap_out_bytes += mgr.swapped_out_blocks * bb
                    swap_in_bytes += mgr.swapped_in_blocks * bb
                    spilled += mgr.spilled_blocks
            if self._handoffs is not None:
                bb = self.executor.kv_block_bytes(r.index)
                entry["handoffs"] = r.handoffs
                entry["handoff_blocks"] = r.handoff_blocks
                entry["handoff_bytes"] = r.handoff_blocks * bb
            per_replica.append(entry)
        info["per_replica"] = per_replica
        if self._handoffs is not None:
            info["handoffs"] = float(sum(r.handoffs for r in self.replicas))
            info["handoff_bytes"] = float(sum(
                r.handoff_blocks * self.executor.kv_block_bytes(r.index)
                for r in self.replicas))
            # (req_id, target replica, blocks) per committed handoff, in
            # source commit order per replica — backend-independent for
            # deterministic target topologies (asserted in tests).
            info["handoff_log"] = [list(r.handoff_log)
                                   for r in self.replicas]
            info.update(self._handoffs.stats())
        if kv_peaks:
            info["kv_peak_blocks"] = float(max(kv_peaks))
        if swap_outs or swap_ins or spilled:
            info["swap_outs"] = float(swap_outs)
            info["swap_ins"] = float(swap_ins)
            info["swapped_out_bytes"] = float(swap_out_bytes)
            info["swapped_in_bytes"] = float(swap_in_bytes)
            info["host_spilled_blocks"] = float(spilled)
        if getattr(self.executor, "prefix_cache", False):
            info["prefix_hit_rate"] = (hit_tok / prompt_tok
                                       if prompt_tok else 0.0)
            info["prefix_hit_tokens"] = float(hit_tok)
        if autoscale is not None:
            info["autoscale_events"] = float(len(self.scale_log))
        if injector is not None:
            # (time, kind, gpu_type, victim indices) per applied event —
            # backend-independent by construction, asserted in tests
            info["fault_log"] = list(injector.log)
            if injector.watcher is not None:
                info["watcher_replans"] = float(injector.watcher.replans)
        return RuntimeResult(records=states, per_replica_busy=busy,
                             info=info)

    # ------------------------------------------------------------- advance

    def _advance_all(self, until: float = math.inf) -> None:
        """Advance every replica until no event can start before ``until``
        (atomic events may complete past it)."""
        if self.mode == "sequential":
            while True:
                progressed = False
                for rep in self.replicas:
                    while rep.step(until=until):
                        progressed = True
                if self._handoffs is None:
                    break
                # Cross-replica deliveries (handoff payloads landing on
                # decode replicas) can unblock replicas already passed
                # this sweep: pump, then fixpoint until nothing moves.
                if self._handoffs.pump():
                    progressed = True
                self._handoffs.drain_touched()
                if not progressed:
                    break
        elif getattr(self.executor, "concurrent", False) \
                and len(self.replicas) > 1:
            self._advance_concurrent(until)
        else:
            self._advance_events(until)

    def _advance_events(self, until: float = math.inf) -> None:
        """Global event heap: always fire the event with the earliest
        start time across all replicas."""
        heap: List = []
        for r in self.replicas:
            t = r.next_event_time()
            if t < until:
                heapq.heappush(heap, (t, r.index))
        while heap:
            _, i = heapq.heappop(heap)
            rep = self.replicas[i]
            pending = rep.begin_step(until)
            if pending is None:
                # Planning itself can move work (a handoff degrading to
                # recompute enqueues on another replica): wake targets.
                self._pump_handoffs(heap, until)
                continue
            try:
                result = pending.execute(self.executor, i)
            except Exception as exc:
                self._worker_failure(rep, pending, exc)
                self._repush(heap, until, busy=())
                continue
            rep.complete_step(pending, result)
            self._pump_handoffs(heap, until)
            t2 = rep.next_event_time()
            if t2 < until:
                heapq.heappush(heap, (t2, i))

    def _repush(self, heap: List, until: float, busy) -> None:
        """After a worker failure re-dispatched requests, idle replicas
        (absent from the heap) may suddenly have work: rebuild the heap
        from scratch — except replicas with an executor call in flight."""
        heap.clear()
        for r in self.replicas:
            if r.index in busy:
                continue
            t = r.next_event_time()
            if t < until:
                heapq.heappush(heap, (t, r.index))

    def _advance_concurrent(self, until: float = math.inf) -> None:
        """Event heap with overlapped execution: planned events are
        submitted to per-replica actor workers in global time order and
        their futures resolve back into the heap."""
        import concurrent.futures as cf
        heap: List = []
        for r in self.replicas:
            t = r.next_event_time()
            if t < until:
                heapq.heappush(heap, (t, r.index))
        inflight: Dict[cf.Future, tuple] = {}
        while heap or inflight:
            while heap:
                _, i = heapq.heappop(heap)
                if any(r.index == i for r, _ in inflight.values()):
                    continue       # stale duplicate: the replica is busy
                rep = self.replicas[i]
                pending = rep.begin_step(until)
                if pending is None:
                    self._pump_handoffs(heap, until)
                    continue
                fut = self._worker(i).submit(
                    lambda p=pending, i=i: p.execute(self.executor, i))
                inflight[fut] = (rep, pending)
            if not inflight:
                break
            done, _ = cf.wait(list(inflight),
                              return_when=cf.FIRST_COMPLETED)
            for fut in done:
                rep, pending = inflight.pop(fut)
                try:
                    result = fut.result()
                except Exception as exc:
                    self._worker_failure(rep, pending, exc)
                    self._repush(heap, until,
                                 busy={r.index
                                       for r, _ in inflight.values()})
                    continue
                rep.complete_step(pending, result)
                self._pump_handoffs(heap, until)
                t2 = rep.next_event_time()
                if t2 < until:
                    heapq.heappush(heap, (t2, rep.index))

    # ----------------------------------------------------------------- live

    def _advance_live(self, source: ArrivalSource,
                      until: float = math.inf) -> None:
        """Serve a live source until the barrier (a replan/autoscale time,
        in wall-clock offsets) or — when ``until`` is inf — until the
        source is closed and fully drained.

        Unlike the trace path, arrivals are *not* known up front: the loop
        drains new submissions between every event (so a request can join
        a replica's next admission group while its batch is mid-decode),
        executes each replica's next startable event (on the replica's
        actor worker when the executor is concurrent, overlapping wall
        time across replicas exactly like :meth:`_advance_concurrent`),
        and blocks on the source while nothing is startable.  Future
        completions ``kick()`` the source so commit latency isn't a poll
        interval.
        """
        conc = getattr(self.executor, "concurrent", False)
        import concurrent.futures as cf
        inflight: Dict[cf.Future, tuple] = {}
        busy: set = set()
        while True:
            seen = source.version()
            done = [f for f in list(inflight) if f.done()]
            for fut in done:
                rep, pending = inflight.pop(fut)
                busy.discard(rep.index)
                try:
                    result = fut.result()
                except Exception as exc:
                    self._worker_failure(rep, pending, exc)
                    continue
                rep.complete_step(pending, result)
                self._pump_handoffs(None, until)
            for state in source.take_until(until):
                self._dispatch(state)
            launched = False
            for rep in list(self.replicas):
                if rep.index in busy:
                    continue
                if rep.next_event_time() >= until:
                    continue
                pending = rep.begin_step(until)
                if pending is None:
                    # A degrade-at-plan-time handoff may have enqueued
                    # work on another replica without an event to commit.
                    self._pump_handoffs(None, until)
                    continue
                launched = True
                if conc:
                    fut = self._worker(rep.index).submit(
                        lambda p=pending, i=rep.index:
                            p.execute(self.executor, i))
                    inflight[fut] = (rep, pending)
                    busy.add(rep.index)
                    fut.add_done_callback(lambda _f: source.kick())
                else:
                    try:
                        result = pending.execute(self.executor, rep.index)
                    except Exception as exc:
                        self._worker_failure(rep, pending, exc)
                        continue
                    rep.complete_step(pending, result)
                    self._pump_handoffs(None, until)
            if launched or done:
                continue
            if not inflight:
                # A handoff-stalled replica reports a startable time but
                # begin_step keeps returning None — count it idle here,
                # or an exhausted source could never end the run (the
                # stranded requests fail at run end, like orphans).
                idle = all(r.next_event_time() >= until
                           or self._handoff_stalled(r)
                           for r in self.replicas)
                if until == math.inf:
                    if source.exhausted() and idle:
                        return
                elif source.now() >= until or (source.exhausted() and idle):
                    return
            timeout = None
            if until < math.inf:
                timeout = max(0.0, until - source.now())
                if inflight and timeout <= 0.0:
                    # Past the barrier but a launched event is still in
                    # flight: its done-callback kick() is the wakeup —
                    # block instead of spinning on a zero timeout.
                    timeout = None
            source.wait(seen, timeout)

    # -------------------------------------------------------------- export

    def export_trace(self, path: str) -> str:
        """Write this runtime's observability capture as Chrome
        trace-event JSON (open in https://ui.perfetto.dev).  Requires the
        runtime to have been constructed with ``obs=Observability()``."""
        if self.obs is None:
            raise RuntimeError(
                "export_trace requires observability: construct the "
                "runtime with ServingRuntime(..., obs=Observability()) "
                "or serve(..., observability=True)")
        return self.obs.export_chrome_trace(path)

    # ------------------------------------------------------------- workers

    def _worker(self, index: int) -> ReplicaWorker:
        worker = self._workers.get(index)
        if worker is None or not worker.alive:
            device = None
            device_for = getattr(self.executor, "device_for", None)
            if device_for is not None:
                device = device_for(index)
            worker = ReplicaWorker(f"replica-worker-{index}", device=device,
                                   obs=self.obs,
                                   call_timeout=self.worker_timeout)
            self._workers[index] = worker
        return worker

    def _close_workers(self) -> None:
        workers, self._workers = self._workers, {}
        for worker in workers.values():
            worker.close()
