"""The unified event-driven serving runtime.

:class:`ServingRuntime` executes a trace against a ``ServingPlan`` with
**streaming dispatch** — each request is routed at its arrival time through
the plan's :class:`~repro.runtime.router.AssignmentRouter`, never upfront —
and per-replica continuous batching
(:class:`~repro.runtime.replica.ReplicaRuntime`).  The pluggable
:class:`~repro.runtime.executor.Executor` decides whether the run is a
cost-model *prediction* (``CostModelExecutor``) or real token *execution*
(``EngineExecutor``); both travel the identical admission/batching/routing
code path and report the same TTFT/TPOT/goodput metrics.

Online replanning: pass :class:`ReplanEvent` s (e.g. the output of
``repro.core.scheduler.replan`` when a spot pool is reclaimed).  At each
event time the runtime matches the new plan's replicas against the live
pool by config key — survivors keep their clock, queue, and active batch;
removed replicas drain their active batch but their *queued* requests
migrate through the new plan's router to surviving/new replicas; arrivals
after the event are routed by the new plan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.plan import ServingPlan
from repro.core.workloads import Trace

from repro.runtime.executor import Executor
from repro.runtime.lifecycle import RequestState, RuntimeResult
from repro.runtime.replica import ReplicaRuntime
from repro.runtime.router import AssignmentRouter


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """Switch to ``plan`` at runtime time ``time`` (seconds)."""

    time: float
    plan: ServingPlan


class ServingRuntime:
    """One continuous-batching core behind both prediction and execution."""

    def __init__(self, plan: ServingPlan, executor: Executor):
        self.plan = plan
        self.executor = executor
        self.replicas: List[ReplicaRuntime] = [
            ReplicaRuntime(i, cfg, executor)
            for i, cfg in enumerate(plan.replicas)]
        self.router = AssignmentRouter(plan)
        # router's plan-local replica j -> global ReplicaRuntime
        self._route_map: List[ReplicaRuntime] = list(self.replicas)
        self.info: Dict[str, float] = {}

    # ------------------------------------------------------------- dispatch

    def _dispatch(self, state: RequestState,
                  at: Optional[float] = None) -> None:
        j = self.router.route(state.req)
        if j is None:
            state.replica = -1     # unroutable: no replica serves this model
            return
        state.routed_at = state.req.arrival if at is None else at
        self._route_map[j].enqueue(state)

    # -------------------------------------------------------------- replan

    def _apply_replan(self, event: ReplanEvent) -> None:
        new_plan = event.plan
        live = [r for r in self.replicas if not r.draining]
        claimed: set = set()
        kept = 0
        new_map: List[ReplicaRuntime] = []
        for cfg in new_plan.replicas:
            match = next((r for r in live if r.config.key == cfg.key
                          and r.index not in claimed), None)
            if match is not None:
                claimed.add(match.index)
                # An idle survivor's clock may lag the replan point; clamp so
                # migrated requests cannot be admitted before the event that
                # moved them (busy survivors are already past event.time).
                match.now = max(match.now, event.time)
                new_map.append(match)
                kept += 1
            else:
                idx = len(self.replicas)
                self.executor.add_replica(cfg)
                rep = ReplicaRuntime(idx, cfg, self.executor)
                rep.now = event.time          # spun up at the replan point
                self.replicas.append(rep)
                new_map.append(rep)
        migrated: List[RequestState] = []
        for r in live:
            if r.index not in claimed:
                r.draining = True             # finish active, admit nothing
                migrated.extend(r.strip_queue())
        self.router = AssignmentRouter(new_plan)
        self._route_map = new_map
        for state in sorted(migrated, key=lambda s: s.req.arrival):
            self._dispatch(state, at=event.time)   # rerouted now, not on arrival
        self.info["replicas_kept"] = self.info.get("replicas_kept", 0) + kept
        self.info["replicas_added"] = (self.info.get("replicas_added", 0)
                                       + len(new_plan.replicas) - kept)
        self.info["replicas_drained"] = (self.info.get("replicas_drained", 0)
                                         + len(live) - kept)
        self.info["requests_migrated"] = (self.info.get("requests_migrated", 0)
                                          + len(migrated))

    # ----------------------------------------------------------------- run

    def run(self, trace: Trace, *,
            replan: Union[ReplanEvent, Sequence[ReplanEvent], None] = None
            ) -> RuntimeResult:
        """Serve the trace; returns per-request records + aggregate metrics."""
        events: List[ReplanEvent] = (
            [replan] if isinstance(replan, ReplanEvent)
            else sorted(replan, key=lambda e: e.time) if replan else [])
        order = sorted(trace.requests, key=lambda q: q.arrival)
        states = [RequestState(req=req) for req in order]
        pos = 0
        for event in events:
            while pos < len(states) and order[pos].arrival <= event.time:
                self._dispatch(states[pos])
                pos += 1
            self._advance_all(until=event.time)
            self._apply_replan(event)
        while pos < len(states):
            self._dispatch(states[pos])
            pos += 1
        self._advance_all()
        busy = np.array([r.busy for r in self.replicas])
        info = dict(self.info)
        info["preemptions"] = float(sum(r.preempted for r in self.replicas))
        kv_peaks = [m.peak_used for m in
                    (self.executor.kv_manager(r.index) for r in self.replicas)
                    if m is not None]
        if kv_peaks:
            info["kv_peak_blocks"] = float(max(kv_peaks))
        return RuntimeResult(records=states, per_replica_busy=busy,
                             info=info)

    def _advance_all(self, until: float = math.inf) -> None:
        for rep in self.replicas:
            while rep.step(until=until):
                pass
