"""The unified event-driven serving runtime.

:class:`ServingRuntime` executes a trace against a ``ServingPlan`` with
**streaming dispatch** — each request is routed at its arrival time through
the plan's :class:`~repro.runtime.router.AssignmentRouter`, never upfront —
and per-replica continuous batching
(:class:`~repro.runtime.replica.ReplicaRuntime`).  The pluggable
:class:`~repro.runtime.executor.Executor` decides whether the run is a
cost-model *prediction* (``CostModelExecutor``) or real token *execution*
(``EngineExecutor``); both travel the identical admission/batching/routing
code path and report the same TTFT/TPOT/goodput metrics.

Time model — one **global event heap**: every replica is an event
generator (:meth:`~repro.runtime.replica.ReplicaRuntime.next_event_time` /
``begin_step``/``complete_step``) and the runtime always pops the
globally-earliest event, so arrivals, admissions, decode steps, replans,
and autoscale decisions interleave in true time order across replicas.
When the executor is concurrent (``EngineExecutor``), popped events are
*executed* on per-replica actor workers
(:class:`~repro.runtime.actor.ReplicaWorker`) so prefill/decode calls of
different replicas overlap in wall time, their futures resolving back
into the heap.  ``mode="sequential"`` keeps the legacy
replica-at-a-time loop as the equivalence baseline (byte-identical
schedules on the cost-model backend, asserted in ``tests/test_runtime``).

Online replanning: pass :class:`ReplanEvent` s (e.g. the output of
``repro.core.scheduler.replan`` when a spot pool is reclaimed).  At each
event time the runtime matches the new plan's replicas against the live
pool by config key — survivors keep their clock, queue, and active batch;
removed replicas drain their active batch but their *queued* requests
migrate through the new plan's router to surviving/new replicas; arrivals
after the event are routed by the new plan.

Autoscaling: pass a :class:`~repro.core.scheduler.ScalePolicy` as
``autoscale`` — the runtime samples per-replica queue depth and KV
watermark every ``policy.interval`` seconds of serving time and applies
the policy's add/drain decisions as online replans (with queue
rebalancing, so a scale-up immediately relieves a backlogged survivor).
Decisions are recorded in :attr:`scale_log` and counted in
``result.info``.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.plan import ServingPlan
from repro.core.workloads import Trace

from repro.runtime.actor import ReplicaWorker
from repro.runtime.executor import Executor
from repro.runtime.lifecycle import RequestState, RuntimeResult
from repro.runtime.replica import ReplicaRuntime
from repro.runtime.router import AssignmentRouter

MODES = ("events", "sequential")


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """Switch to ``plan`` at runtime time ``time`` (seconds)."""

    time: float
    plan: ServingPlan


class ServingRuntime:
    """One continuous-batching core behind both prediction and execution."""

    def __init__(self, plan: ServingPlan, executor: Executor, *,
                 mode: str = "events", preempt_policy: str = "latest"):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.plan = plan
        self.executor = executor
        self.mode = mode
        self.preempt_policy = preempt_policy
        self.replicas: List[ReplicaRuntime] = [
            ReplicaRuntime(i, cfg, executor, preempt_policy=preempt_policy)
            for i, cfg in enumerate(plan.replicas)]
        self.router = AssignmentRouter(plan)
        # router's plan-local replica j -> global ReplicaRuntime
        self._route_map: List[ReplicaRuntime] = list(self.replicas)
        self.info: Dict[str, object] = {}
        self.scale_log: List[object] = []     # ScaleDecision records
        self._workers: Dict[int, ReplicaWorker] = {}

    # ------------------------------------------------------------- dispatch

    def _dispatch(self, state: RequestState,
                  at: Optional[float] = None) -> None:
        j = self.router.route(state.req)
        if j is None:
            state.replica = -1     # unroutable: no replica serves this model
            return
        state.routed_at = state.req.arrival if at is None else at
        self._route_map[j].enqueue(state)

    # -------------------------------------------------------------- replan

    def _apply_replan(self, event: ReplanEvent, *,
                      rebalance: bool = False) -> None:
        """Switch the live pool to ``event.plan``.  ``rebalance`` (used by
        the autoscaler) additionally re-routes every *queued* request of
        surviving replicas through the new plan's router, so an added
        replica immediately shares a survivor's backlog."""
        new_plan = event.plan
        live = [r for r in self.replicas if not r.draining]
        claimed: set = set()
        kept = 0
        new_map: List[ReplicaRuntime] = []
        for cfg in new_plan.replicas:
            # Among same-key candidates, keep the one with the most
            # outstanding work (ties: lowest index, the legacy order) —
            # so when the autoscaler drains one of several identical
            # replicas, the *idle* instance is the one released.
            candidates = [r for r in live if r.config.key == cfg.key
                          and r.index not in claimed]
            match = max(candidates,
                        key=lambda r: (len(r.active) + len(r.queue),
                                       -r.index)) if candidates else None
            if match is not None:
                claimed.add(match.index)
                # An idle survivor's clock may lag the replan point; clamp so
                # migrated requests cannot be admitted before the event that
                # moved them (busy survivors are already past event.time).
                match.now = max(match.now, event.time)
                new_map.append(match)
                kept += 1
            else:
                idx = len(self.replicas)
                self.executor.add_replica(cfg)
                rep = ReplicaRuntime(idx, cfg, self.executor,
                                     preempt_policy=self.preempt_policy)
                rep.now = event.time          # spun up at the replan point
                self.replicas.append(rep)
                new_map.append(rep)
        migrated: List[RequestState] = []
        for r in live:
            if r.index not in claimed:
                r.draining = True             # finish active, admit nothing
                migrated.extend(r.strip_queue())
        if rebalance:
            for r in new_map:
                migrated.extend(r.strip_queue())
        self.router = AssignmentRouter(new_plan)
        self._route_map = new_map
        for state in sorted(migrated, key=lambda s: s.req.arrival):
            self._dispatch(state, at=event.time)   # rerouted now, not on arrival
        self._bump("replicas_kept", kept)
        self._bump("replicas_added", len(new_plan.replicas) - kept)
        self._bump("replicas_drained", len(live) - kept)
        self._bump("requests_migrated", len(migrated))

    def _bump(self, key: str, n: float) -> None:
        self.info[key] = float(self.info.get(key, 0)) + n

    # ---------------------------------------------------------- autoscaling

    def _snapshot(self):
        """Per-replica load observations for the scale policy."""
        from repro.core.scheduler import ReplicaSnapshot
        snaps = []
        for r in self.replicas:
            mgr = self.executor.kv_manager(r.index)
            kv = 0.0
            if mgr is not None and mgr.num_blocks > 0:
                kv = mgr.used_blocks / mgr.num_blocks
            snaps.append(ReplicaSnapshot(
                index=r.index, config=r.config, queue_len=len(r.queue),
                active=len(r.active), kv_used_frac=float(kv),
                draining=r.draining,
                step_time_s=self.executor.step_time_estimate(r.index)))
        return snaps

    def _autoscale_tick(self, t: float, policy) -> None:
        decision = policy.update(t, self._snapshot(), self.router.plan)
        if decision is None:
            return
        self.scale_log.append(decision)
        self._bump("autoscale_adds" if decision.action == "add"
                   else "autoscale_drains", 1)
        self._apply_replan(ReplanEvent(time=t, plan=decision.plan),
                           rebalance=True)

    # ----------------------------------------------------------------- run

    def run(self, trace: Trace, *,
            replan: Union[ReplanEvent, Sequence[ReplanEvent], None] = None,
            autoscale=None) -> RuntimeResult:
        """Serve the trace; returns per-request records + aggregate metrics.

        ``replan`` passes pre-planned :class:`ReplanEvent` s; ``autoscale``
        optionally passes a :class:`~repro.core.scheduler.ScalePolicy`
        that emits further replans online from observed load.
        """
        events: List[ReplanEvent] = (
            [replan] if isinstance(replan, ReplanEvent)
            else sorted(replan, key=lambda e: e.time) if replan else [])
        order = sorted(trace.requests, key=lambda q: q.arrival)
        states = [RequestState(req=req) for req in order]
        pos = 0
        ei = 0
        tick = math.inf
        if autoscale is not None:
            autoscale.reset()
            tick = (order[0].arrival if order else 0.0) + autoscale.interval
        try:
            while True:
                next_replan = (events[ei].time if ei < len(events)
                               else math.inf)
                barrier = min(next_replan, tick)
                while pos < len(states) and order[pos].arrival <= barrier:
                    self._dispatch(states[pos])
                    pos += 1
                self._advance_all(until=barrier)
                if barrier == math.inf:
                    break
                if next_replan <= tick:
                    self._apply_replan(events[ei])
                    ei += 1
                else:
                    self._autoscale_tick(tick, autoscale)
                    tick += autoscale.interval
                    if (pos >= len(states) and ei >= len(events)
                            and all(r.next_event_time() == math.inf
                                    for r in self.replicas)):
                        break     # trace fully served: stop ticking
        finally:
            self._close_workers()
        busy = np.array([r.busy for r in self.replicas])
        info = dict(self.info)
        info["preemptions"] = float(sum(r.preempted for r in self.replicas))
        per_replica: List[Dict[str, object]] = []
        kv_peaks: List[float] = []
        for r in self.replicas:
            mgr = self.executor.kv_manager(r.index)
            if mgr is not None:
                kv_peaks.append(mgr.peak_used)
            per_replica.append({
                "replica": r.index,
                "config": r.config.key,
                "busy_s": float(r.busy),
                "completed": r.completed,
                "preemptions": r.preempted,
                "draining": r.draining,
                "kv_peak_blocks": mgr.peak_used if mgr is not None else None,
                "kv_blocks": mgr.num_blocks if mgr is not None else None,
                "step_time_s": self.executor.step_time_estimate(r.index),
            })
        info["per_replica"] = per_replica
        if kv_peaks:
            info["kv_peak_blocks"] = float(max(kv_peaks))
        if autoscale is not None:
            info["autoscale_events"] = float(len(self.scale_log))
        return RuntimeResult(records=states, per_replica_busy=busy,
                             info=info)

    # ------------------------------------------------------------- advance

    def _advance_all(self, until: float = math.inf) -> None:
        """Advance every replica until no event can start before ``until``
        (atomic events may complete past it)."""
        if self.mode == "sequential":
            for rep in self.replicas:
                while rep.step(until=until):
                    pass
        elif getattr(self.executor, "concurrent", False) \
                and len(self.replicas) > 1:
            self._advance_concurrent(until)
        else:
            self._advance_events(until)

    def _advance_events(self, until: float = math.inf) -> None:
        """Global event heap: always fire the event with the earliest
        start time across all replicas."""
        heap: List = []
        for r in self.replicas:
            t = r.next_event_time()
            if t < until:
                heapq.heappush(heap, (t, r.index))
        while heap:
            _, i = heapq.heappop(heap)
            rep = self.replicas[i]
            if not rep.step_event(until):
                continue
            t2 = rep.next_event_time()
            if t2 < until:
                heapq.heappush(heap, (t2, i))

    def _advance_concurrent(self, until: float = math.inf) -> None:
        """Event heap with overlapped execution: planned events are
        submitted to per-replica actor workers in global time order and
        their futures resolve back into the heap."""
        import concurrent.futures as cf
        heap: List = []
        for r in self.replicas:
            t = r.next_event_time()
            if t < until:
                heapq.heappush(heap, (t, r.index))
        inflight: Dict[cf.Future, tuple] = {}
        while heap or inflight:
            while heap:
                _, i = heapq.heappop(heap)
                rep = self.replicas[i]
                pending = rep.begin_step(until)
                if pending is None:
                    continue
                fut = self._worker(i).submit(
                    lambda p=pending, i=i: p.execute(self.executor, i))
                inflight[fut] = (rep, pending)
            if not inflight:
                break
            done, _ = cf.wait(list(inflight),
                              return_when=cf.FIRST_COMPLETED)
            for fut in done:
                rep, pending = inflight.pop(fut)
                rep.complete_step(pending, fut.result())
                t2 = rep.next_event_time()
                if t2 < until:
                    heapq.heappush(heap, (t2, rep.index))

    # ------------------------------------------------------------- workers

    def _worker(self, index: int) -> ReplicaWorker:
        worker = self._workers.get(index)
        if worker is None:
            device = None
            device_for = getattr(self.executor, "device_for", None)
            if device_for is not None:
                device = device_for(index)
            worker = ReplicaWorker(f"replica-worker-{index}", device=device)
            self._workers[index] = worker
        return worker

    def _close_workers(self) -> None:
        workers, self._workers = self._workers, {}
        for worker in workers.values():
            worker.close()
