"""Actor-style per-replica workers for concurrent engine execution.

Each :class:`ReplicaWorker` owns one daemon thread and a mailbox
(the actor pattern, à la xoscar): the orchestrator submits one executor
call at a time per replica and gets a :class:`concurrent.futures.Future`
back, which the global event heap resolves into the replica's clock when
it completes.  Per-replica serialization is the concurrency contract —
a replica's prefill/decode calls never overlap *each other*, only calls
of *different* replicas overlap in wall time.

An optional JAX device pins every call the worker runs (one accelerator
per replica in deployment; a no-op on a single-device container).

With observability attached (``obs=``), every executed task is recorded
as a **wall-clock** occupancy span on a per-worker trace track — using
``time.perf_counter`` directly, *outside* the executor's own timing
bracket (the executor's injectable clock seam stays untouched, so a
pinned deterministic test clock still measures exactly one tick per
call; see ``repro.obs.clock``).
"""
from __future__ import annotations

import contextlib
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, Optional


class ReplicaWorker:
    """One mailbox thread executing a replica's backend calls in order."""

    def __init__(self, name: str, device: Optional[object] = None,
                 obs=None):
        self.name = name
        self.device = device
        self.obs = obs
        self._mailbox: "queue.SimpleQueue" = queue.SimpleQueue()
        self._closed = False
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)
        self._thread.start()

    @property
    def alive(self) -> bool:
        """False once closed (or the thread died): the owner must build a
        fresh worker — long-lived runtimes (sessions / reusable servers)
        recreate workers lazily per run."""
        return not self._closed and self._thread.is_alive()

    def submit(self, fn: Callable[[], object]) -> Future:
        """Enqueue ``fn`` on this worker's thread; returns its Future."""
        if not self.alive:
            raise RuntimeError(f"worker {self.name} is closed")
        fut: Future = Future()
        self._mailbox.put((fn, fut))
        return fut

    def _device_scope(self):
        if self.device is None:
            return contextlib.nullcontext()
        import jax
        return jax.default_device(self.device)

    def _loop(self) -> None:
        while True:
            item = self._mailbox.get()
            if item is None:
                return
            fn, fut = item
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                with self._device_scope():
                    if self.obs is None:
                        fut.set_result(fn())
                    else:
                        t0 = time.perf_counter()
                        result = fn()
                        self.obs.on_worker_task(self.name, t0,
                                                time.perf_counter())
                        fut.set_result(result)
            except BaseException as exc:  # propagate through the future
                fut.set_exception(exc)

    def close(self, timeout: float = 5.0) -> None:
        """Drain the mailbox and stop the thread (idempotent)."""
        self._closed = True
        self._mailbox.put(None)
        self._thread.join(timeout=timeout)
